from repro.ckpt.ckpt import (  # noqa: F401
    latest_step, restore, restore_latest, save, gc_keep_n)
