"""Fault-tolerant checkpointing: atomic per-step npz snapshots.

Write protocol (restart-safe at any kill point):
  1. serialize the pytree to  <dir>/step_<N>.npz.tmp
  2. fsync + os.replace -> <dir>/step_<N>.npz       (atomic on POSIX)
  3. rewrite <dir>/LATEST (tmp + replace) with N
A crash mid-write leaves only a .tmp file that restore ignores; LATEST
always points at a fully-written snapshot.  Resume = restore_latest().

The data pipeline needs no state file: batches are pure functions of the
step index (repro.data.synthetic), so restoring `step` resumes the exact
token stream.  Multi-host note: on a real cluster each process saves its
own address-space shards under <dir>/proc_<k>/ with the same protocol and
a rendezvous on LATEST; this container is single-process.
"""
from __future__ import annotations

import io
import os
import pathlib
import re

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        a = np.asarray(leaf)
        # np.savez cannot store ml_dtypes (bfloat16 etc.); store as f32 —
        # restore() casts back to the example leaf's dtype (lossless for
        # bf16 since bf16 -> f32 -> bf16 is exact)
        if a.dtype.kind == "V" or a.dtype.name in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            a = np.asarray(leaf, np.float32)
        flat[key] = a
    return flat


def save(ckpt_dir, step: int, tree) -> str:
    d = pathlib.Path(ckpt_dir)
    d.mkdir(parents=True, exist_ok=True)
    path = d / f"step_{step:08d}.npz"
    tmp = d / f"step_{step:08d}.npz.tmp"
    buf = io.BytesIO()
    np.savez(buf, **_flatten(tree))
    with open(tmp, "wb") as f:
        f.write(buf.getvalue())
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    ltmp = d / "LATEST.tmp"
    ltmp.write_text(str(step))
    os.replace(ltmp, d / "LATEST")
    return str(path)


def latest_step(ckpt_dir) -> int | None:
    d = pathlib.Path(ckpt_dir)
    marker = d / "LATEST"
    if marker.exists():
        try:
            step = int(marker.read_text().strip())
            if (d / f"step_{step:08d}.npz").exists():
                return step
        except ValueError:
            pass
    # fall back to scanning (LATEST lost but snapshots intact)
    best = None
    for p in d.glob("step_*.npz"):
        m = re.match(r"step_(\d+)\.npz$", p.name)
        if m:
            best = max(best or 0, int(m.group(1)))
    return best


def restore(ckpt_dir, step: int, example_tree):
    """Restore into the structure of example_tree (dtypes preserved)."""
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}.npz"
    with np.load(path) as data:
        flat = dict(data)
    paths, treedef = jax.tree_util.tree_flatten_with_path(example_tree)
    leaves = []
    for path_k, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path_k)
        arr = flat[key]
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_latest(ckpt_dir, example_tree):
    step = latest_step(ckpt_dir)
    if step is None:
        return None, None
    return step, restore(ckpt_dir, step, example_tree)


def gc_keep_n(ckpt_dir, keep: int = 3):
    """Delete all but the newest `keep` snapshots."""
    d = pathlib.Path(ckpt_dir)
    snaps = sorted(d.glob("step_*.npz"))
    for p in snaps[:-keep] if keep > 0 else []:
        p.unlink(missing_ok=True)
    for p in d.glob("*.tmp"):
        p.unlink(missing_ok=True)
