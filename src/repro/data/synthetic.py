"""Procedural datasets (this container has no external datasets).

Image tasks are MNIST/smallNORB/CIFAR *analogues*: class templates rendered
with random affine pose + noise, so (a) a CapsNet can genuinely learn them
and (b) post-training quantization has a real float-vs-int8 accuracy gap to
measure.  LM data is a noisy deterministic token process (learnable
structure, so train loss decreases measurably).

Everything is generated from (seed, index) — a batch is a pure function of
its index, which makes data-pipeline state trivially checkpointable: resume
= remember the step counter (repro.ckpt stores it).
"""
from __future__ import annotations

import numpy as np

DIGITS = [
    "01110 10001 10011 10101 11001 10001 01110",  # 0
    "00100 01100 00100 00100 00100 00100 01110",  # 1
    "01110 10001 00001 00010 00100 01000 11111",  # 2
    "01110 10001 00001 00110 00001 10001 01110",  # 3
    "00010 00110 01010 10010 11111 00010 00010",  # 4
    "11111 10000 11110 00001 00001 10001 01110",  # 5
    "01110 10000 11110 10001 10001 10001 01110",  # 6
    "11111 00001 00010 00100 01000 01000 01000",  # 7
    "01110 10001 10001 01110 10001 10001 01110",  # 8
    "01110 10001 10001 01111 00001 00001 01110",  # 9
]


def _bitmap(tpl: str) -> np.ndarray:
    rows = tpl.split()
    return np.array([[float(c) for c in r] for r in rows], np.float32)


_DIGIT_MAPS = [np.kron(_bitmap(t), np.ones((3, 3), np.float32))
               for t in DIGITS]                        # 21 x 15


def _affine_place(canvas_hw, img, rng, max_shift=3, rot=0.35, scale=0.25):
    """Place `img` on a canvas with a random rotation/scale/shift
    (inverse-mapped bilinear sampling)."""
    H, W = canvas_hw
    h, w = img.shape
    th = rng.uniform(-rot, rot)
    sc = 1.0 + rng.uniform(-scale, scale)
    cx, cy = W / 2 + rng.integers(-max_shift, max_shift + 1), \
        H / 2 + rng.integers(-max_shift, max_shift + 1)
    cos, sin = np.cos(th) / sc, np.sin(th) / sc
    ys, xs = np.mgrid[0:H, 0:W]
    u = cos * (xs - cx) + sin * (ys - cy) + w / 2
    v = -sin * (xs - cx) + cos * (ys - cy) + h / 2
    u0 = np.clip(np.floor(u).astype(int), 0, w - 2)
    v0 = np.clip(np.floor(v).astype(int), 0, h - 2)
    du = np.clip(u - u0, 0, 1)
    dv = np.clip(v - v0, 0, 1)
    valid = (u >= 0) & (u < w - 1) & (v >= 0) & (v < h - 1)
    out = (img[v0, u0] * (1 - du) * (1 - dv) + img[v0, u0 + 1] * du * (1 - dv)
           + img[v0 + 1, u0] * (1 - du) * dv + img[v0 + 1, u0 + 1] * du * dv)
    return np.where(valid, out, 0.0).astype(np.float32)


def _shape_mask(kind: int, size: int = 24) -> np.ndarray:
    ys, xs = np.mgrid[0:size, 0:size].astype(np.float32)
    c = (size - 1) / 2
    x, y = (xs - c) / c, (ys - c) / c
    if kind == 0:                                     # ellipse
        return ((x / 0.9) ** 2 + (y / 0.55) ** 2 <= 1).astype(np.float32)
    if kind == 1:                                     # rectangle
        return ((np.abs(x) <= 0.8) & (np.abs(y) <= 0.45)).astype(np.float32)
    if kind == 2:                                     # triangle
        return ((y >= -0.7) & (y <= 0.8) &
                (np.abs(x) <= 0.8 * (0.8 - y) / 1.5)).astype(np.float32)
    if kind == 3:                                     # plus
        return ((np.abs(x) <= 0.25) | (np.abs(y) <= 0.25)).astype(np.float32)
    r = np.sqrt(x * x + y * y)
    a = np.arctan2(y, x)
    return (r <= 0.45 + 0.4 * np.cos(5 * a) ** 2).astype(np.float32)  # star


_DIGIT_MAPS_SMALL = [np.kron(_bitmap(t), np.ones((2, 2), np.float32))
                     for t in DIGITS]                  # 14 x 10

def make_image_dataset(kind: str, n: int, seed: int = 0):
    """kind: mnist | smallnorb | cifar10 | edge_tiny.
    Returns (images NHWC, labels).  "edge_tiny" is the MNIST analogue
    shrunk to the serving registry's EDGE_TINY geometry (16x16x1, digits
    0-3) so the deep-edge config has a real accuracy task to train on."""
    rng = np.random.default_rng(seed)
    if kind == "mnist":
        H, W, C, ncls = 28, 28, 1, 10
    elif kind == "edge_tiny":
        H, W, C, ncls = 16, 16, 1, 4
    elif kind == "smallnorb":
        H, W, C, ncls = 32, 32, 2, 5
    else:
        H, W, C, ncls = 32, 32, 3, 10
    imgs = np.zeros((n, H, W, C), np.float32)
    labels = rng.integers(0, ncls, n).astype(np.int32)
    for i in range(n):
        y = int(labels[i])
        if kind == "mnist":
            base = _affine_place((H, W), _DIGIT_MAPS[y], rng)
            imgs[i, :, :, 0] = base
        elif kind == "edge_tiny":
            base = _affine_place((H, W), _DIGIT_MAPS_SMALL[y], rng,
                                 max_shift=1)
            imgs[i, :, :, 0] = base
        elif kind == "smallnorb":
            m = _shape_mask(y)
            base = _affine_place((H, W), m, rng, rot=1.2)
            light = rng.uniform(0.5, 1.0)
            shift = rng.integers(1, 3)
            imgs[i, :, :, 0] = base * light
            imgs[i, :, :, 1] = np.roll(base, shift, axis=1) * light
        else:
            shape = _shape_mask(y % 5)
            base = _affine_place((H, W), shape, rng, rot=1.2)
            hue = (y // 5)
            col = rng.uniform(0.6, 1.0, 3)
            col[hue] *= 0.3                       # class-dependent colour
            for ch in range(3):
                imgs[i, :, :, ch] = base * col[ch]
            imgs[i] += rng.uniform(0, 0.25) * \
                rng.random((H, W, C)).astype(np.float32)
        imgs[i] += rng.normal(0, 0.04, (H, W, C)).astype(np.float32)
    np.clip(imgs, 0.0, 1.0, out=imgs)
    return imgs, labels


# ---------------------------------------------------------------------------
# LM token stream
# ---------------------------------------------------------------------------
class TokenTask:
    """Noisy affine-recurrence token stream: token_{t+1} =
    (a * token_t + b) mod V with random resets — learnable structure."""

    def __init__(self, vocab: int, seq_len: int, seed: int = 0,
                 a: int = 31, b: int = 17, reset_p: float = 0.05):
        self.vocab = max(vocab, 8)
        self.seq = seq_len
        self.seed = seed
        self.a, self.b, self.reset_p = a, b, reset_p

    def batch(self, index: int, batch_size: int) -> dict:
        rng = np.random.default_rng((self.seed, index))
        toks = np.zeros((batch_size, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, batch_size)
        resets = rng.random((batch_size, self.seq)) < self.reset_p
        fresh = rng.integers(0, self.vocab, (batch_size, self.seq))
        for t in range(self.seq):
            nxt = (self.a * toks[:, t] + self.b) % self.vocab
            toks[:, t + 1] = np.where(resets[:, t], fresh[:, t], nxt)
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}


class ImageTask:
    """Index-addressable image batches (for CapsNet training)."""

    def __init__(self, kind: str, seed: int = 0):
        self.kind = kind
        self.seed = seed

    def batch(self, index: int, batch_size: int):
        imgs, labels = make_image_dataset(self.kind, batch_size,
                                          seed=(self.seed * 100003 + index))
        return imgs, labels
