"""jamba-v0.1-52b [arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2.
Mamba:attention 7:1 interleave (1 attention layer per 8-layer block, at index
4 per the Jamba paper), MoE every other layer.
Hybrid with 4/32 attention layers -> long_500k runs (attention caches are
sequence-sharded).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba_v01_52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    blocks=(
        ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
        ("attn", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
    ),
    num_experts=16,
    experts_per_tok=2,
    ssm_state_dim=16,
    ssm_conv_dim=4,
    ssm_expand=2,
    rope_theta=10_000.0,
    source="arXiv:2403.19887",
)
