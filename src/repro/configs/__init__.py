from repro.configs.base import (  # noqa: F401
    ARCH_IDS, SHAPES, ModelConfig, ShapeSpec, all_configs, cell_is_runnable,
    get_config)
