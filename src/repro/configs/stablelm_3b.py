"""stablelm-3b [hf:stabilityai/stablelm-2-1_6b family; unverified].

32L d_model=2560 32H (kv=32 -> full MHA) d_ff=6912 vocab=50304.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm_3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    blocks=(("attn", "mlp"),),
    rope_theta=10_000.0,
    source="hf:stabilityai/stablelm-2-1_6b",
)
