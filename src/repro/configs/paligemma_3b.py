"""paligemma-3b [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1 -> MQA) d_ff=16384 vocab=257216.
SigLIP frontend is a STUB per assignment: input_specs() provides precomputed
patch embeddings [B, 256, d_model]; attention is prefix-bidirectional over the
patch prefix (prefix-LM), causal over text.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma_3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    blocks=(("attn", "mlp"),),
    prefix_bidir=True,
    frontend="patch",
    num_prefix_embeds=256,
    rope_theta=10_000.0,
    source="arXiv:2407.07726",
)
