"""xlstm-1.3b [arXiv:2405.04517; unverified].

48 blocks d_model=2048 4H d_ff=0 vocab=50304, xLSTM[7:1] — 7 mLSTM blocks per
sLSTM block.  Blocks carry their own up/down projections (d_ff=0 in the
assignment means no separate FFN): mLSTM uses projection factor 2, sLSTM a
gated FFN with factor 4/3, per the xLSTM paper.
Recurrent (O(1) decode state) -> long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm_1_3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    blocks=(
        ("mlstm", "none"), ("mlstm", "none"), ("mlstm", "none"),
        ("slstm", "none"), ("mlstm", "none"), ("mlstm", "none"),
        ("mlstm", "none"), ("mlstm", "none"),
    ),
    xlstm_expand=2,
    source="arXiv:2405.04517",
)
