"""Config system: model architecture configs + assigned input-shape grid.

Every assigned architecture is a `ModelConfig`; every assigned input shape is a
`ShapeSpec`.  The dry-run grid is the cross product (minus documented skips, see
DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------
# mixer kinds: "attn" (global causal), "swa" (sliding-window), "mamba",
#              "mlstm", "slstm"
# ffn kinds:   "mlp", "moe", "none"
BlockSpec = tuple[str, str]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # block pattern, cycled over the depth.  len must divide num_layers.
    blocks: tuple[BlockSpec, ...] = (("attn", "mlp"),)
    # --- attention options -------------------------------------------------
    window_size: int = 0             # for "swa" blocks
    qk_norm: bool = False
    qkv_bias: bool = False
    # extra all-zero query heads so the head count divides the 16-way model
    # axis (function-preserving: zero wq rows -> uniform attention ->
    # killed by zero wo rows).  qwen3 40H -> +8; §Perf B1.
    head_pad: int = 0
    rope_theta: float = 10_000.0
    prefix_bidir: bool = False       # VLM prefix-LM attention over the prefix
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    experts_per_tok: int = 2
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "gshard_sort"    # gshard_sort | ep (shard_map all-to-all)
    # --- SSM (mamba) --------------------------------------------------------
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model / 16)
    # --- xLSTM --------------------------------------------------------------
    xlstm_expand: int = 2
    xlstm_impl: str = "chunked"      # chunked (closed form) | recurrent
    xlstm_chunk: int = 256
    # --- enc-dec ------------------------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # --- modality frontend stub ---------------------------------------------
    frontend: Optional[str] = None   # "patch" (vlm) | "frame" (audio)
    num_prefix_embeds: int = 256     # patches per image for vlm
    # --- numerics -----------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # decode: unroll the layer loop so per-layer caches are top-level
    # donated buffers updated IN PLACE — a scanned cache (xs/ys) rewrites
    # the full cache every step (§Perf C3).  Train/prefill stay scanned.
    decode_unroll: bool = True
    # int8 KV cache (§Perf C5): the paper's Qm.n power-of-two format
    # applied to the decode cache — K/V stored int8 with per-(pos, head)
    # exponents; attention probabilities re-quantized per-row to Q0.7
    # (exactly the coupling-coefficient pattern of the routing kernel).
    kv_cache_int8: bool = False
    # --- provenance ---------------------------------------------------------
    source: str = ""

    def __post_init__(self):
        assert self.num_layers % len(self.blocks) == 0, (
            f"{self.name}: pattern len {len(self.blocks)} must divide "
            f"num_layers {self.num_layers}")

    @property
    def num_cycles(self) -> int:
        return self.num_layers // len(self.blocks)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables are padded to a multiple of 256 so the
        16-way model axis (and data*model=256) always divides them
        (e.g. seamless 256206 -> 256256).  Logical vocab is unchanged."""
        return -(-self.vocab_size // 256) * 256

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def xlstm_inner(self) -> int:
        return self.xlstm_expand * self.d_model

    @property
    def is_subquadratic(self) -> bool:
        """True if no block is unbounded full attention (cycled pattern)."""
        return all(m != "attn" for m, _ in self.blocks)

    @property
    def has_mostly_bounded_context(self) -> bool:
        """True if the arch is SSM/hybrid/local-attn enough for long_500k.

        gemma3 (5 local : 1 global), jamba (28 mamba : 4 attn) and mixtral
        (SWA everywhere) qualify; pure full-attention stacks do not.
        """
        n_full = sum(1 for m, _ in self.blocks if m == "attn")
        return n_full == 0 or n_full / len(self.blocks) <= 0.25

    def scaled(self, **kw) -> "ModelConfig":
        """Return a reduced copy (for smoke tests)."""
        return dataclasses.replace(self, **kw)

    # Rough parameter count (embeddings included), used for roofline
    # MODEL_FLOPS = 6 * N * D  (N_active for MoE).
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        qdim = self.num_heads * self.head_dim
        kdim = self.num_kv_heads * self.head_dim
        total = v * d + d * v  # embed + head (untied)
        if self.tie_embeddings:
            total -= d * v
        def block_params(mixer: str, ffn: str) -> int:
            p = 2 * d  # norms
            if mixer in ("attn", "swa"):
                p += d * (qdim + 2 * kdim) + qdim * d
                if self.qkv_bias:
                    p += qdim + 2 * kdim
            elif mixer == "mamba":
                ed, n, r = self.ssm_inner, self.ssm_state_dim, self.dt_rank
                p += d * 2 * ed + ed * self.ssm_conv_dim + ed * (r + 2 * n)
                p += r * ed + ed * n + ed + ed * d
            elif mixer == "mlstm":
                ed = self.xlstm_inner
                p += d * 2 * ed + 3 * ed * ed + 2 * ed * self.num_heads + ed * d
            elif mixer == "slstm":
                dh = d // self.num_heads
                p += 4 * d * d + 4 * self.num_heads * dh * dh
                p += 2 * d * (4 * d // 3)   # pf=4/3 FFN
            if ffn == "mlp":
                p += 3 * d * f
            elif ffn == "moe":
                e = self.num_experts if not active_only else self.experts_per_tok
                p += d * self.num_experts  # router (always resident)
                p += e * 3 * d * f
            return p
        per_cycle = sum(block_params(m, fk) for m, fk in self.blocks)
        total += per_cycle * self.num_cycles
        if self.is_encoder_decoder:
            # encoder self-attn+mlp plus decoder cross-attn per layer
            enc = self.num_encoder_layers * (
                d * (qdim + 2 * kdim) + qdim * d + 3 * d * f + 2 * d)
            cross = self.num_layers * (d * (qdim + 2 * kdim) + qdim * d + d)
            total += enc + cross
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k":   ShapeSpec("long_500k", "decode", 524_288, 1),
}

ARCH_IDS = (
    "phi35_moe", "mixtral_8x22b", "qwen2_72b", "qwen3_14b", "gemma3_12b",
    "stablelm_3b", "paligemma_3b", "xlstm_1_3b", "jamba_v01_52b",
    "seamless_m4t_medium",
)

# long_500k runs only for archs with mostly bounded context (DESIGN.md §5).
def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.has_mostly_bounded_context:
        return False, "long_500k skipped: pure full-attention arch (DESIGN.md §5)"
    return True, ""


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
