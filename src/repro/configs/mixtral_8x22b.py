"""mixtral-8x22b [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts top-2,
sliding-window attention (assignment specifies SWA) -> window-bounded cache,
so long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral_8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    blocks=(("swa", "moe"),),
    window_size=4096,
    num_experts=8,
    experts_per_tok=2,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)
