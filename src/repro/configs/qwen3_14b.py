"""qwen3-14b [hf:Qwen/Qwen3-8B family config; hf].

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936, qk_norm, GQA.
Pure full attention -> long_500k skipped.

Note: 40 query heads are not divisible by the 16-way model axis; GSPMD pads
the head dim in attention einsums (48/40 = 1.2x attention-FLOP overhead,
recorded in EXPERIMENTS.md §Roofline).  Projection weights shard on the flat
H*head_dim = 5120 dim, which is divisible.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    blocks=(("attn", "mlp"),),
    qk_norm=True,
    head_pad=8,   # 40 -> 48 query heads for the 16-way model axis (zeroed)
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-8B",
)
