"""seamless-m4t-medium [arXiv:2308.11596; hf].

12L d_model=1024 16H (kv=16 MHA) d_ff=4096 vocab=256206, encoder-decoder,
multimodal.  The speech frontend is a STUB per assignment: input_specs()
provides precomputed frame embeddings [B, S_src, d_model].
Shapes are interpreted as src_len = tgt_len = seq_len.  Enc-dec (not
encoder-only) -> decode shapes run against the decoder.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    blocks=(("attn", "mlp"),),
    is_encoder_decoder=True,
    num_encoder_layers=12,
    frontend="frame",
    rope_theta=10_000.0,
    source="arXiv:2308.11596",
)
