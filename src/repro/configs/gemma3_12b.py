"""gemma3-12b [hf:google/gemma-3-1b-pt scaled family; unverified].

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144, 5:1 local:global
attention (window 1024), 128k context.  Mostly bounded context -> long_500k
runs (8/48 global layers use a sequence-sharded KV cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3_12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    blocks=(
        ("swa", "mlp"), ("swa", "mlp"), ("swa", "mlp"),
        ("swa", "mlp"), ("swa", "mlp"), ("attn", "mlp"),
    ),
    window_size=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt",
)
