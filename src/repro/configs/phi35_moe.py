"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
Pure full attention -> long_500k skipped (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi35_moe",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    blocks=(("attn", "moe"),),
    num_experts=16,
    experts_per_tok=2,
    rope_theta=10_000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
