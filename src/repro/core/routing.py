"""Dynamic routing between capsules (Sabour et al. 2017, Algorithm 1) —
float reference implementation.

u_hat [B, J, I, O]: prediction of capsule j (layer L+1) from capsule i
(layer L).  Coupling logits b start at zero; each iteration couples via a
softmax over the *output* capsules j (the importances of capsule i for all
j sum to 1), forms s_j = sum_i c_ij u_hat_ji, squashes, and reinforces b by
the agreement <u_hat_ji, v_j>.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def squash(s, axis: int = -1, eps: float = 1e-7):
    """v = (|s|^2 / (1+|s|^2)) * s/|s|  (Eq. 1), fp32 internals."""
    s = s.astype(jnp.float32)
    sq = jnp.sum(jnp.square(s), axis=axis, keepdims=True)
    return (sq / (1.0 + sq)) * s * jax.lax.rsqrt(sq + eps)


def dynamic_routing(u_hat, num_iters: int = 3):
    """u_hat [B, J, I, O] -> v [B, J, O] (and final coupling c [B, J, I])."""
    B, J, I, O = u_hat.shape
    b = jnp.zeros((B, J, I), jnp.float32)
    u_f = u_hat.astype(jnp.float32)
    # routing does not backprop through the coupling iterations' inputs in
    # the original implementation except the last; we keep full backprop
    # (matches the reference TF code behaviour with small r).
    v = None
    for r in range(num_iters):
        c = jax.nn.softmax(b, axis=1)            # over output capsules j
        s = jnp.einsum("bji,bjio->bjo", c, u_f)
        v = squash(s, axis=-1)
        if r < num_iters - 1:
            b = b + jnp.einsum("bjio,bjo->bji", u_f, v)
    return v.astype(u_hat.dtype), None
