"""Float CapsNet — compatibility shim over the typed repro.nn pipeline.

The model itself (layers, geometry, calibration taps) lives in
`repro.nn`; this module keeps the original function-style API — and the
legacy trace-dict key names — for training code, tests and benchmarks.
Config classes re-export from repro.nn.config (paper Table 1 geometries
and the Table 2/7 footprint cross-checks are documented there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.nn import compat
from repro.nn.config import (CAPSNET_CONFIGS, CIFAR10,  # noqa: F401
                             MNIST, SMALLNORB, CapsNetConfig)
from repro.nn.pipeline import CapsPipeline


@functools.lru_cache(maxsize=None)
def pipeline(cfg: CapsNetConfig) -> CapsPipeline:
    """The shared typed pipeline for a config (configs are frozen)."""
    return CapsPipeline.from_config(cfg)


def init_capsnet(key, cfg: CapsNetConfig) -> dict:
    return pipeline(cfg).init(key)


def capsnet_forward(params, x, cfg: CapsNetConfig, *, with_trace=False):
    """x [B,H,W,C] float in [0,1] -> class capsule vectors [B, J, O].

    with_trace: also return intermediate activations under the legacy
    trace keys (use `pipeline(cfg).forward(..., with_taps=True)` for the
    namespaced tap names).
    """
    if with_trace:
        v, taps = pipeline(cfg).forward(params, x, with_taps=True)
        return v, compat.taps_to_trace(taps)
    return pipeline(cfg).forward(params, x)


def primary_caps(params, x, cfg: CapsNetConfig):
    """conv -> reshape [B, N_caps, dim] -> squash (paper §3.3)."""
    layer = pipeline(cfg).layer("pcap")
    u, _ = layer.fwd_f32(params["pcap"], x)
    return u


def class_lengths(v):
    return jnp.sqrt(jnp.sum(jnp.square(v), axis=-1) + 1e-9)


def margin_loss(v, labels, num_classes: int,
                m_pos=0.9, m_neg=0.1, lam=0.5):
    """Sabour et al. margin loss."""
    L = class_lengths(v)                              # [B, J]
    T = jax.nn.one_hot(labels, num_classes)
    pos = T * jnp.square(jnp.maximum(0.0, m_pos - L))
    neg = lam * (1 - T) * jnp.square(jnp.maximum(0.0, L - m_neg))
    return jnp.mean(jnp.sum(pos + neg, axis=-1))


def accuracy(v, labels):
    return jnp.mean((jnp.argmax(class_lengths(v), -1) == labels)
                    .astype(jnp.float32))


def param_bytes_fp32(params) -> int:
    return sum(4 * l.size for l in jax.tree_util.tree_leaves(params))
