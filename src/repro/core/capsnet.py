"""CapsNet with dynamic routing — float reference (paper Table 1 configs).

Geometry check against the paper (exact): with VALID padding,
  MNIST    28x28x1: conv16 k7 s1 -> 22x22; pcap k7 s2 -> 8x8x(16x4)
           -> 1024 input capsules  => caps layer 10x1024x6x4   (Table 7 "L")
           => 297.1k params = 1187.20 KB fp32                  (Table 2)
  smallNORB 32x32x2 (resized, as the paper's table sizes imply): conv32 k7
           -> 26x26; pcap k7 s2 -> 10x10 -> 1600 caps => 5x1600x6x4 ("M")
           => 295.6k params = 1182.34 KB fp32
  CIFAR-10 32x32x3: convs 32,32,64,64 k3 s1,1,2,2 -> 6x6; pcap k3 s2 ->
           2x2 -> 64 caps => 10x64x5x4 ("S") => 115.3k = 461.19 KB fp32
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.routing import dynamic_routing, squash


@dataclasses.dataclass(frozen=True)
class CapsNetConfig:
    name: str
    input_shape: tuple                     # (H, W, C)
    conv_filters: tuple                    # e.g. (16,) or (32,32,64,64)
    conv_kernels: tuple
    conv_strides: tuple
    pcap_caps: int = 16
    pcap_dim: int = 4
    pcap_kernel: int = 7
    pcap_stride: int = 2
    num_classes: int = 10
    caps_dim: int = 6
    routings: int = 3
    lr: float = 1e-3

    @property
    def conv_out_hw(self) -> tuple:
        h, w = self.input_shape[0], self.input_shape[1]
        for k, s in zip(self.conv_kernels, self.conv_strides):
            h = (h - k) // s + 1
            w = (w - k) // s + 1
        return h, w

    @property
    def pcap_out_hw(self) -> tuple:
        h, w = self.conv_out_hw
        k, s = self.pcap_kernel, self.pcap_stride
        return (h - k) // s + 1, (w - k) // s + 1

    @property
    def num_input_caps(self) -> int:
        h, w = self.pcap_out_hw
        return h * w * self.pcap_caps


MNIST = CapsNetConfig("capsnet_mnist", (28, 28, 1), (16,), (7,), (1,),
                      num_classes=10, caps_dim=6, lr=1e-3)
SMALLNORB = CapsNetConfig("capsnet_smallnorb", (32, 32, 2), (32,), (7,), (1,),
                          num_classes=5, caps_dim=6, lr=2.5e-4)
CIFAR10 = CapsNetConfig("capsnet_cifar10", (32, 32, 3), (32, 32, 64, 64),
                        (3, 3, 3, 3), (1, 1, 2, 2), pcap_kernel=3,
                        num_classes=10, caps_dim=5, lr=2.5e-4)
CAPSNET_CONFIGS = {c.name: c for c in (MNIST, SMALLNORB, CIFAR10)}


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_capsnet(key, cfg: CapsNetConfig) -> dict:
    params = {}
    cin = cfg.input_shape[2]
    ks = jax.random.split(key, len(cfg.conv_filters) + 2)
    for i, (f, k, s) in enumerate(zip(cfg.conv_filters, cfg.conv_kernels,
                                      cfg.conv_strides)):
        fan_in = k * k * cin
        params[f"conv{i}"] = {
            "w": jax.random.normal(ks[i], (k, k, cin, f), jnp.float32)
            * (2.0 / fan_in) ** 0.5,
            "b": jnp.zeros((f,), jnp.float32),
        }
        cin = f
    k_p = cfg.pcap_kernel
    pout = cfg.pcap_caps * cfg.pcap_dim
    fan_in = k_p * k_p * cin
    params["pcap"] = {
        "w": jax.random.normal(ks[-2], (k_p, k_p, cin, pout), jnp.float32)
        * (1.0 / fan_in) ** 0.5,
        "b": jnp.zeros((pout,), jnp.float32),
    }
    params["caps"] = {
        "W": jax.random.normal(
            ks[-1], (cfg.num_classes, cfg.num_input_caps, cfg.caps_dim,
                     cfg.pcap_dim), jnp.float32) * 0.1,
    }
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _conv(x, p, stride):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + p["b"]


def primary_caps(params, x, cfg: CapsNetConfig):
    """conv -> reshape [B, N_caps, dim] -> squash (paper §3.3)."""
    y = _conv(x, params["pcap"], cfg.pcap_stride)
    B = y.shape[0]
    u = y.reshape(B, -1, cfg.pcap_dim)      # [B, h*w*caps, dim]
    return squash(u, axis=-1)


def capsnet_forward(params, x, cfg: CapsNetConfig, *, with_trace=False):
    """x [B,H,W,C] float in [0,1] -> class capsule vectors [B, J, O].

    with_trace: also return intermediate activations (for PTQ calibration).
    """
    trace = {"input": x}
    h = x
    for i, s in enumerate(cfg.conv_strides):
        h = _conv(h, params[f"conv{i}"], s)
        trace[f"conv{i}_out"] = h
        h = jax.nn.relu(h)
    y = _conv(h, params["pcap"], cfg.pcap_stride)
    trace["pcap_out"] = y
    u = squash(y.reshape(y.shape[0], -1, cfg.pcap_dim), axis=-1)
    trace["pcap_squashed"] = u

    W = params["caps"]["W"]
    u_hat = jnp.einsum("jiod,bid->bjio", W, u)
    trace["u_hat"] = u_hat

    # routing with per-iteration traces (PTQ needs per-iteration formats)
    B, J, I, O = u_hat.shape
    b = jnp.zeros((B, J, I), jnp.float32)
    v = None
    for r in range(cfg.routings):
        c = jax.nn.softmax(b, axis=1)
        s = jnp.einsum("bji,bjio->bjo", c, u_hat)
        trace[f"s_iter{r}"] = s
        v = squash(s, axis=-1)
        if r < cfg.routings - 1:
            a = jnp.einsum("bjio,bjo->bji", u_hat, v)
            trace[f"agree_iter{r}"] = a
            b = b + a
            trace[f"logits_iter{r}"] = b
    if with_trace:
        return v, trace
    return v


def class_lengths(v):
    return jnp.sqrt(jnp.sum(jnp.square(v), axis=-1) + 1e-9)


def margin_loss(v, labels, num_classes: int,
                m_pos=0.9, m_neg=0.1, lam=0.5):
    """Sabour et al. margin loss."""
    L = class_lengths(v)                              # [B, J]
    T = jax.nn.one_hot(labels, num_classes)
    pos = T * jnp.square(jnp.maximum(0.0, m_pos - L))
    neg = lam * (1 - T) * jnp.square(jnp.maximum(0.0, L - m_neg))
    return jnp.mean(jnp.sum(pos + neg, axis=-1))


def accuracy(v, labels):
    return jnp.mean((jnp.argmax(class_lengths(v), -1) == labels)
                    .astype(jnp.float32))


def param_bytes_fp32(params) -> int:
    return sum(4 * l.size for l in jax.tree_util.tree_leaves(params))
