"""Quantized (int8) CapsNet inference — compatibility shim over repro.nn.

The integer execution now lives in the typed layer API (`repro.nn`): each
layer runs `fwd_q7(qweights, plan, x)` against a selectable op backend
(the jnp oracle or the Pallas kernels).  This module keeps the paper-era
surface — `QCapsNet` with its string-keyed shift table, `pcap_q7`,
`capsule_layer_q7` (Alg. 5), `qcapsnet_forward` — translating the shift
table into typed plans at the boundary.

The softmax variant is a proper field now (`QCapsNet.softmax_impl`,
carried into RoutingPlan.softmax_impl) — the old import-time monkey-patch
of a `softmax` method onto the dataclass is gone.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.capsnet import CapsNetConfig, pipeline
from repro.nn import compat
from repro.nn.variants import REGISTRY as _VARIANTS


@dataclasses.dataclass
class QCapsNet:
    """Quantized model: int8 weights + the shift table from PTQ (Alg. 6).

    Legacy container — new code should hold a repro.nn QuantCapsNet.
    """
    cfg: CapsNetConfig
    weights: dict          # int8 arrays (+ int bias)
    shifts: dict           # name -> int shift amounts / frac-bit counts
    rounding: str = "floor"   # paper/CMSIS semantics; "nearest" = option
    # softmax variant reference (repro.nn.variants; plan field, not a
    # patch) — defaulted FROM the registry so this shim cannot drift
    softmax_impl: str = _VARIANTS.default("softmax")
    backend: str = "jnp"      # "jnp" oracle | "pallas" kernels

    def memory_bytes(self) -> int:
        n = sum(l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(self.weights))
        n += 4 * len(jax.tree_util.tree_leaves(self.shifts))  # int32 shifts
        return int(n)


def pcap_q7(model: QCapsNet, x_q):
    """Primary capsule layer (conv + reshape + squash_q7), paper §3.3.

    The pcap_q7_basic/fast split of the paper is a Cortex-M register-
    blocking concern; on TPU both map to the same int8 conv.
    """
    layer = pipeline(model.cfg).layer("pcap")
    plan = compat.pcap_plan_from_shifts(model.shifts)
    return layer.fwd_q7(model.weights["pcap"], plan, x_q,
                        backend=model.backend, rounding=model.rounding)


def capsule_layer_q7(model: QCapsNet, u_q):
    """Alg. 5.  u_q int8 [B, I, D_in] (Q0.7 post-squash) -> v int8 [B,J,O]."""
    layer = pipeline(model.cfg).layer("caps")
    plan = compat.routing_plan_from_shifts(
        model.shifts, model.cfg.routings, model.softmax_impl)
    return layer.fwd_q7(model.weights["caps"], plan, u_q,
                        backend=model.backend, rounding=model.rounding)


def qcapsnet_forward(model: QCapsNet, x_q):
    """Full quantized inference: x_q int8 image (Q0.7) -> v int8 [B,J,O]."""
    pipe = pipeline(model.cfg)
    plan = compat.shifts_to_plan(
        model.shifts, len(model.cfg.conv_filters), model.cfg.routings,
        model.softmax_impl)
    return pipe.forward_q7(model.weights, plan, x_q,
                           backend=model.backend, rounding=model.rounding)


def qclass_lengths(model: QCapsNet, v_q):
    """Class probabilities ~ vector lengths of int8 capsules (Q0.7)."""
    v32 = v_q.astype(jnp.int32)
    return jnp.sqrt(jnp.sum(v32 * v32, axis=-1).astype(jnp.float32)) / 128.0
