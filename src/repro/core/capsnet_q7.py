"""Quantized (int8) CapsNet inference pass — mirrors the paper's kernels.

The structure follows Alg. 5 exactly:
  capsule_layer_q7 = calc_inputs_hat -> r x ( calc_coupling_coefs ->
                     calc_caps_output -> calc_agreement_w_prev_caps )
with int8 operands, int32 accumulators, power-of-two shifts.  All integer
semantics come from repro.quant.int8_ops (the jnp oracles the Pallas
kernels are validated against).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.capsnet import CapsNetConfig
from repro.quant import int8_ops as q


@dataclasses.dataclass
class QCapsNet:
    """Quantized model: int8 weights + the shift table from PTQ (Alg. 6)."""
    cfg: CapsNetConfig
    weights: dict          # int8 arrays (+ int bias)
    shifts: dict           # name -> int shift amounts / frac-bit counts
    rounding: str = "floor"   # paper/CMSIS semantics; "nearest" = option

    def memory_bytes(self) -> int:
        n = sum(l.size for l in jax.tree_util.tree_leaves(self.weights))
        n += 4 * len(jax.tree_util.tree_leaves(self.shifts))  # int32 shifts
        return int(n)


def pcap_q7(model: QCapsNet, x_q):
    """Primary capsule layer (conv + reshape + squash_q7), paper §3.3.

    The pcap_q7_basic/fast split of the paper is a Cortex-M register-
    blocking concern; on TPU both map to the same int8 conv.
    """
    cfg, w, s = model.cfg, model.weights, model.shifts
    y = q.conv2d_q7(x_q, w["pcap"]["w"], w["pcap"]["b"],
                    s["pcap_out_shift"], s["pcap_bias_shift"],
                    stride=cfg.pcap_stride, rounding=model.rounding)
    u = y.reshape(y.shape[0], -1, cfg.pcap_dim)
    return q.squash_q7(u, in_frac=s["pcap_out_frac"], out_frac=7)


def capsule_layer_q7(model: QCapsNet, u_q):
    """Alg. 5.  u_q int8 [B, I, D_in] (Q0.7 post-squash) -> v int8 [B,J,O]."""
    cfg, w, s = model.cfg, model.weights, model.shifts
    W = w["caps"]["W"]                                 # int8 [J, I, O, D]

    # calc_inputs_hat: batched per-(j,i) matmul, int32 accum, one shift
    acc = jnp.einsum("jiod,bid->bjio", W.astype(jnp.int32),
                     u_q.astype(jnp.int32))
    u_hat = q.rshift_sat8(acc, s["uhat_shift"], model.rounding)

    B, J, I, O = u_hat.shape
    b = jnp.zeros((B, J, I), jnp.int8)                 # logits (int8, paper)
    v = None
    for r in range(cfg.routings):
        # calc_coupling_coefs: softmax over output capsules -> Q0.7
        c = model.softmax(b.swapaxes(1, 2), in_frac=s["logit_frac"]) \
            .swapaxes(1, 2)                             # softmax over J
        # calc_caps_output: sum_i c_ij * u_hat  (int32 accum, shift, squash)
        acc = jnp.einsum("bji,bjio->bjo", c.astype(jnp.int32),
                         u_hat.astype(jnp.int32))
        s_q = q.rshift_sat8(acc, s[f"caps_out_shift_{r}"], model.rounding)
        v = q.squash_q7(s_q, in_frac=s[f"caps_out_frac_{r}"], out_frac=7)
        if r < cfg.routings - 1:
            # calc_agreement_w_prev_caps: <u_hat, v> then saturating add
            acc = jnp.einsum("bjio,bjo->bji", u_hat.astype(jnp.int32),
                             v.astype(jnp.int32))
            a = q.rshift_sat8(acc, s[f"agree_shift_{r}"], model.rounding)
            b = q.add_q7(b, a)                          # int8 saturating add
    return v


# bind softmax implementation onto the dataclass (configurable variant)
def _softmax(self, x, in_frac):
    if getattr(self, "softmax_impl", "q7") == "precise":
        return q.softmax_q7_precise(x, in_frac)
    return q.softmax_q7(x, in_frac)


QCapsNet.softmax = _softmax


def qcapsnet_forward(model: QCapsNet, x_q):
    """Full quantized inference: x_q int8 image (Q0.7) -> v int8 [B,J,O]."""
    cfg, w, s = model.cfg, model.weights, model.shifts
    h = x_q
    for i in range(len(cfg.conv_filters)):
        h = q.conv2d_q7(h, w[f"conv{i}"]["w"], w[f"conv{i}"]["b"],
                        s[f"conv{i}_out_shift"], s[f"conv{i}_bias_shift"],
                        stride=cfg.conv_strides[i], rounding=model.rounding)
        h = q.relu_q7(h)
    u = pcap_q7(model, h)
    return capsule_layer_q7(model, u)


def qclass_lengths(model: QCapsNet, v_q):
    """Class probabilities ~ vector lengths of int8 capsules (Q0.7)."""
    v32 = v_q.astype(jnp.int32)
    return jnp.sqrt(jnp.sum(v32 * v32, axis=-1).astype(jnp.float32)) / 128.0
