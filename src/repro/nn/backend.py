"""Selectable int8 op backends for the quantized execution path.

`jnp`    — the pure-jnp oracle semantics from repro.quant.int8_ops: the
           bit-exact reference every other backend must reproduce.
           Operator variants (softmax/squash, see repro.nn.variants) are
           resolved through the variant registry, never by string
           comparison here.
`pallas` — the TPU kernels from repro.kernels: Pallas squash and the
           FUSED routing kernel (u_hat resident in VMEM, DESIGN §7).
           The fused kernels implement only the default ("q7" softmax,
           "exact" squash, Q0.7 output) plan; any other variant falls
           back to the oracle loop — bit-identically, but observably:
           every fallback decision increments `PallasBackend.fallbacks`
           and warns once per (op, variant) (no more silent degradation;
           the serving registry adds the per-model warning).

Both backends are bit-identical on every plan — the fused kernel is a
perf change, not a semantics change (tests/test_kernels.py).
"""
from __future__ import annotations

import warnings

import jax.numpy as jnp

from repro.nn.variants import REGISTRY
from repro.obs import METRICS, MetricsRegistry
from repro.quant import int8_ops as q


class JnpBackend:
    """Oracle backend: exact paper/CMSIS integer semantics in plain jnp."""

    name = "jnp"

    def conv2d_q7(self, x, w, b, out_shift, bias_shift, *, stride, rounding):
        return q.conv2d_q7(x, w, b, out_shift, bias_shift,
                           stride=stride, rounding=rounding)

    def conv2d_q7_per_channel(self, x, w, b, out_shifts, bias_shifts, *,
                              stride, rounding):
        """Per-output-channel requantization (ConvPlan.per_channel).  The
        conv itself is the same XLA int8 conv on every backend; only the
        shift step becomes a table lookup, so Pallas inherits this."""
        return q.conv2d_q7_per_channel(x, w, b, out_shifts, bias_shifts,
                                       stride=stride, rounding=rounding)

    def relu_q7(self, x):
        return q.relu_q7(x)

    def squash_q7(self, s, *, in_frac, out_frac=7, impl=None):
        impl = impl or REGISTRY.default("squash")
        return REGISTRY.get("squash", impl).q7(s, in_frac=in_frac,
                                               out_frac=out_frac)

    def softmax_q7(self, x, *, in_frac, impl=None):
        impl = impl or REGISTRY.default("softmax")
        return REGISTRY.get("softmax", impl).q7(x, in_frac)

    def uhat_q7(self, W, u, *, shift, rounding):
        """calc_inputs_hat: W int8 [J,I,O,D] x u int8 [B,I,D] -> int8
        u_hat [B,J,I,O] (int32 accumulation, one shift).  `shift` is
        either a scalar (per-tensor W format) or a length-J sequence
        (RoutingPlan.uhat_shift_per_out), applied per output capsule."""
        acc = jnp.einsum("jiod,bid->bjio", W.astype(jnp.int32),
                         u.astype(jnp.int32))
        if isinstance(shift, (tuple, list)):
            shifts = jnp.asarray(shift, jnp.int32)[None, :, None, None]
            return q.rshift_sat8_vec(acc, shifts, rounding)
        return q.rshift_sat8(acc, shift, rounding)

    def routing_q7(self, u_hat, plan, *, rounding):
        """Alg. 5's r-iteration loop over an already-computed u_hat."""
        b = jnp.zeros(u_hat.shape[:3], jnp.int8)
        v = None
        for r in range(plan.routings):
            c = self.softmax_q7(b.swapaxes(1, 2), in_frac=plan.logit_frac,
                                impl=plan.softmax_impl).swapaxes(1, 2)
            acc = jnp.einsum("bji,bjio->bjo", c.astype(jnp.int32),
                             u_hat.astype(jnp.int32))
            s_q = q.rshift_sat8(acc, plan.caps_out_shifts[r], rounding)
            v = self.squash_q7(s_q, in_frac=plan.caps_out_fracs[r],
                               out_frac=plan.out_frac,
                               impl=plan.squash_impl)
            if r < plan.routings - 1:
                acc = jnp.einsum("bjio,bjo->bji", u_hat.astype(jnp.int32),
                                 v.astype(jnp.int32))
                # agree_shifts were derived for a Q0.7 squash output
                # (layers.py); compensate when the plan's squash_out_frac
                # has been edited so logits keep their Q(f_logit) format
                a = q.rshift_sat8(
                    acc, plan.agree_shifts[r] + plan.out_frac - 7, rounding)
                b = q.add_q7(b, a)
        return v


# the fallback target for PallasBackend: a plain oracle instance, so a
# routing-level fallback runs the WHOLE loop on oracle ops and records
# exactly one counter entry per fallback decision (re-entering the
# pallas squash_q7 from inside the oracle loop would double-count)
_JNP_ORACLE = JnpBackend()


class PallasBackend(JnpBackend):
    """TPU-kernel backend (interpret mode on CPU): Pallas squash + the
    fused routing kernel.  Convs stay on the XLA int8 conv (the MXU path
    the paper's pcap maps to; there is no bespoke conv kernel)."""

    name = "pallas"

    def __init__(self, metrics: MetricsRegistry | None = None):
        # fallback DECISIONS (one per trace / direct call, not per
        # served image) are counted in a metrics registry, labeled
        # (op, variant).  A bare PallasBackend() gets a private registry
        # (fresh counters, the semantics the old ad-hoc Counter had);
        # the shared BACKENDS["pallas"] singleton records into the
        # process-default obs.METRICS so one snapshot sees it.
        self.metrics = MetricsRegistry("pallas") if metrics is None \
            else metrics
        self._fallback_counter = self.metrics.counter(
            "pallas.fallback_decisions",
            help="pallas->jnp-oracle fallback decisions by (op, variant)")
        self._warned: set = set()

    @property
    def fallbacks(self):
        """Counter-compatible view keyed by (op, variant) — the
        pre-registry attribute, preserved (tests/test_variants.py)."""
        return self._fallback_counter.view("op", "variant")

    def _fallback(self, op: str, variant: str):
        self._fallback_counter.inc(op=op, variant=variant)
        if (op, variant) not in self._warned:
            self._warned.add((op, variant))
            warnings.warn(
                f"pallas backend has no fused {op} kernel for variant "
                f"{variant!r}; falling back to the jnp oracle "
                "(bit-identical, slower)", RuntimeWarning, stacklevel=3)

    def squash_q7(self, s, *, in_frac, out_frac=7, impl=None):
        impl = impl or REGISTRY.default("squash")
        if impl != REGISTRY.default("squash"):
            self._fallback("squash", impl)
            return super().squash_q7(s, in_frac=in_frac, out_frac=out_frac,
                                     impl=impl)
        from repro.kernels import ops as kops
        return kops.squash_q7(s, in_frac=in_frac, out_frac=out_frac)

    def routing_q7(self, u_hat, plan, *, rounding):
        # the fused kernel implements only the default variants and the
        # Q0.7 squash output; other plans take the oracle loop
        if plan.softmax_impl != REGISTRY.default("softmax"):
            self._fallback("routing.softmax", plan.softmax_impl)
            return _JNP_ORACLE.routing_q7(u_hat, plan, rounding=rounding)
        if plan.squash_impl != REGISTRY.default("squash"):
            self._fallback("routing.squash", plan.squash_impl)
            return _JNP_ORACLE.routing_q7(u_hat, plan, rounding=rounding)
        if plan.out_frac != 7:
            return super().routing_q7(u_hat, plan, rounding=rounding)
        from repro.kernels import ops as kops
        return kops.routing_q7(
            u_hat, num_iters=plan.routings,
            caps_out_shifts=plan.caps_out_shifts,
            caps_out_fracs=plan.caps_out_fracs,
            agree_shifts=plan.agree_shifts,
            logit_frac=plan.logit_frac, rounding=rounding)


BACKENDS = {"jnp": JnpBackend(), "pallas": PallasBackend(metrics=METRICS)}


def get_backend(backend):
    """Resolve a backend name (or pass an OpBackend-shaped object through)."""
    if isinstance(backend, str):
        try:
            return BACKENDS[backend]
        except KeyError:
            raise ValueError(
                f"unknown backend {backend!r}; have {sorted(BACKENDS)}")
    return backend
