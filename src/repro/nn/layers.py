"""Capsule-network layers implementing the three-face protocol.

Every layer is a small frozen object with one protocol (`CapsLayer`):

  fwd_f32(params, x)            -> (y, taps)   float forward; `taps` are
                                   the layer's OWN named calibration
                                   points (no global trace dict).
  plan(params, stats, in_frac)  -> LayerQuantPlan   derive the layer's
                                   Qm.n formats and shifts (Alg. 6/7).
  quantize(params, plan)        -> int8 weight dict (Alg. 7).
  fwd_q7(qweights, plan, x, *, backend, rounding) -> y   int8 execution
                                   on a selectable op backend.

`plan_tap_names()` declares exactly which stats keys `plan` reads, so the
pipeline can verify calibration completeness instead of KeyError-ing deep
inside a walk.  int8 shapes come from the data, never the config, so the
same layer objects serve ad-hoc geometries (benchmarks, kernel tests).

Layers also carry a fourth, training-only face used by `repro.captrain`:

  fwd_fq(params, plan, x, *, rounding) -> y   fake-quantized float forward
                                   (QAT): every tensor the int8 graph
                                   would quantize is snapped onto the SAME
                                   Qm.n grid the plan prescribes, with a
                                   straight-through gradient
                                   (`qformat.fake_quant`).  Weights and
                                   the softmax couplings use the nearest
                                   quantizer (like Alg. 7); activations
                                   use the net's rounding mode so floor
                                   training sees the truncation bias of
                                   the `>> shift` requantization.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.routing import squash
from repro.nn.backend import get_backend
from repro.nn.plans import ConvPlan, PrimaryCapsPlan, RoutingPlan, TapStats
from repro.nn.variants import DEFAULT_SOFTMAX, DEFAULT_SQUASH, REGISTRY
from repro.quant import qformat as qf


@runtime_checkable
class CapsLayer(Protocol):
    name: str

    def init(self, key) -> dict: ...
    def fwd_f32(self, params, x) -> tuple: ...
    def plan_tap_names(self) -> tuple: ...
    def plan(self, params, stats: TapStats, in_frac: int): ...
    def quantize(self, params, plan) -> dict: ...
    def fwd_q7(self, qweights, plan, x, *, backend="jnp",
               rounding="floor"): ...
    def fwd_fq(self, params, plan, x, *, rounding="floor"): ...


def _conv(x, w, b, stride: int):
    y = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _weight_frac(w) -> int:
    return qf.frac_bits(float(jnp.max(jnp.abs(w))))


@dataclasses.dataclass(frozen=True)
class QuantConv2D:
    """VALID-padded NHWC conv + bias (+ optional relu), int8 via one
    accumulator shift.  Taps: "out" (pre-activation)."""
    name: str
    kernel: int
    stride: int
    in_ch: int
    out_ch: int
    relu: bool = True
    init_scale_pow: float = 2.0     # he-normal: sqrt(init_scale_pow/fan_in)
    per_channel: bool = False       # per-output-channel weight formats

    def init(self, key) -> dict:
        k, fan_in = self.kernel, self.kernel * self.kernel * self.in_ch
        return {
            "w": jax.random.normal(key, (k, k, self.in_ch, self.out_ch),
                                   jnp.float32)
            * (self.init_scale_pow / fan_in) ** 0.5,
            "b": jnp.zeros((self.out_ch,), jnp.float32),
        }

    def fwd_f32(self, params, x):
        y = _conv(x, params["w"], params["b"], self.stride)
        taps = {"out": y}
        return (jax.nn.relu(y) if self.relu else y), taps

    def plan_tap_names(self) -> tuple:
        return (f"{self.name}.out",)

    def plan(self, params, stats: TapStats, in_frac: int) -> ConvPlan:
        f_w = _weight_frac(params["w"])
        f_b = _weight_frac(params["b"]) if params["b"].size else f_w
        f_out = qf.frac_bits(stats[f"{self.name}.out"])
        pc_w = pc_out = pc_bias = ()
        if self.per_channel:
            # the channel formats come from the same derivation the
            # quantizer uses (qformat.quantize_per_channel), so plan and
            # weights cannot disagree
            _, ns = qf.quantize_per_channel(params["w"], axis=-1)
            pc_w = tuple(int(n) for n in ns)
            pc_out = tuple(qf.out_shift(in_frac, f, f_out) for f in pc_w)
            pc_bias = tuple(qf.bias_shift(in_frac, f, f_b) for f in pc_w)
        return ConvPlan(
            in_frac=in_frac, w_frac=f_w, b_frac=f_b, out_frac=f_out,
            out_shift=qf.out_shift(in_frac, f_w, f_out),
            bias_shift=qf.bias_shift(in_frac, f_w, f_b),
            w_frac_per_channel=pc_w, out_shift_per_channel=pc_out,
            bias_shift_per_channel=pc_bias)

    def quantize(self, params, plan: ConvPlan) -> dict:
        if plan.per_channel:
            # quantize with the PLAN's channel formats (not a fresh
            # derivation) so plan edits stay consistent with the shifts
            # fwd_q7 will apply
            qw = qf.quantize_with_fracs(params["w"],
                                        plan.w_frac_per_channel, axis=-1)
        else:
            qw = qf.quantize(params["w"], plan.w_frac)
        return {"w": qw, "b": qf.quantize(params["b"], plan.b_frac)}

    def fwd_q7(self, qweights, plan: ConvPlan, x, *, backend="jnp",
               rounding="floor"):
        be = get_backend(backend)
        if plan.per_channel:
            y = be.conv2d_q7_per_channel(
                x, qweights["w"], qweights["b"],
                plan.out_shift_per_channel, plan.bias_shift_per_channel,
                stride=self.stride, rounding=rounding)
        else:
            y = be.conv2d_q7(x, qweights["w"], qweights["b"], plan.out_shift,
                             plan.bias_shift, stride=self.stride,
                             rounding=rounding)
        return be.relu_q7(y) if self.relu else y

    def fwd_fq(self, params, plan: ConvPlan, x, *, rounding="floor"):
        """Fake-quant forward mirroring fwd_q7's requantization points:
        weights/bias on their plan grids (nearest, like Alg. 7), the
        accumulator snapped to out_frac with the net's rounding."""
        if plan.per_channel:
            w = qf.fake_quant_with_fracs(params["w"],
                                         plan.w_frac_per_channel, axis=-1)
        else:
            w = qf.fake_quant(params["w"], plan.w_frac)
        b = qf.fake_quant(params["b"], plan.b_frac)
        y = qf.fake_quant(_conv(x, w, b, self.stride), plan.out_frac,
                          rounding)
        return jax.nn.relu(y) if self.relu else y


@dataclasses.dataclass(frozen=True)
class PrimaryCaps:
    """Primary capsules (paper §3.3): conv -> reshape [B, N_caps, dim] ->
    squash into Q0.7.  Taps: "out" (conv pre-squash), "squashed".

    The conv faces delegate to an inner QuantConv2D (no relu, 1/fan_in
    init); this layer adds only the reshape + integer squash."""
    name: str
    kernel: int
    stride: int
    in_ch: int
    caps: int
    dim: int
    per_channel: bool = False
    squash_impl: str = DEFAULT_SQUASH   # variant default carried into plan

    @property
    def out_ch(self) -> int:
        return self.caps * self.dim

    @property
    def conv(self) -> QuantConv2D:
        return QuantConv2D(self.name, self.kernel, self.stride, self.in_ch,
                           self.out_ch, relu=False, init_scale_pow=1.0,
                           per_channel=self.per_channel)

    def init(self, key) -> dict:
        return self.conv.init(key)

    def fwd_f32(self, params, x):
        y, taps = self.conv.fwd_f32(params, x)
        u = squash(y.reshape(y.shape[0], -1, self.dim), axis=-1)
        return u, {**taps, "squashed": u}

    def plan_tap_names(self) -> tuple:
        return self.conv.plan_tap_names()

    def plan(self, params, stats: TapStats, in_frac: int) -> PrimaryCapsPlan:
        return PrimaryCapsPlan(conv=self.conv.plan(params, stats, in_frac),
                               squash_impl=self.squash_impl)

    def quantize(self, params, plan: PrimaryCapsPlan) -> dict:
        return self.conv.quantize(params, plan.conv)

    def fwd_q7(self, qweights, plan: PrimaryCapsPlan, x, *, backend="jnp",
               rounding="floor"):
        y = self.conv.fwd_q7(qweights, plan.conv, x, backend=backend,
                             rounding=rounding)
        u = y.reshape(y.shape[0], -1, self.dim)
        return get_backend(backend).squash_q7(
            u, in_frac=plan.conv.out_frac, out_frac=plan.squash_out_frac,
            impl=plan.squash_impl)

    def fwd_fq(self, params, plan: PrimaryCapsPlan, x, *, rounding="floor"):
        y = self.conv.fwd_fq(params, plan.conv, x, rounding=rounding)
        u = y.reshape(y.shape[0], -1, self.dim)
        return REGISTRY.get("squash", plan.squash_impl).fq(
            u, plan.squash_out_frac, rounding)


@dataclasses.dataclass(frozen=True)
class CapsuleRouting:
    """Class capsules with dynamic routing (Alg. 5).  Taps: "u_hat",
    per-iteration "s/{r}", "agree/{r}", "logits/{r}"."""
    name: str
    num_out: int                    # J (classes)
    num_in: int                     # I (input capsules)
    out_dim: int                    # O
    in_dim: int                     # D
    routings: int = 3
    softmax_impl: str = DEFAULT_SOFTMAX   # variant defaults carried into
    squash_impl: str = DEFAULT_SQUASH     # the plan (registry-validated)
    per_channel: bool = False       # per-output-capsule W formats

    def init(self, key) -> dict:
        return {"W": jax.random.normal(
            key, (self.num_out, self.num_in, self.out_dim, self.in_dim),
            jnp.float32) * 0.1}

    def fwd_f32(self, params, u):
        W = params["W"]
        u_hat = jnp.einsum("jiod,bid->bjio", W, u)
        taps = {"u_hat": u_hat}
        b = jnp.zeros(u_hat.shape[:3], jnp.float32)
        v = None
        for r in range(self.routings):
            c = jax.nn.softmax(b, axis=1)
            s = jnp.einsum("bji,bjio->bjo", c, u_hat)
            taps[f"s/{r}"] = s
            v = squash(s, axis=-1)
            if r < self.routings - 1:
                a = jnp.einsum("bjio,bjo->bji", u_hat, v)
                taps[f"agree/{r}"] = a
                b = b + a
                taps[f"logits/{r}"] = b
        return v, taps

    def plan_tap_names(self) -> tuple:
        names = [f"{self.name}.u_hat"]
        names += [f"{self.name}.s/{r}" for r in range(self.routings)]
        names += [f"{self.name}.logits/{r}"
                  for r in range(self.routings - 1)]
        return tuple(names)

    def plan(self, params, stats: TapStats, in_frac: int) -> RoutingPlan:
        fb = qf.frac_bits
        f_W = _weight_frac(params["W"])
        f_uhat = fb(stats[f"{self.name}.u_hat"])
        # logit format is shared across iterations (b accumulates
        # agreements), capped at the Q0.7 barrier
        max_logit = max([stats.get(f"{self.name}.logits/{r}")
                         for r in range(self.routings - 1)] + [1e-6])
        f_logit = min(fb(max_logit), 7)
        f_s = tuple(fb(stats[f"{self.name}.s/{r}"])
                    for r in range(self.routings))
        pc_W = pc_shift = ()
        if self.per_channel:
            # per-output-capsule formats from the same derivation the
            # quantizer uses (axis 0 = the J output capsules), so plan
            # and weights cannot disagree
            _, ns = qf.quantize_per_channel(params["W"], axis=0)
            pc_W = tuple(int(n) for n in ns)
            pc_shift = tuple(qf.out_shift(in_frac, f, f_uhat)
                             for f in pc_W)
        return RoutingPlan(
            uhat_shift=qf.out_shift(in_frac, f_W, f_uhat),
            logit_frac=f_logit,
            caps_out_shifts=tuple(qf.out_shift(f_uhat, 7, f)
                                  for f in f_s),
            caps_out_fracs=f_s,
            agree_shifts=tuple(qf.out_shift(f_uhat, 7, f_logit)
                               for _ in range(self.routings - 1)),
            softmax_impl=self.softmax_impl, squash_impl=self.squash_impl,
            in_frac=in_frac, W_frac=f_W, uhat_frac=f_uhat,
            W_frac_per_out=pc_W, uhat_shift_per_out=pc_shift)

    def quantize(self, params, plan: RoutingPlan) -> dict:
        if plan.per_out:
            # quantize with the PLAN's per-capsule formats (like the
            # conv's per-channel path) so plan edits stay consistent
            # with the shifts fwd_q7 will apply
            return {"W": qf.quantize_with_fracs(params["W"],
                                                plan.W_frac_per_out,
                                                axis=0)}
        return {"W": qf.quantize(params["W"], plan.W_frac)}

    def fwd_q7(self, qweights, plan: RoutingPlan, u, *, backend="jnp",
               rounding="floor"):
        be = get_backend(backend)
        shift = plan.uhat_shift_per_out if plan.per_out \
            else plan.uhat_shift
        u_hat = be.uhat_q7(qweights["W"], u, shift=shift,
                           rounding=rounding)
        return be.routing_q7(u_hat, plan, rounding=rounding)

    @staticmethod
    def _softmax_fq(b, impl: str):
        """Couplings in Q0.7 the way the int8 graph computes them — the
        registered variant's fake-quant face (repro.nn.variants): the
        variant's forward approximation with the float softmax as the
        straight-through gradient surrogate.  Kept as a method so QAT
        code can probe one softmax face in isolation."""
        return REGISTRY.get("softmax", impl).fq(b)

    def fwd_fq(self, params, plan: RoutingPlan, u, *, rounding="floor"):
        """Fake-quant routing: u_hat, couplings, per-iteration s/v and
        the accumulated logits all snap to the grids routing_q7 uses
        (couplings and squash via the plan's variant references, like
        the backends; the logit clamp models add_q7's int8 saturation)."""
        sq = REGISTRY.get("squash", plan.squash_impl)
        if plan.per_out:
            W = qf.fake_quant_with_fracs(params["W"],
                                         plan.W_frac_per_out, axis=0)
        else:
            W = qf.fake_quant(params["W"], plan.W_frac)
        u_hat = qf.fake_quant(jnp.einsum("jiod,bid->bjio", W, u),
                              plan.uhat_frac, rounding)
        b = jnp.zeros(u_hat.shape[:3], jnp.float32)
        v = None
        for r in range(self.routings):
            c = self._softmax_fq(b, plan.softmax_impl)
            s = qf.fake_quant(jnp.einsum("bji,bjio->bjo", c, u_hat),
                              plan.caps_out_fracs[r], rounding)
            v = sq.fq(s, plan.squash_out_frac, rounding)
            if r < self.routings - 1:
                a = qf.fake_quant(jnp.einsum("bjio,bjo->bji", u_hat, v),
                                  plan.logit_frac, rounding)
                b = qf.fake_quant(b + a, plan.logit_frac, rounding)
        return v
