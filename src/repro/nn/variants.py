"""First-class operator variants: a typed registry spanning PTQ -> QAT ->
serving -> edge.

The paper's edge story hinges on swapping capsule operators for cheaper
integer variants, and the ISLPED'22 follow-up ("Enabling Capsule Networks
at the Edge through Approximate Softmax and Squash Operations") makes the
softmax/squash choice the next latency lever.  Before this module that
choice was a bare ``softmax_impl: str`` hand-copied through ~10 call
sites; now a variant is ONE registration here and every consumer — the
jnp/pallas backends, the fake-quant QAT face, ``edge.lower``/``EdgeVM``/
``emit_c``, the serving registry, both CLIs — resolves it through the
same `VariantRegistry`.

An `OpVariant` carries every face one operator variant needs:

  q7      jnp int8 oracle (the semantics `fwd_q7` executes; bit-exact
          contract with `np_q7`)
  np_q7   pure-NumPy mirror (what `EdgeVM` runs — and what the MCU
          kernels must reproduce)
  fq      fake-quant face (QAT trains against the variant's forward with
          a straight-through gradient; see `CapsLayer.fwd_fq`)
  f32     plain float math of the variant (A/B studies; the pipeline's
          `fwd_f32` calibration reference intentionally stays the exact
          float model)

plus the plan-field schema (`plan_field` — which typed-plan field carries
the reference) and the C-emitter lowering attrs (`c_symbol`, `c_suffix`).
Plan fields remain plain strings — JSON- and ``.capsbin``-safe by
construction — but they are now *validated references*: the plan
dataclasses, `plan_from_json`, the ``.capsbin`` importer, and the CLIs
all reject unknown names with the registered ones listed.

Registered variants:

  softmax  "q7"       arm_softmax-style shift softmax (paper baseline)
           "precise"  dequantize -> fp32 softmax -> requant (beyond-paper)
           "approx"   ISLPED'22: powers-of-two probabilities with a
                      power-of-two normalizer — the per-element integer
                      division becomes one arithmetic shift
  squash   "exact"    Eq. 8 with Alg. 4 Newton-Raphson integer sqrt
           "approx"   ISLPED'22: the L2 norm is replaced by the L-inf
                      norm max|s_i| — no square root at all

`VariantSet` is the pipeline-level selection (one softmax + one squash)
that attaches to a `PipelinePlan`: build with
``CapsPipeline.from_config(cfg, variants=VariantSet(...))``, edit a
quantized model with ``QuantCapsNet.with_variants`` (a pure plan edit),
and read it back from any plan via ``PipelinePlan.variants``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

_INT8_MIN, _INT8_MAX = -128, 127
_SQUASH_GUARD_BITS = 10             # must match quant.int8_ops
_EXP_FLOOR = -20                    # exponent clamp shared by softmaxes


# ---------------------------------------------------------------------------
# NumPy faces (the EdgeVM semantics; no jax anywhere in this block)
# ---------------------------------------------------------------------------
def _np_sat8(x):
    return np.clip(x, _INT8_MIN, _INT8_MAX).astype(np.int8)


def _np_ceil_log2(tot):
    """ceil(log2(tot)) for positive int32 arrays, integer-only (bit
    length of tot-1) so jnp and NumPy cannot disagree on boundaries."""
    t1 = tot.astype(np.int32) - 1
    k = np.zeros_like(t1)
    for j in range(31):
        k = k + (np.right_shift(t1, j) > 0)
    return k


def _np_softmax_q7(x, in_frac: int):
    x32 = x.astype(np.int32)
    m = np.max(x32, axis=-1, keepdims=True)
    e = np.maximum(np.right_shift(x32 - m, in_frac), _EXP_FLOOR)
    p = np.left_shift(np.ones_like(e), 20 + e)
    tot = np.sum(p, axis=-1, keepdims=True, dtype=np.int32)
    c = np.left_shift(p, 7) // np.maximum(tot, 1)
    return np.clip(c, 0, _INT8_MAX).astype(np.int8)


def _np_softmax_q7_precise(x, in_frac: int):
    xf = x.astype(np.float32) * np.float32(2.0 ** -in_frac)
    xf = xf - xf.max(axis=-1, keepdims=True)
    p = np.exp(xf)
    p = p / p.sum(axis=-1, keepdims=True)
    c = np.round(p.astype(np.float32) * 128.0)
    return np.clip(c, 0, _INT8_MAX).astype(np.int8)


def _np_softmax_q7_approx(x, in_frac: int):
    """ISLPED'22 shift softmax: 2^floor(x-max) probabilities normalized
    by 2^ceil(log2(sum)) — division-free (one shift per element)."""
    x32 = x.astype(np.int32)
    m = np.max(x32, axis=-1, keepdims=True)
    e = np.maximum(np.right_shift(x32 - m, in_frac), _EXP_FLOOR)
    p = np.left_shift(np.ones_like(e), 20 + e)
    tot = np.sum(p, axis=-1, keepdims=True, dtype=np.int32)
    k = _np_ceil_log2(tot)                   # >= 20: the max term is 2^20
    c = np.right_shift(p, k - 7)
    return np.clip(c, 0, _INT8_MAX).astype(np.int8)


def _np_isqrt_newton(n):
    n = n.astype(np.int32)
    x = np.maximum(n // 2, 1)
    for _ in range(32):
        nxt = (x + n // np.maximum(x, 1)) // 2
        x = np.where(nxt < x, nxt, x)
    return np.where(n <= 1, n, x)


def _np_squash_factor(S, Q, in_frac: int, out_frac: int):
    """Eq. 8 ratio on a (norm, norm^2) pair; shared by both variants."""
    P = _SQUASH_GUARD_BITS
    shift = out_frac - in_frac + P
    num = np.left_shift(S, shift) if shift >= 0 \
        else np.right_shift(S, -shift)
    den = (1 << in_frac) + np.right_shift(Q, in_frac)
    return num // np.maximum(den, 1)


def _np_squash_q7(s, in_frac: int, out_frac: int = 7):
    s32 = s.astype(np.int32)
    Q = np.sum(s32 * s32, axis=-1, keepdims=True, dtype=np.int32)
    ratio = _np_squash_factor(_np_isqrt_newton(Q), Q, in_frac, out_frac)
    return _np_sat8(np.right_shift(ratio * s32, _SQUASH_GUARD_BITS))


def _np_squash_q7_approx(s, in_frac: int, out_frac: int = 7):
    """ISLPED'22 approximate squash: the L2 norm (32-iteration Newton
    isqrt, Alg. 4) is replaced by the L-inf norm max|s_i| — no sqrt."""
    s32 = s.astype(np.int32)
    M = np.max(np.abs(s32), axis=-1, keepdims=True)
    ratio = _np_squash_factor(M, M * M, in_frac, out_frac)
    return _np_sat8(np.right_shift(ratio * s32, _SQUASH_GUARD_BITS))


# ---------------------------------------------------------------------------
# jnp faces (int8 oracle + fake-quant; jax imported lazily so importing
# the registry never forces it)
# ---------------------------------------------------------------------------
def _q7_softmax(x, in_frac: int):
    from repro.quant import int8_ops as q
    return q.softmax_q7(x, in_frac)


def _q7_softmax_precise(x, in_frac: int):
    from repro.quant import int8_ops as q
    return q.softmax_q7_precise(x, in_frac)


def _q7_softmax_approx(x, in_frac: int):
    from repro.quant import int8_ops as q
    return q.softmax_q7_approx(x, in_frac)


def _q7_squash(s, in_frac: int, out_frac: int = 7):
    from repro.quant import int8_ops as q
    return q.squash_q7(s, in_frac=in_frac, out_frac=out_frac)


def _q7_squash_approx(s, in_frac: int, out_frac: int = 7):
    from repro.quant import int8_ops as q
    return q.squash_q7_approx(s, in_frac=in_frac, out_frac=out_frac)


def _f32_softmax(b, axis: int = -1):
    import jax
    return jax.nn.softmax(b, axis=axis)


def _f32_softmax_approx(b, axis: int = -1):
    """Float math of the shift softmax (dequantized semantics)."""
    import jax.numpy as jnp
    e = jnp.maximum(jnp.floor(b - jnp.max(b, axis=axis, keepdims=True)),
                    float(_EXP_FLOOR))
    p = jnp.exp2(e)
    t = jnp.sum(p, axis=axis, keepdims=True)
    return p * jnp.exp2(-_f32_ceil_log2(t))


def _f32_ceil_log2(t):
    """ceil(log2(t)) on floats by counting powers of two strictly below
    t (t in [2^-20, 2^30)).  Used by the FLOAT face only: exact for the
    value `t` it is handed, but a float32 normalizer sum can itself
    round across a power-of-two boundary — the fake-quant face therefore
    mirrors the integer op's int32 sum + `ceil_log2_int` instead."""
    import jax.numpy as jnp
    K = jnp.full_like(t, float(_EXP_FLOOR - 1))
    for j in range(_EXP_FLOOR - 1, 31):
        K = K + (t > 2.0 ** j)
    return K


def _f32_squash(s):
    from repro.core.routing import squash
    return squash(s, axis=-1)


def _f32_squash_approx(s):
    import jax.numpy as jnp
    M = jnp.max(jnp.abs(s), axis=-1, keepdims=True)
    return s * M / (1.0 + M * M)


# fake-quant faces.  Softmax fq takes the routing logits [B, J, I] and
# returns couplings over axis=1 (the convention of the routing loop's
# QAT face); the float softmax is always the straight-through surrogate.
def _fq_softmax_q7(b):
    import jax
    import jax.numpy as jnp
    sm = jax.nn.softmax(b, axis=1)
    e = jnp.maximum(jnp.floor(b - jnp.max(b, axis=1, keepdims=True)),
                    float(_EXP_FLOOR))
    p = jnp.exp2(e)
    c = jnp.clip(jnp.floor(p * 128.0 / jnp.sum(p, axis=1, keepdims=True)),
                 0.0, 127.0) / 128.0
    return sm + jax.lax.stop_gradient(c - sm)


def _fq_softmax_precise(b):
    import jax
    from repro.quant import qformat as qf
    return qf.fake_quant(jax.nn.softmax(b, axis=1), 7)


def _fq_softmax_approx(b):
    import jax
    import jax.numpy as jnp
    from repro.quant.int8_ops import ceil_log2_int
    sm = jax.nn.softmax(b, axis=1)
    e = jnp.maximum(jnp.floor(b - jnp.max(b, axis=1, keepdims=True)),
                    float(_EXP_FLOOR))
    # the normalizer exponent must be computed EXACTLY like the integer
    # op's (sum of int32 powers of two + integer ceil-log2): a float32
    # sum of exp2(e) loses the tail once >=16 logits tie at the max and
    # would round K across a power-of-two boundary, silently diverging
    # from the deployed arithmetic (everything here sits behind the STE
    # stop_gradient, so integer ops are gradient-safe)
    p_int = jnp.exp2(e - float(_EXP_FLOOR)).astype(jnp.int32)
    k = ceil_log2_int(jnp.sum(p_int, axis=1, keepdims=True))
    K = (k + _EXP_FLOOR).astype(jnp.float32)
    c = jnp.clip(jnp.floor(jnp.exp2(e - K) * 128.0), 0.0, 127.0) / 128.0
    return sm + jax.lax.stop_gradient(c - sm)


# Squash fq faces: float math of the variant snapped onto the plan's
# output grid (same STE pattern the layers already used for "exact").
def _fq_squash(s, out_frac: int, rounding: str = "floor"):
    from repro.quant import qformat as qf
    return qf.fake_quant(_f32_squash(s), out_frac, rounding)


def _fq_squash_approx(s, out_frac: int, rounding: str = "floor"):
    from repro.quant import qformat as qf
    return qf.fake_quant(_f32_squash_approx(s), out_frac, rounding)


# ---------------------------------------------------------------------------
# the typed spec + registry
# ---------------------------------------------------------------------------
KINDS = ("softmax", "squash")
PLAN_FIELDS = {"softmax": "softmax_impl", "squash": "squash_impl"}


@dataclasses.dataclass(frozen=True)
class OpVariant:
    """One operator variant: every face + the lowering attrs it needs."""
    name: str                       # registry key within its kind
    kind: str                       # "softmax" | "squash"
    description: str
    q7: callable                    # jnp int8 oracle
    np_q7: callable                 # NumPy mirror (EdgeVM / MCU contract)
    fq: callable                    # fake-quant (QAT) face
    f32: callable                   # plain float math of the variant
    c_symbol: str                   # standalone kernel symbol (emit_c)
    c_suffix: str = ""              # routing-kernel symbol suffix

    @property
    def plan_field(self) -> str:
        return PLAN_FIELDS[self.kind]


class VariantRegistry:
    """(kind, name) -> OpVariant, with one default per kind.

    The registry is the single authority on what variant names mean:
    plans validate against it at construction, the backends and the
    EdgeVM resolve implementations through it, and the CLIs list its
    names in their --softmax/--squash choices.
    """

    def __init__(self):
        self._variants: dict = {}
        self._defaults: dict = {}

    def register(self, v: OpVariant, *, default: bool = False) -> OpVariant:
        if v.kind not in KINDS:
            raise ValueError(f"unknown op kind {v.kind!r}; have {KINDS}")
        key = (v.kind, v.name)
        if key in self._variants:
            raise ValueError(f"variant {v.kind}:{v.name} already registered")
        self._variants[key] = v
        if default:
            self._defaults[v.kind] = v.name
        return v

    def get(self, kind: str, name: str) -> OpVariant:
        try:
            return self._variants[(kind, name)]
        except KeyError:
            raise ValueError(
                f"unknown {kind} variant {name!r}; registered: "
                f"{', '.join(self.names(kind)) or '(none)'}") from None

    def names(self, kind: str) -> tuple:
        return tuple(sorted(n for k, n in self._variants if k == kind))

    def default(self, kind: str) -> str:
        return self._defaults[kind]

    def validate(self, kind: str, name: str) -> str:
        """Raise (listing registered names) unless `name` is registered."""
        self.get(kind, name)
        return name

    def is_registered(self, kind: str, name: str) -> bool:
        """Non-raising membership test (the static checker reports
        unknown references as diagnostics instead of exceptions)."""
        return (kind, name) in self._variants

    def from_attrs(self, kind: str, attrs: dict) -> OpVariant:
        """Resolve an EdgeOp attr dict's variant reference (the kind's
        plan-field key), defaulting for pre-variant artifacts — THE
        accessor every edge consumer (VM, importer, C emitter) shares,
        so the defaulting rule lives in exactly one place."""
        return self.get(kind, attrs.get(PLAN_FIELDS[kind],
                                        self.default(kind)))


REGISTRY = VariantRegistry()

REGISTRY.register(OpVariant(
    name="q7", kind="softmax",
    description="arm_softmax-style shift softmax (paper baseline): "
                "powers of two of floor(x - max), integer-divided by "
                "their sum",
    q7=_q7_softmax, np_q7=_np_softmax_q7, fq=_fq_softmax_q7,
    f32=_f32_softmax, c_symbol="arm_softmax_q7"), default=True)
REGISTRY.register(OpVariant(
    name="precise", kind="softmax",
    description="dequantize -> fp32 softmax -> requant Q0.7 "
                "(beyond-paper accuracy reference)",
    q7=_q7_softmax_precise, np_q7=_np_softmax_q7_precise,
    fq=_fq_softmax_precise, f32=_f32_softmax,
    c_symbol="capsnet_softmax_q7_precise", c_suffix="_softmax_precise"))
REGISTRY.register(OpVariant(
    name="approx", kind="softmax",
    description="ISLPED'22 approximate softmax: shift-based exp with "
                "power-of-two normalization — no integer division",
    q7=_q7_softmax_approx, np_q7=_np_softmax_q7_approx,
    fq=_fq_softmax_approx, f32=_f32_softmax_approx,
    c_symbol="capsnet_softmax_q7_approx", c_suffix="_softmax_approx"))

REGISTRY.register(OpVariant(
    name="exact", kind="squash",
    description="Eq. 8 squash with Alg. 4 Newton-Raphson integer sqrt "
                "(paper baseline)",
    q7=_q7_squash, np_q7=_np_squash_q7, fq=_fq_squash,
    f32=_f32_squash, c_symbol="capsnet_squash_q7"), default=True)
REGISTRY.register(OpVariant(
    name="approx", kind="squash",
    description="ISLPED'22 approximate squash: L-inf norm instead of "
                "the L2 norm — no square root",
    q7=_q7_squash_approx, np_q7=_np_squash_q7_approx,
    fq=_fq_squash_approx, f32=_f32_squash_approx,
    c_symbol="capsnet_squash_q7_approx", c_suffix="_squash_approx"))

DEFAULT_SOFTMAX = REGISTRY.default("softmax")
DEFAULT_SQUASH = REGISTRY.default("squash")


# ---------------------------------------------------------------------------
# VariantSet — the pipeline-level selection, attached to PipelinePlan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class VariantSet:
    """One softmax + one squash choice for a whole pipeline; validated
    against the registry at construction and applied/read as plan
    edits (never a method patch)."""
    softmax: str = DEFAULT_SOFTMAX
    squash: str = DEFAULT_SQUASH

    def __post_init__(self):
        REGISTRY.validate("softmax", self.softmax)
        REGISTRY.validate("squash", self.squash)

    @property
    def tag(self) -> str:
        return f"{self.softmax}+{self.squash}"

    def is_default(self) -> bool:
        return self.softmax == DEFAULT_SOFTMAX \
            and self.squash == DEFAULT_SQUASH

    @classmethod
    def of_plan(cls, plan) -> "VariantSet":
        """Read the selection off a PipelinePlan's layer plans (they must
        agree — apply() is the only writer and keeps them uniform)."""
        sms, sqs = set(), set()
        for p in plan.layers.values():
            if hasattr(p, "softmax_impl"):
                sms.add(p.softmax_impl)
            if hasattr(p, "squash_impl"):
                sqs.add(p.squash_impl)
        if len(sms) > 1 or len(sqs) > 1:
            raise ValueError(
                f"plan mixes operator variants: softmax={sorted(sms)} "
                f"squash={sorted(sqs)}")
        return cls(softmax=sms.pop() if sms else DEFAULT_SOFTMAX,
                   squash=sqs.pop() if sqs else DEFAULT_SQUASH)

    def apply(self, plan):
        """Return a PipelinePlan with every variant-bearing layer plan
        switched to this selection (untouched plans keep identity, so a
        no-op apply is free and `is`-stable)."""
        layers = {}
        for name, p in plan.layers.items():
            kw = {}
            if hasattr(p, "softmax_impl") and p.softmax_impl != self.softmax:
                kw["softmax_impl"] = self.softmax
            if hasattr(p, "squash_impl") and p.squash_impl != self.squash:
                kw["squash_impl"] = self.squash
            layers[name] = dataclasses.replace(p, **kw) if kw else p
        return dataclasses.replace(plan, layers=layers)

    def to_json(self) -> dict:
        return {"softmax": self.softmax, "squash": self.squash}

    @classmethod
    def from_json(cls, d: dict) -> "VariantSet":
        return cls(softmax=d.get("softmax", DEFAULT_SOFTMAX),
                   squash=d.get("squash", DEFAULT_SQUASH))


def all_variant_sets() -> tuple:
    """Every (softmax, squash) combination currently registered — the
    sweep the benchmark and the bit-parity tests iterate."""
    return tuple(VariantSet(softmax=sm, squash=sq)
                 for sm in REGISTRY.names("softmax")
                 for sq in REGISTRY.names("squash"))
