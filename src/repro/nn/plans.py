"""Typed quantization plans — the per-layer replacement for the old
string-keyed shift table.

A plan is everything a layer needs to execute its int8 path: Qm.n formats
for its weights and activations plus the power-of-two shifts between them
(paper Alg. 6).  Each layer derives its own plan from its calibration taps
(`layer.plan(params, stats, in_frac)`), and the pipeline threads the
activation format from one plan's `out_frac` into the next layer's
`in_frac` — the contract the old design encoded as ~25 magic dict keys.
"""
from __future__ import annotations

import dataclasses

from repro.nn import variants as _variants


@dataclasses.dataclass(frozen=True)
class TapStats:
    """max|x| observed on the calibration set, per tap name.

    Tap names are `<layer>.<tap>` (e.g. "conv0.out", "caps.s/1") plus the
    pipeline-level "input"."""
    max_abs: dict

    def __getitem__(self, name: str) -> float:
        return self.max_abs[name]

    def get(self, name: str, default: float = 0.0) -> float:
        return self.max_abs.get(name, default)


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """int8 conv: out_shift rescales the int32 accumulator into the
    output format; bias_shift aligns the bias into the accumulator.

    Per-channel mode (opt-in, beyond-paper but still shift-only): each
    output channel c gets its own weight format `w_frac_per_channel[c]`,
    so the accumulator scale — and therefore out/bias shift — varies per
    channel.  Empty tuples mean per-tensor (the paper's scheme); the
    scalar fields always hold the per-tensor derivation so compat
    translations keep working."""
    in_frac: int
    w_frac: int
    b_frac: int
    out_frac: int
    out_shift: int
    bias_shift: int
    w_frac_per_channel: tuple = ()
    out_shift_per_channel: tuple = ()
    bias_shift_per_channel: tuple = ()

    @property
    def per_channel(self) -> bool:
        return bool(self.w_frac_per_channel)


@dataclasses.dataclass(frozen=True)
class PrimaryCapsPlan:
    """conv plan + the integer squash that lands capsules in Q0.7.

    `squash_impl` is a validated reference into the operator-variant
    registry (`repro.nn.variants`): construction rejects unknown names,
    so a plan — whether built by `plan()`, read back from QAT's JSON
    side-car, or imported from a `.capsbin` — can only ever name a
    squash the backends, the EdgeVM, and the C emitter all implement."""
    conv: ConvPlan
    squash_out_frac: int = 7
    squash_impl: str = _variants.DEFAULT_SQUASH

    def __post_init__(self):
        _variants.REGISTRY.validate("squash", self.squash_impl)

    @property
    def out_frac(self) -> int:
        return self.squash_out_frac


@dataclasses.dataclass(frozen=True)
class RoutingPlan:
    """Dynamic routing (Alg. 5): one caps-output shift/format pair per
    iteration, one agreement shift per non-final iteration, a shared
    logit format, and the softmax operator variant as a plan field
    (previously a method monkey-patched onto QCapsNet)."""
    uhat_shift: int
    logit_frac: int
    caps_out_shifts: tuple
    caps_out_fracs: tuple
    agree_shifts: tuple              # derived for a Q0.7 squash output;
    #                                  backends add (out_frac - 7) when
    #                                  squash_out_frac is edited
    softmax_impl: str = _variants.DEFAULT_SOFTMAX   # registry reference
    in_frac: int = 7                # post-squash capsules are Q0.7
    W_frac: int = 0                 # bookkeeping for requantization/export
    uhat_frac: int = 0
    squash_out_frac: int = 7        # Q0.7 default; a plan edit, like softmax
    squash_impl: str = _variants.DEFAULT_SQUASH     # registry reference
    # per-output-capsule weight formats (opt-in, the routing analogue of
    # ConvPlan's per-channel tables): entry j is the Qm.n format of
    # W[j, ...] and the matching u_hat requantization shift.  Empty
    # tuples mean per-tensor (the paper's scheme).
    W_frac_per_out: tuple = ()
    uhat_shift_per_out: tuple = ()

    def __post_init__(self):
        _variants.REGISTRY.validate("softmax", self.softmax_impl)
        _variants.REGISTRY.validate("squash", self.squash_impl)

    @property
    def per_out(self) -> bool:
        return bool(self.W_frac_per_out)

    @property
    def routings(self) -> int:
        return len(self.caps_out_shifts)

    @property
    def out_frac(self) -> int:
        return self.squash_out_frac


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """The whole network's quantization decision: the input image format
    plus one typed plan per layer, keyed by layer name in walk order."""
    input_frac: int
    layers: dict

    def __getitem__(self, name: str):
        return self.layers[name]

    @property
    def variants(self) -> "_variants.VariantSet":
        """The operator-variant selection this plan carries (one softmax
        + one squash reference; see repro.nn.variants.VariantSet)."""
        return _variants.VariantSet.of_plan(self)

    def check(self) -> list:
        """Lint this plan's shift/frac algebra, per-channel tables,
        variant references and layer chaining (repro.analysis.plancheck)
        — returns the diagnostics, empty when clean."""
        from repro.analysis.plancheck import check_pipeline_plan
        return check_pipeline_plan(self)


_PLAN_KINDS = {}                      # class name -> plan dataclass


def _register(cls):
    _PLAN_KINDS[cls.__name__] = cls
    return cls


for _cls in (ConvPlan, PrimaryCapsPlan, RoutingPlan):
    _register(_cls)


def plan_to_json(plan) -> dict:
    """Typed plan -> JSON-safe dict (used by captrain's QAT checkpoints
    and anything else that wants a plan outside a Python process)."""
    if isinstance(plan, PipelinePlan):
        return {"kind": "PipelinePlan", "input_frac": plan.input_frac,
                "layers": {k: plan_to_json(p)
                           for k, p in plan.layers.items()}}
    d = {"kind": type(plan).__name__}
    for f in dataclasses.fields(plan):
        v = getattr(plan, f.name)
        if dataclasses.is_dataclass(v):
            v = plan_to_json(v)
        elif isinstance(v, tuple):
            v = list(v)
        d[f.name] = v
    return d


def plan_from_json(d: dict):
    """Inverse of plan_to_json; round-trips bit-exactly (all-int plans)."""
    kind = d["kind"]
    if kind == "PipelinePlan":
        return PipelinePlan(input_frac=d["input_frac"],
                            layers={k: plan_from_json(p)
                                    for k, p in d["layers"].items()})
    cls = _PLAN_KINDS[kind]
    kw = {}
    for f in dataclasses.fields(cls):
        if f.name not in d:
            continue        # field added after this JSON was written:
            #                 fall back to the dataclass default
        v = d[f.name]
        if isinstance(v, dict) and "kind" in v:
            v = plan_from_json(v)
        elif isinstance(v, list):
            v = tuple(v)
        kw[f.name] = v
    return cls(**kw)        # variant references re-validate in __post_init__


def plan_scalars(plan) -> int:
    """Number of scalar entries a plan materializes at runtime (the
    analogue of the old shift table's length, for footprint accounting)."""
    if isinstance(plan, PipelinePlan):
        return 1 + sum(plan_scalars(p) for p in plan.layers.values())
    n = 0
    for f in dataclasses.fields(plan):
        v = getattr(plan, f.name)
        if isinstance(v, int):
            n += 1
        elif isinstance(v, tuple):
            n += len(v)
        elif dataclasses.is_dataclass(v):
            n += plan_scalars(v)
    return n
