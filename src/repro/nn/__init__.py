"""Typed capsule-layer API: one pipeline for float forward, PTQ
calibration, and int8 inference.  See README.md in this package."""
from repro.nn.backend import (BACKENDS, JnpBackend,  # noqa: F401
                              PallasBackend, get_backend)
from repro.nn.config import (CAPSNET_CONFIGS, CIFAR10,  # noqa: F401
                             MNIST, SMALLNORB, CapsNetConfig)
from repro.nn.layers import (CapsLayer, CapsuleRouting,  # noqa: F401
                             PrimaryCaps, QuantConv2D)
from repro.nn.pipeline import CapsPipeline, QuantCapsNet  # noqa: F401
from repro.nn.plans import (ConvPlan, PipelinePlan,  # noqa: F401
                            PrimaryCapsPlan, RoutingPlan, TapStats)
from repro.nn.variants import (REGISTRY, OpVariant,  # noqa: F401
                               VariantRegistry, VariantSet,
                               all_variant_sets)
