"""CapsPipeline: one typed graph walk for all three execution faces.

  forward    — float inference (optionally returning calibration taps)
  calibrate  — max|x| per tap over a reference dataset (Alg. 6 line 8)
  quantize   — per-layer plans + int8 weights -> a QuantCapsNet
  forward_q7 — int8 inference on a selectable op backend

The pipeline owns nothing numeric: every operation, tap, format and shift
belongs to a layer.  Adding a layer kind (deeper stacks, approximate-op
variants, per-channel PTQ) means writing one class against the CapsLayer
protocol — no cross-file string threading.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.config import CapsNetConfig
from repro.nn.layers import CapsuleRouting, PrimaryCaps, QuantConv2D
from repro.nn.plans import PipelinePlan, TapStats, plan_scalars
from repro.nn.variants import VariantSet
from repro.obs import numerics as _health
from repro.quant import qformat as qf


@dataclasses.dataclass(frozen=True)
class CapsPipeline:
    cfg: CapsNetConfig
    layers: tuple

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, cfg: CapsNetConfig, softmax_impl: str | None = None,
                    per_channel: bool = False,
                    squash_impl: str | None = None,
                    variants: VariantSet | None = None,
                    per_channel_w: bool = False) -> "CapsPipeline":
        """Build the typed pipeline for a geometry config.

        Operator variants come from the registry (repro.nn.variants):
        pass a whole `variants=VariantSet(...)`, or the individual
        `softmax_impl=` / `squash_impl=` names (unknown names raise with
        the registered ones listed).  Omitted -> registry defaults.
        `per_channel` opts the convs into per-output-channel weight
        formats; `per_channel_w` does the same for the routing W
        (per-output-capsule formats, RoutingPlan.W_frac_per_out)."""
        if variants is None:
            variants = VariantSet(
                **{k: v for k, v in (("softmax", softmax_impl),
                                     ("squash", squash_impl))
                   if v is not None})
        elif softmax_impl is not None or squash_impl is not None:
            raise ValueError(
                "pass either variants= or softmax_impl=/squash_impl=, "
                "not both")
        layers = []
        cin = cfg.input_shape[2]
        for i, (f, k, s) in enumerate(zip(cfg.conv_filters, cfg.conv_kernels,
                                          cfg.conv_strides)):
            layers.append(QuantConv2D(f"conv{i}", k, s, cin, f, relu=True,
                                      per_channel=per_channel))
            cin = f
        layers.append(PrimaryCaps("pcap", cfg.pcap_kernel, cfg.pcap_stride,
                                  cin, cfg.pcap_caps, cfg.pcap_dim,
                                  per_channel=per_channel,
                                  squash_impl=variants.squash))
        layers.append(CapsuleRouting(
            "caps", cfg.num_classes, cfg.num_input_caps, cfg.caps_dim,
            cfg.pcap_dim, cfg.routings, softmax_impl=variants.softmax,
            squash_impl=variants.squash, per_channel=per_channel_w))
        return cls(cfg=cfg, layers=tuple(layers))

    def layer(self, name: str):
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    def init(self, key) -> dict:
        ks = jax.random.split(key, len(self.layers))
        return {l.name: l.init(k) for l, k in zip(self.layers, ks)}

    @staticmethod
    def param_bytes(params) -> int:
        """fp32 footprint of a param pytree (Table 2's numerator)."""
        return sum(4 * l.size for l in jax.tree_util.tree_leaves(params))

    # ------------------------------------------------------------------
    # float face
    # ------------------------------------------------------------------
    def forward(self, params, x, *, with_taps: bool = False):
        """x [B,H,W,C] float in [0,1] -> class capsules [B, J, O]."""
        taps = {"input": x}
        h = x
        for l in self.layers:
            h, t = l.fwd_f32(params[l.name], h)
            for k, v in t.items():
                taps[f"{l.name}.{k}"] = v
        return (h, taps) if with_taps else h

    def tap_names(self) -> tuple:
        """Every stats key any layer's plan() will read."""
        names = ["input"]
        for l in self.layers:
            names.extend(l.plan_tap_names())
        return tuple(names)

    # ------------------------------------------------------------------
    # calibration face (Alg. 6 line 8)
    # ------------------------------------------------------------------
    def calibrate(self, params, calib_images, batch: int = 64) -> TapStats:
        """Running max|x| per tap accumulates on device; the host sees one
        sync at the end, not one `float()` per tap per batch."""
        @jax.jit
        def batch_maxes(x):
            _, taps = self.forward(params, x, with_taps=True)
            return {k: jnp.max(jnp.abs(t)) for k, t in taps.items()}

        running = None
        n = calib_images.shape[0]
        for i in range(0, n, batch):
            m = batch_maxes(calib_images[i:i + batch])
            running = m if running is None else \
                jax.tree.map(jnp.maximum, running, m)
        if running is None:
            raise ValueError("empty calibration set")
        return TapStats({k: float(v)
                         for k, v in jax.device_get(running).items()})

    # ------------------------------------------------------------------
    # planning + quantization face (Alg. 6 & 7)
    # ------------------------------------------------------------------
    def plan(self, params, stats: TapStats) -> PipelinePlan:
        """Each layer derives its own plan; the activation format chains
        through `out_frac` -> next layer's `in_frac`."""
        input_frac = qf.frac_bits(stats["input"])
        f_act = input_frac
        plans: dict = {}
        for l in self.layers:
            p = l.plan(params[l.name], stats, f_act)
            plans[l.name] = p
            f_act = p.out_frac
        return PipelinePlan(input_frac=input_frac, layers=plans)

    def quantize(self, params, calib_images, *, rounding: str = "floor",
                 backend: str = "jnp", batch: int = 64) -> "QuantCapsNet":
        from repro import obs
        with obs.span("ptq.calibrate", config=self.cfg.name):
            stats = self.calibrate(params, calib_images, batch=batch)
        with obs.span("ptq.plan", config=self.cfg.name):
            plan = self.plan(params, stats)
        with obs.span("ptq.quantize_weights", config=self.cfg.name):
            qweights = {l.name: l.quantize(params[l.name], plan[l.name])
                        for l in self.layers}
        return QuantCapsNet(pipeline=self, plan=plan, qweights=qweights,
                            rounding=rounding, backend=backend)

    # ------------------------------------------------------------------
    # fake-quant face (QAT; see repro.captrain)
    # ------------------------------------------------------------------
    def forward_fq(self, params, x, plan: PipelinePlan, *,
                   rounding: str = "floor"):
        """Float forward with every int8 quantization point fake-applied
        on the plan's Qm.n grids (straight-through gradients).  The plan
        comes from the SAME `plan()` machinery PTQ uses, so a QAT model
        quantizes/lowers/serves with zero new conversion code."""
        if _health._PROBE is None:                 # hot path untouched
            h = qf.fake_quant(x, plan.input_frac)
            for l in self.layers:
                h = l.fwd_fq(params[l.name], plan[l.name], h,
                             rounding=rounding)
            return h
        with _health.scope("input"):
            h = qf.fake_quant(x, plan.input_frac)
        for i, l in enumerate(self.layers):
            with _health.scope(l.name, index=i, kind=type(l).__name__):
                h = l.fwd_fq(params[l.name], plan[l.name], h,
                             rounding=rounding)
        return h

    # ------------------------------------------------------------------
    # int8 face
    # ------------------------------------------------------------------
    def forward_q7(self, qweights, plan: PipelinePlan, x_q, *,
                   backend: str = "jnp", rounding: str = "floor"):
        """x_q int8 image in the plan's input format -> v int8 [B,J,O]."""
        if _health._PROBE is None:                 # hot path untouched
            h = x_q
            for l in self.layers:
                h = l.fwd_q7(qweights[l.name], plan[l.name], h,
                             backend=backend, rounding=rounding)
            return h
        h = x_q
        for i, l in enumerate(self.layers):
            with _health.scope(l.name, index=i, kind=type(l).__name__):
                h = l.fwd_q7(qweights[l.name], plan[l.name], h,
                             backend=backend, rounding=rounding)
                if not _health._is_tracer(h):
                    _health._PROBE.observe_output(
                        h, frac=plan[l.name].out_frac)
        return h

    def quantize_input(self, x, plan: PipelinePlan):
        return qf.quantize(x, plan.input_frac)


@dataclasses.dataclass(frozen=True)
class QuantCapsNet:
    """A quantized CapsNet as a typed object: pipeline + plan + int8
    weights (the replacement for QCapsNet's string-keyed shift table)."""
    pipeline: CapsPipeline
    plan: PipelinePlan
    qweights: dict
    rounding: str = "floor"
    backend: str = "jnp"

    def quantize_input(self, x):
        return self.pipeline.quantize_input(x, self.plan)

    def forward(self, x_q):
        return self.pipeline.forward_q7(self.qweights, self.plan, x_q,
                                        backend=self.backend,
                                        rounding=self.rounding)

    def class_lengths(self, v_q):
        """||v|| per class, dequantized with the final layer's output
        format (not a hardcoded Q0.7 /128 — squash_out_frac is a plan
        field and non-default plans must score correctly)."""
        out_frac = self.plan[self.pipeline.layers[-1].name].out_frac
        v32 = v_q.astype(jnp.int32)
        return jnp.sqrt(jnp.sum(v32 * v32, axis=-1)
                        .astype(jnp.float32)) * (2.0 ** -out_frac)

    def memory_bytes(self) -> int:
        n = sum(l.size * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(self.qweights))
        n += 4 * plan_scalars(self.plan)       # int32 shift/format table
        return int(n)

    def with_backend(self, backend: str) -> "QuantCapsNet":
        return dataclasses.replace(self, backend=backend)

    @property
    def variants(self) -> VariantSet:
        """The operator-variant selection the plan carries."""
        return self.plan.variants

    def with_variants(self, variants: VariantSet) -> "QuantCapsNet":
        """Return a model running `variants` — a pure plan edit (weights
        and shifts untouched; variant choices never affect Alg. 7's
        weight quantization), applied to every variant-bearing layer
        plan in the pipeline (deeper stacks may have several)."""
        return dataclasses.replace(self, plan=variants.apply(self.plan))

    def with_softmax(self, impl: str) -> "QuantCapsNet":
        """Softmax-only plan edit (see with_variants)."""
        return self.with_variants(
            dataclasses.replace(self.variants, softmax=impl))

    def with_squash(self, impl: str) -> "QuantCapsNet":
        """Squash-only plan edit (see with_variants)."""
        return self.with_variants(
            dataclasses.replace(self.variants, squash=impl))
