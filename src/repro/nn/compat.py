"""Translation between the typed plans/taps and the legacy string-keyed
shift table / trace dict.

This is the ONLY place the old magic keys ("conv{i}_out_shift",
"caps_out_shift_{r}", "agree_shift_{r}", ...) exist outside the thin
compatibility shims in core/capsnet*.py and quant/ptq.py.  Everything
here is a pure renaming: the numbers are the plans' own.
"""
from __future__ import annotations

import dataclasses
import re

from repro.nn.plans import (ConvPlan, PipelinePlan, PrimaryCapsPlan,
                            RoutingPlan)
from repro.nn.variants import REGISTRY as _VARIANTS

# tap name -> legacy trace key (and the reverse renames, for stats)
_TAP_RULES = (
    (re.compile(r"^input$"), lambda m: "input"),
    (re.compile(r"^(conv\d+)\.out$"), lambda m: f"{m.group(1)}_out"),
    (re.compile(r"^pcap\.out$"), lambda m: "pcap_out"),
    (re.compile(r"^pcap\.squashed$"), lambda m: "pcap_squashed"),
    (re.compile(r"^caps\.u_hat$"), lambda m: "u_hat"),
    (re.compile(r"^caps\.s/(\d+)$"), lambda m: f"s_iter{m.group(1)}"),
    (re.compile(r"^caps\.agree/(\d+)$"),
     lambda m: f"agree_iter{m.group(1)}"),
    (re.compile(r"^caps\.logits/(\d+)$"),
     lambda m: f"logits_iter{m.group(1)}"),
)


def tap_to_trace_key(name: str) -> str:
    for rx, fmt in _TAP_RULES:
        m = rx.match(name)
        if m:
            return fmt(m)
    return name.replace(".", "_").replace("/", "_")


def taps_to_trace(taps: dict) -> dict:
    """Namespaced tap dict -> the legacy with_trace trace dict."""
    return {tap_to_trace_key(k): v for k, v in taps.items()}


# ---------------------------------------------------------------------------
# plans -> legacy shift table
# ---------------------------------------------------------------------------
def plan_to_shifts(plan: PipelinePlan) -> dict:
    """Flatten a PipelinePlan into the exact legacy shift-table keys."""
    shifts: dict = {"input_frac": plan.input_frac}
    for name, p in plan.layers.items():
        if isinstance(p, ConvPlan):
            shifts[f"{name}_w_frac"] = p.w_frac
            shifts[f"{name}_out_frac"] = p.out_frac
            shifts[f"{name}_out_shift"] = p.out_shift
            shifts[f"{name}_bias_shift"] = p.bias_shift
        elif isinstance(p, PrimaryCapsPlan):
            shifts[f"{name}_w_frac"] = p.conv.w_frac
            shifts[f"{name}_out_frac"] = p.conv.out_frac
            shifts[f"{name}_out_shift"] = p.conv.out_shift
            shifts[f"{name}_bias_shift"] = p.conv.bias_shift
        elif isinstance(p, RoutingPlan):
            if "uhat_shift" in shifts:
                raise ValueError(
                    "the legacy shift table holds exactly one routing "
                    "layer; use the typed PipelinePlan for deeper stacks")
            # the legacy table knows exactly one routing layer, under
            # fixed keys — "caps_W_frac" regardless of the layer's name
            shifts["caps_W_frac"] = p.W_frac
            shifts["uhat_frac"] = p.uhat_frac
            shifts["uhat_shift"] = p.uhat_shift
            shifts["logit_frac"] = p.logit_frac
            for r in range(p.routings):
                shifts[f"caps_out_frac_{r}"] = p.caps_out_fracs[r]
                shifts[f"caps_out_shift_{r}"] = p.caps_out_shifts[r]
            for r, s in enumerate(p.agree_shifts):
                shifts[f"agree_shift_{r}"] = s
        else:
            raise TypeError(f"unknown plan type for layer {name}: {p!r}")
    return shifts


# ---------------------------------------------------------------------------
# legacy shift table -> plans (partial tables allowed, per shim)
# ---------------------------------------------------------------------------
def conv_plan_from_shifts(shifts: dict, name: str) -> ConvPlan:
    return ConvPlan(
        in_frac=shifts.get("input_frac", 7),
        w_frac=shifts.get(f"{name}_w_frac", 0),
        b_frac=0,
        out_frac=shifts.get(f"{name}_out_frac", 7),
        out_shift=shifts[f"{name}_out_shift"],
        bias_shift=shifts[f"{name}_bias_shift"])


def pcap_plan_from_shifts(shifts: dict) -> PrimaryCapsPlan:
    return PrimaryCapsPlan(conv=ConvPlan(
        in_frac=0, w_frac=shifts.get("pcap_w_frac", 0), b_frac=0,
        out_frac=shifts["pcap_out_frac"],
        out_shift=shifts["pcap_out_shift"],
        bias_shift=shifts["pcap_bias_shift"]))


def routing_plan_from_shifts(shifts: dict, routings: int,
                             softmax_impl: str | None = None) -> RoutingPlan:
    # the legacy table has no variant columns: default from the registry
    # (never a literal here, so the shims cannot drift from the typed path)
    softmax_impl = _VARIANTS.validate(
        "softmax", softmax_impl or _VARIANTS.default("softmax"))
    return RoutingPlan(
        uhat_shift=shifts["uhat_shift"],
        logit_frac=shifts["logit_frac"],
        caps_out_shifts=tuple(shifts[f"caps_out_shift_{r}"]
                              for r in range(routings)),
        caps_out_fracs=tuple(shifts[f"caps_out_frac_{r}"]
                             for r in range(routings)),
        agree_shifts=tuple(shifts[f"agree_shift_{r}"]
                           for r in range(routings - 1)),
        softmax_impl=softmax_impl,
        W_frac=shifts.get("caps_W_frac", 0),
        uhat_frac=shifts.get("uhat_frac", 0))


def shifts_to_plan(shifts: dict, num_convs: int, routings: int,
                   softmax_impl: str | None = None) -> PipelinePlan:
    """Full legacy shift table -> PipelinePlan (for the forward shim)."""
    layers: dict = {}
    f_act = shifts.get("input_frac", 7)   # execution never reads in_frac
    for i in range(num_convs):
        p = conv_plan_from_shifts(shifts, f"conv{i}")
        layers[f"conv{i}"] = dataclasses.replace(p, in_frac=f_act)
        f_act = p.out_frac
    pc = pcap_plan_from_shifts(shifts)
    layers["pcap"] = dataclasses.replace(
        pc, conv=dataclasses.replace(pc.conv, in_frac=f_act))
    layers["caps"] = routing_plan_from_shifts(shifts, routings,
                                              softmax_impl)
    return PipelinePlan(input_frac=shifts.get("input_frac", 7),
                        layers=layers)
