"""CapsNet geometry configs (paper Table 1 / Table 7).

Geometry check against the paper (exact): with VALID padding,
  MNIST    28x28x1: conv16 k7 s1 -> 22x22; pcap k7 s2 -> 8x8x(16x4)
           -> 1024 input capsules  => caps layer 10x1024x6x4   (Table 7 "L")
           => 297.1k params = 1187.20 KB fp32                  (Table 2)
  smallNORB 32x32x2 (resized, as the paper's table sizes imply): conv32 k7
           -> 26x26; pcap k7 s2 -> 10x10 -> 1600 caps => 5x1600x6x4 ("M")
           => 295.6k params = 1182.34 KB fp32
  CIFAR-10 32x32x3: convs 32,32,64,64 k3 s1,1,2,2 -> 6x6; pcap k3 s2 ->
           2x2 -> 64 caps => 10x64x5x4 ("S") => 115.3k = 461.19 KB fp32
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CapsNetConfig:
    name: str
    input_shape: tuple                     # (H, W, C)
    conv_filters: tuple                    # e.g. (16,) or (32,32,64,64)
    conv_kernels: tuple
    conv_strides: tuple
    pcap_caps: int = 16
    pcap_dim: int = 4
    pcap_kernel: int = 7
    pcap_stride: int = 2
    num_classes: int = 10
    caps_dim: int = 6
    routings: int = 3
    lr: float = 1e-3

    @property
    def conv_out_hw(self) -> tuple:
        h, w = self.input_shape[0], self.input_shape[1]
        for k, s in zip(self.conv_kernels, self.conv_strides):
            h = (h - k) // s + 1
            w = (w - k) // s + 1
        return h, w

    @property
    def pcap_out_hw(self) -> tuple:
        h, w = self.conv_out_hw
        k, s = self.pcap_kernel, self.pcap_stride
        return (h - k) // s + 1, (w - k) // s + 1

    @property
    def num_input_caps(self) -> int:
        h, w = self.pcap_out_hw
        return h * w * self.pcap_caps


MNIST = CapsNetConfig("capsnet_mnist", (28, 28, 1), (16,), (7,), (1,),
                      num_classes=10, caps_dim=6, lr=1e-3)
SMALLNORB = CapsNetConfig("capsnet_smallnorb", (32, 32, 2), (32,), (7,), (1,),
                          num_classes=5, caps_dim=6, lr=2.5e-4)
CIFAR10 = CapsNetConfig("capsnet_cifar10", (32, 32, 3), (32, 32, 64, 64),
                        (3, 3, 3, 3), (1, 1, 2, 2), pcap_kernel=3,
                        num_classes=10, caps_dim=5, lr=2.5e-4)
CAPSNET_CONFIGS = {c.name: c for c in (MNIST, SMALLNORB, CIFAR10)}
