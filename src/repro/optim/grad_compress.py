"""Int8 gradient compression with error feedback (beyond-paper, DESIGN §7).

The paper's Qm.n power-of-two int8 format applied to the cross-pod
data-parallel gradient reduction: each worker quantizes its gradient
contribution to int8 with a per-tensor power-of-two scale before the
all-reduce (4x ICI bytes saved on the slowest links), keeps the
quantization residual in an error-feedback buffer, and adds it back the
next step — the standard EF-SGD construction, which preserves convergence
(tested in tests/test_grad_compress.py by training to parity).

`compress / decompress` are the wire format; `EFCompressor.apply` is the
drop-in gradient transform; `compressed_psum` is the shard_map collective
for explicit-DP setups.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def pow2_scale(max_abs):
    """Power-of-two scale s with max_abs/s <= 127 (traced-value version of
    qformat.frac_bits: exponent = floor(log2(127 / max_abs)))."""
    e = jnp.floor(jnp.log2(127.0 / jnp.maximum(max_abs, 1e-30)))
    return jnp.clip(e, -24, 24)


def compress(g):
    """float tensor -> (int8 tensor, exponent scalar)."""
    gf = g.astype(jnp.float32)
    e = pow2_scale(jnp.max(jnp.abs(gf)))
    q = jnp.clip(jnp.round(gf * jnp.exp2(e)), -128, 127).astype(jnp.int8)
    return q, e


def decompress(q, e):
    return q.astype(jnp.float32) * jnp.exp2(-e)


@dataclasses.dataclass(frozen=True)
class EFCompressor:
    """Error-feedback int8 gradient compressor."""

    def init(self, params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(self, grads, err):
        """Returns (compressed-then-decompressed grads, new error state)."""
        def one(g, e_buf):
            gf = g.astype(jnp.float32) + e_buf
            q, e = compress(gf)
            deq = decompress(q, e)
            return deq, gf - deq
        out = jax.tree.map(one, grads, err)
        deq = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        return deq, new_err


def compressed_psum(x, axis_name: str):
    """All-reduce of an int8-compressed tensor over `axis_name` (shard_map
    context).  Wire bytes = 1/4 of fp32 psum; the residual handling lives
    in EFCompressor at the caller."""
    q, e = compress(x)
    # align exponents across workers (use the max -> smallest scale)
    e_min = jax.lax.pmin(e, axis_name)
    q_aligned = jnp.right_shift(q.astype(jnp.int32),
                                (e - e_min).astype(jnp.int32))
    tot = jax.lax.psum(q_aligned, axis_name)
    return tot.astype(jnp.float32) * jnp.exp2(-e_min)
