"""Optimizers in pure JAX: AdamW (default), SGD-momentum; cosine/linear
schedules; global-norm clipping.

Moments are fp32 regardless of parameter dtype (bf16 params + fp32 m/v =
the usual mixed-precision training recipe; see DESIGN.md §4 memory budget).
Optimizer state shards exactly like its parameter (the sharding rules map
state leaves through the same path rules).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Schedule = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state, params):
        step = state["step"] + 1
        if self.clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        b1, b2 = self.b1, self.b2
        lr = self._lr(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        new_state = {"m": new_m, "v": new_v, "step": step}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


@dataclasses.dataclass(frozen=True)
class SGDM:
    lr: Schedule = 1e-2
    momentum: float = 0.9
    clip_norm: float = 0.0

    def init(self, params) -> dict:
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr)

    def update(self, grads, state, params):
        step = state["step"] + 1
        if self.clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        lr = self._lr(step)

        def upd(p, g, m):
            m = self.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        new_pm = jax.tree.map(upd, params, grads, state["m"])
        new_params = jax.tree.map(lambda t: t[0], new_pm,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], new_pm,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "step": step}, {"grad_norm": gnorm,
                                                        "lr": lr}
