"""repro.search — accuracy-driven quantization & variant search.

Q-CapsNets-style design-space exploration over the typed PipelinePlan:
per-layer Qm.n frac reductions, per-channel/per-out weight formats, and
operator-variant selection, scored on accuracy x memory x estimated MCU
latency x numerics health, producing a *verified* Pareto frontier
(every point exports/re-imports/bit-verifies as `.capsbin`).  See
src/repro/search/README.md for the module contract.
"""
from repro.search.driver import (SearchConfig, model_config, run_search,
                                 save_doc, setup_space)
from repro.search.frontier import (AXES, SEARCH_SCHEMA, build_doc,
                                   dominated_pairs, dominates,
                                   frontier_table_rows, load_doc, pareto,
                                   rebuild_point, verify_point)
from repro.search.objective import Candidate, Objective, flash_packed_bytes
from repro.search.space import MAX_REDUCTION, CandidateSpec, SearchSpace
from repro.search.strategies import STRATEGIES

__all__ = [
    "AXES", "Candidate", "CandidateSpec", "MAX_REDUCTION", "Objective",
    "SEARCH_SCHEMA", "STRATEGIES", "SearchConfig", "SearchSpace",
    "build_doc", "dominated_pairs", "dominates", "flash_packed_bytes",
    "frontier_table_rows", "load_doc", "model_config", "pareto",
    "rebuild_point", "run_search", "save_doc", "setup_space",
    "verify_point",
]
