"""The quantization & variant design space over a trained CapsNet.

A point in the space is a `CandidateSpec`: per-layer Qm.n fractional-bit
reductions (Q-CapsNets-style "virtual bit" coarsening of weights and
activations), per-tensor vs per-channel weight formats for the convs
and the routing `W`, and the softmax/squash operator variant selection
(repro.nn.variants).  `SearchSpace` turns any spec into a requantized
`QuantCapsNet` whose plan satisfies the full shift algebra — candidates
are built by re-deriving the default plan from the trained weights and
the calibration set, then applying the spec's deltas with every
dependent shift recomputed, so `PipelinePlan.check()` is clean by
construction (and asserted).

Frac deltas are always <= 0: the search coarsens formats (fewer
fractional bits -> smaller packed weights, the paper's memory axis),
never refines past the calibrated allocation (which is already the
finest format that provably fits int8).
"""
from __future__ import annotations

import dataclasses
import json

import jax.numpy as jnp

from repro.nn.layers import CapsuleRouting, PrimaryCaps, QuantConv2D
from repro.nn.pipeline import CapsPipeline, QuantCapsNet
from repro.nn.plans import (ConvPlan, PipelinePlan, PrimaryCapsPlan,
                            RoutingPlan)
from repro.nn.variants import REGISTRY

# deepest per-coordinate fractional-bit reduction the space admits;
# beyond ~3 bits an int8 weight grid has lost most of its levels and
# every candidate is rejected on accuracy anyway
MAX_REDUCTION = 3


@dataclasses.dataclass(frozen=True)
class CandidateSpec:
    """One point of the design space, JSON-round-trippable and hashable
    (delta maps are canonically-sorted tuples of (layer, delta<=0))."""
    softmax: str = ""                # "" -> registry default
    squash: str = ""
    per_channel: bool = False        # conv weight formats per out-channel
    per_channel_w: bool = False      # routing W formats per out-capsule
    w_frac_deltas: tuple = ()        # ((layer, delta), ...)
    out_frac_deltas: tuple = ()      # ((layer, delta), ...)

    def __post_init__(self):
        for field in ("w_frac_deltas", "out_frac_deltas"):
            entries = tuple(tuple(e) for e in getattr(self, field))
            object.__setattr__(self, field,
                               tuple(sorted(dict(entries).items())))
            for layer, delta in getattr(self, field):
                if not -MAX_REDUCTION <= delta <= 0:
                    raise ValueError(
                        f"{field}[{layer!r}] = {delta}: deltas must be "
                        f"in [-{MAX_REDUCTION}, 0]")
        if self.softmax:
            REGISTRY.validate("softmax", self.softmax)
        if self.squash:
            REGISTRY.validate("squash", self.squash)

    def delta(self, field: str, layer: str) -> int:
        return dict(getattr(self, field)).get(layer, 0)

    @property
    def key(self) -> str:
        """Canonical identity (dedupe/cache key)."""
        return json.dumps(self.to_json(), sort_keys=True)

    def to_json(self) -> dict:
        return {"softmax": self.softmax, "squash": self.squash,
                "per_channel": self.per_channel,
                "per_channel_w": self.per_channel_w,
                "w_frac_deltas": [list(e) for e in self.w_frac_deltas],
                "out_frac_deltas": [list(e) for e in self.out_frac_deltas]}

    @classmethod
    def from_json(cls, d: dict) -> "CandidateSpec":
        softmax = str(d.get("softmax") or "")
        squash = str(d.get("squash") or "")
        return cls(softmax=softmax,
                   squash=squash,
                   per_channel=bool(d.get("per_channel", False)),
                   per_channel_w=bool(d.get("per_channel_w", False)),
                   w_frac_deltas=tuple(tuple(e)
                                       for e in d.get("w_frac_deltas", [])),
                   out_frac_deltas=tuple(
                       tuple(e) for e in d.get("out_frac_deltas", [])))

    # -- functional edits (the strategies' move set) -------------------
    def with_delta(self, field: str, layer: str,
                   delta: int) -> "CandidateSpec":
        entries = dict(getattr(self, field))
        if delta == 0:
            entries.pop(layer, None)
        else:
            entries[layer] = delta
        return dataclasses.replace(self, **{field: tuple(entries.items())})

    def with_variant(self, kind: str, name: str) -> "CandidateSpec":
        if name == REGISTRY.default(kind):
            name = ""
        return dataclasses.replace(
            self, **{"softmax" if kind == "softmax" else "squash": name})

    def with_flag(self, flag: str, value: bool) -> "CandidateSpec":
        return dataclasses.replace(self, **{flag: value})


class SearchSpace:
    """Spec -> verified plan/model factory over ONE trained network.

    Holds the float params and the calibration set; every structural
    pipeline (variant set x per-channel flags) and its calibration
    stats are derived once and cached, so a search loop pays only the
    delta algebra + weight requantization per candidate."""

    def __init__(self, cfg, params, calib_images):
        self.cfg = cfg
        self.params = params
        self.calib_images = jnp.asarray(calib_images)
        self._pipelines: dict = {}
        self._stats: dict = {}
        self._base_plans: dict = {}

    # -- coordinates ---------------------------------------------------
    def axes(self) -> list:
        """Deterministic coordinate list (the strategies' walk order):
        per-layer ("w_frac", layer) and ("out_frac", layer) reductions,
        then ("variant", kind) selections, then the per-channel flags.
        out_frac applies to conv-stage activations only — squash
        outputs stay in their derived format (the routing contract)."""
        axes = []
        for layer in self.pipeline(CandidateSpec()).layers:
            if isinstance(layer, (QuantConv2D, PrimaryCaps,
                                  CapsuleRouting)):
                axes.append(("w_frac", layer.name))
            if isinstance(layer, (QuantConv2D, PrimaryCaps)):
                axes.append(("out_frac", layer.name))
        axes += [("variant", "softmax"), ("variant", "squash"),
                 ("flag", "per_channel"), ("flag", "per_channel_w")]
        return axes

    def variant_names(self, kind: str) -> tuple:
        return tuple(REGISTRY.names(kind))

    # -- construction --------------------------------------------------
    def _struct_key(self, spec: CandidateSpec) -> tuple:
        return (spec.softmax, spec.squash, spec.per_channel,
                spec.per_channel_w)

    def pipeline(self, spec: CandidateSpec) -> CapsPipeline:
        key = self._struct_key(spec)
        if key not in self._pipelines:
            self._pipelines[key] = CapsPipeline.from_config(
                self.cfg,
                softmax_impl=spec.softmax or None,
                squash_impl=spec.squash or None,
                per_channel=spec.per_channel,
                per_channel_w=spec.per_channel_w)
        return self._pipelines[key]

    def base_plan(self, spec: CandidateSpec) -> PipelinePlan:
        """The calibrated default plan of the spec's structural
        pipeline (before any frac deltas)."""
        key = self._struct_key(spec)
        if key not in self._base_plans:
            pipe = self.pipeline(spec)
            stats = pipe.calibrate(self.params, self.calib_images)
            self._base_plans[key] = pipe.plan(self.params, stats)
        return self._base_plans[key]

    def build_plan(self, spec: CandidateSpec) -> PipelinePlan:
        """Apply the spec's frac deltas to the calibrated plan,
        recomputing every dependent shift so the Qm.n algebra holds
        (asserted via PipelinePlan.check)."""
        plan = _apply_deltas(self.base_plan(spec), spec)
        findings = plan.check()
        assert not findings, \
            f"search produced an inconsistent plan: {findings}"
        return plan

    def build_qnet(self, spec: CandidateSpec, *, rounding: str = "floor",
                   params=None, backend: str = "jnp") -> QuantCapsNet:
        """Requantize the trained weights on the spec's plan.  `params`
        overrides the space's float params (QAT-refined weights keep
        the candidate plan — fixed-grid fine-tuning)."""
        pipe = self.pipeline(spec)
        plan = self.build_plan(spec)
        params = self.params if params is None else params
        qweights = {l.name: l.quantize(params[l.name], plan[l.name])
                    for l in pipe.layers}
        return QuantCapsNet(pipeline=pipe, plan=plan, qweights=qweights,
                            rounding=rounding, backend=backend)


# ---------------------------------------------------------------------------
# delta algebra
# ---------------------------------------------------------------------------
def _shift_conv(plan: ConvPlan, in_frac: int, wd: int, od: int) -> ConvPlan:
    w_frac = plan.w_frac + wd
    out_frac = plan.out_frac + od
    pc_w = tuple(f + wd for f in plan.w_frac_per_channel)
    return dataclasses.replace(
        plan, in_frac=in_frac, w_frac=w_frac, out_frac=out_frac,
        out_shift=in_frac + w_frac - out_frac,
        bias_shift=in_frac + w_frac - plan.b_frac,
        w_frac_per_channel=pc_w,
        out_shift_per_channel=tuple(in_frac + f - out_frac for f in pc_w),
        bias_shift_per_channel=tuple(in_frac + f - plan.b_frac
                                     for f in pc_w))


def _apply_deltas(plan: PipelinePlan, spec: CandidateSpec) -> PipelinePlan:
    """Thread the activation format through the layers while applying
    w_frac/out_frac reductions — the same chaining walk as
    `CapsPipeline.plan`, expressed over already-derived plans."""
    f_act = plan.input_frac
    layers: dict = {}
    for name, p in plan.layers.items():
        wd = spec.delta("w_frac_deltas", name)
        od = spec.delta("out_frac_deltas", name)
        if isinstance(p, PrimaryCapsPlan):
            conv = _shift_conv(p.conv, f_act, wd, od)
            p = dataclasses.replace(p, conv=conv)
        elif isinstance(p, ConvPlan):
            p = _shift_conv(p, f_act, wd, od)
        elif isinstance(p, RoutingPlan):
            in_frac = f_act
            W_frac = p.W_frac + wd
            pc_w = tuple(f + wd for f in p.W_frac_per_out)
            p = dataclasses.replace(
                p, in_frac=in_frac, W_frac=W_frac,
                uhat_shift=in_frac + W_frac - p.uhat_frac,
                W_frac_per_out=pc_w,
                uhat_shift_per_out=tuple(in_frac + f - p.uhat_frac
                                         for f in pc_w))
        else:                       # pragma: no cover - new plan kinds
            raise TypeError(f"no delta algebra for {type(p).__name__}")
        layers[name] = p
        f_act = p.out_frac
    return PipelinePlan(input_frac=plan.input_frac, layers=layers)
