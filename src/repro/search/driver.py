"""End-to-end search runs: config -> trained net -> frontier doc.

`run_search` owns the determinism contract.  ONE `np.random.Generator`
seeded from `SearchConfig.seed` is threaded through everything that
draws randomness — the trainer's calibration subsampling (the
`CapsTrainer(rng=...)` contract) and the search strategy — in a fixed
call order, so two runs with the same config produce byte-identical
`repro.search/v1` docs, and `frontier.rebuild_point` can replay the
setup to re-derive any frontier point bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro import obs
from repro.captrain.evalq import eval_float, eval_q7
from repro.captrain.trainer import CapsTrainer, TrainConfig
from repro.data.synthetic import make_image_dataset
from repro.search import frontier as F
from repro.search.objective import SAT_THRESHOLD, Objective
from repro.search.space import CandidateSpec, SearchSpace
from repro.search.strategies import STRATEGIES


def model_config(name: str):
    """Resolve a search model name ("edge_tiny" or a dataset with a
    capsnet_<dataset> config) to its CapsNetConfig."""
    from repro.nn.config import CAPSNET_CONFIGS
    from repro.serving.registry import EDGE_TINY
    if name == "edge_tiny":
        return EDGE_TINY
    try:
        return CAPSNET_CONFIGS[f"capsnet_{name}"]
    except KeyError:
        raise ValueError(
            f"unknown search model {name!r}; have edge_tiny, "
            f"{', '.join(k[len('capsnet_'):] for k in CAPSNET_CONFIGS)}")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """One search run, fully specified (the doc's `config` block —
    `rebuild_point` reconstructs everything from it + the seed)."""
    model: str = "edge_tiny"
    strategy: str = "coordinate"
    budget: int = 24                # unique candidate evaluations
    seed: int = 0
    float_steps: int = 60
    qat_steps: int = 0              # >0: QAT-refine accuracy per candidate
    eval_n: int = 256
    eval_seed: int = 999_999
    rounding: str = "floor"
    sat_threshold: float = SAT_THRESHOLD
    acc_tol: float = 0.005          # paper band: <=0.5 % accuracy loss
    calib_n: int = 64
    batch: int = 64
    numerics_n: int = 64
    verify_n: int = 8               # frontier-point bit-verify images

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; have "
                             f"{sorted(STRATEGIES)}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "SearchConfig":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


@dataclasses.dataclass
class SearchSetup:
    """The deterministic state a run (or a point rebuild) derives from a
    SearchConfig: trained float net + space + eval data + the rng, left
    exactly where the strategy should start consuming it."""
    cfg: SearchConfig
    model_cfg: object
    trainer: CapsTrainer
    state: dict
    space: SearchSpace
    images: np.ndarray
    labels: np.ndarray
    rng: np.random.Generator
    float_acc: float


def setup_space(cfg: SearchConfig, *, log=None) -> SearchSetup:
    """Seed -> trained float net -> SearchSpace.  The rng draw order is
    fixed: the float fit draws nothing, then `calib_images()` draws
    once — so the returned rng's state is a pure function of the
    config, whatever the strategy does with it afterwards."""
    mc = model_config(cfg.model)
    tcfg = TrainConfig(dataset=cfg.model, batch=cfg.batch,
                       calib_n=cfg.calib_n, seed=cfg.seed,
                       rounding=cfg.rounding)
    rng = np.random.default_rng(cfg.seed)
    trainer = CapsTrainer(mc, tcfg, rng=rng)
    state = trainer.init_state()
    with obs.span("search.setup", model=cfg.model, steps=cfg.float_steps):
        state, _, _ = trainer.fit(state, cfg.float_steps,
                                  log_every=50 if log else 0,
                                  log=log or print)
        calib = trainer.calib_images()          # rng draw #1
    space = SearchSpace(mc, state["params"]["caps"], calib)
    images, labels = make_image_dataset(cfg.model, cfg.eval_n,
                                        seed=cfg.eval_seed)
    float_acc = eval_float(trainer.pipeline, state["params"]["caps"],
                           images, labels)
    return SearchSetup(cfg=cfg, model_cfg=mc, trainer=trainer, state=state,
                       space=space, images=images, labels=labels, rng=rng,
                       float_acc=float_acc)


def _qat_eval(st: SearchSetup):
    """Per-candidate QAT refinement: fork the float weights, fine-tune
    fake-quant against the candidate's FIXED plan (recalib off, so no
    rng draws), and re-score int8 accuracy on the same grid."""
    cfg = st.cfg

    def refine(spec: CandidateSpec) -> float:
        plan = st.space.build_plan(spec)
        rtc = dataclasses.replace(st.trainer.tcfg, recalib_every=0,
                                  ckpt_every=0)
        qtr = CapsTrainer(st.model_cfg, rtc)
        qstate, _, _ = qtr.fit(st.state, cfg.qat_steps, qat=True, plan=plan)
        qnet = st.space.build_qnet(spec, rounding=cfg.rounding,
                                   params=qstate["params"]["caps"])
        return eval_q7(qnet, st.images, st.labels)

    return refine


def run_search(cfg: SearchConfig, *, log=None) -> dict:
    """Full pipeline: setup -> strategy -> Pareto frontier -> per-point
    export/check/bit-verify -> `repro.search/v1` doc."""
    say = log or (lambda *_: None)
    st = setup_space(cfg, log=log)
    say(f"[search] {cfg.model}: float acc {st.float_acc:.4f}, "
        f"strategy={cfg.strategy} budget={cfg.budget} seed={cfg.seed}")

    objective = Objective(
        st.space, st.images, st.labels, rounding=cfg.rounding,
        numerics_n=cfg.numerics_n, sat_threshold=cfg.sat_threshold,
        qat_eval=_qat_eval(st) if cfg.qat_steps > 0 else None)
    baseline = objective.evaluate(CandidateSpec())
    STRATEGIES[cfg.strategy](st.space, objective, cfg.budget, st.rng,
                             cfg.acc_tol)
    candidates = list(objective.cache.values())
    say(f"[search] evaluated {objective.evaluations} candidates "
        f"({sum(not c.ok for c in candidates)} rejected)")

    with obs.span("search.frontier", candidates=len(candidates)):
        front = F.pareto(candidates)
        verification = {}
        from repro.nn.plans import plan_to_json
        for i, c in enumerate(front):
            report = F.verify_point(st.space, c, rounding=cfg.rounding,
                                    verify_images=st.images[:cfg.verify_n])
            verification[i] = {
                "verified": bool(report.get("verified")),
                "checked": bool(report.get("checked")),
                "plan": plan_to_json(st.space.build_plan(c.spec)),
            }
    say(f"[search] frontier: {len(front)} verified points")

    doc = F.build_doc(cfg.to_json(), baseline, candidates, front,
                      verification=verification)
    doc["float_acc"] = st.float_acc
    return doc


def save_doc(doc: dict, path) -> None:
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
