"""Search strategies: deterministic walks over the candidate space.

A strategy's ONLY job is to decide which specs to evaluate; every
verdict comes from the `Objective` (which caches by spec identity, so
revisits are free — `budget` counts unique evaluations).  The frontier
is computed afterwards over *everything* the strategy evaluated, so a
strategy does not need to track non-dominated sets itself — it just has
to explore well.

Both strategies are bit-reproducible: `coordinate` draws nothing from
the rng at all, and `random` consumes it in a fixed call order, so the
same seed always yields the same evaluation sequence (and therefore the
same frontier doc — the reproducibility pin in tests/test_search.py).

Adding a strategy = one function `(space, objective, budget, rng,
acc_tol) -> None` registered in `STRATEGIES` (see search/README.md).
"""
from __future__ import annotations

from repro.search.objective import Objective
from repro.search.space import MAX_REDUCTION, CandidateSpec, SearchSpace


def _acceptable(cand, base_acc: float, acc_tol: float) -> bool:
    return cand.ok and base_acc - cand.metrics["acc"] <= acc_tol


def coordinate(space: SearchSpace, objective: Objective, budget: int,
               rng, acc_tol: float = 0.005) -> None:
    """Q-CapsNets-style greedy coordinate descent: walk the axes in
    their deterministic order; on each frac axis push the reduction
    deeper (-1, -2, -3) while the candidate stays verified and within
    `acc_tol` of the baseline accuracy; try each non-default operator
    variant and keep it only when it is strictly cheaper (est m7
    latency) at acceptable accuracy; flip the per-channel flags and
    keep them only when accuracy strictly improves.  Draws nothing from
    `rng` — the walk is fully determined by the space."""
    best = objective.evaluate(CandidateSpec())
    base_acc = best.metrics.get("acc", 0.0)

    def exhausted() -> bool:
        return objective.evaluations >= budget

    for kind, name in space.axes():
        if exhausted():
            return
        if kind in ("w_frac", "out_frac"):
            field = f"{kind}_deltas"
            for delta in range(-1, -MAX_REDUCTION - 1, -1):
                if exhausted():
                    return
                cand = objective.evaluate(
                    best.spec.with_delta(field, name, delta))
                if not _acceptable(cand, base_acc, acc_tol):
                    break               # deeper cuts only get worse
                best = cand
        elif kind == "variant":
            for vname in space.variant_names(name):
                if exhausted():
                    return
                trial = best.spec.with_variant(name, vname)
                if trial.key == best.spec.key:
                    continue
                cand = objective.evaluate(trial)
                if _acceptable(cand, base_acc, acc_tol) and \
                        cand.metrics["est_ms_m7"] < \
                        best.metrics["est_ms_m7"]:
                    best = cand
        elif kind == "flag":
            if exhausted():
                return
            cand = objective.evaluate(
                best.spec.with_flag(name, not getattr(best.spec, name)))
            if cand.ok and cand.metrics["acc"] > best.metrics["acc"]:
                best = cand


def random_search(space: SearchSpace, objective: Objective, budget: int,
                  rng, acc_tol: float = 0.005) -> None:
    """Seeded random/evolutionary baseline: mutate one axis of a parent
    drawn from the acceptable pool (falling back to the default spec)
    until the budget is spent.  All randomness flows through `rng` in a
    fixed call order, so identical seeds replay identically."""
    base = objective.evaluate(CandidateSpec())
    base_acc = base.metrics.get("acc", 0.0)
    pool = [base]
    axes = space.axes()
    attempts = 0
    while objective.evaluations < budget and attempts < budget * 20:
        attempts += 1
        parent = pool[int(rng.integers(len(pool)))].spec
        kind, name = axes[int(rng.integers(len(axes)))]
        if kind in ("w_frac", "out_frac"):
            delta = -int(rng.integers(0, MAX_REDUCTION + 1))
            spec = parent.with_delta(f"{kind}_deltas", name, delta)
        elif kind == "variant":
            names = space.variant_names(name)
            spec = parent.with_variant(
                name, names[int(rng.integers(len(names)))])
        else:
            spec = parent.with_flag(name, bool(rng.integers(2)))
        if spec.key == parent.key:
            continue
        cand = objective.evaluate(spec)
        if _acceptable(cand, base_acc, acc_tol):
            pool.append(cand)


STRATEGIES = {
    "coordinate": coordinate,
    "random": random_search,
}
