"""Pareto frontier over evaluated candidates + the `repro.search/v1` doc.

The frontier is computed over the paper's axes — accuracy up,
virtual-bit-packed flash down, RAM down, estimated Cortex-M7 latency
down — and every surviving point is *re-verified at selection time*:
exported to `.capsbin`, re-imported, statically checked, and bit-exact
EdgeVM-verified against the jnp oracle (`edge.export.export_artifacts`).
A frontier point in the doc is therefore a deployment-ready claim, not
a score.  `rebuild_point` re-derives a point's model from the doc's
search config and asserts the plan matches bit-for-bit — the drift
guard behind `export_caps --from-search`.
"""
from __future__ import annotations

import tempfile

from repro.nn.plans import plan_to_json
from repro.search.objective import Candidate
from repro.search.space import CandidateSpec

SEARCH_SCHEMA = "repro.search/v1"

# (metric, sign): +1 = higher is better, -1 = lower is better
AXES = (("acc", 1), ("flash_packed_bytes", -1), ("ram_bytes", -1),
        ("est_ms_m7", -1))


def dominates(a: dict, b: dict, axes=AXES) -> bool:
    """True if metrics `a` Pareto-dominates `b`: no worse on every axis,
    strictly better on at least one."""
    strict = False
    for key, sign in axes:
        da, db = sign * a[key], sign * b[key]
        if da < db:
            return False
        if da > db:
            strict = True
    return strict


def pareto(candidates, axes=AXES) -> list:
    """The non-dominated subset of the `ok` candidates, in their given
    (deterministic) order.  Duplicate metric vectors keep the first."""
    scored = [c for c in candidates if c.ok and "acc" in c.metrics]
    front = []
    seen = set()
    for c in scored:
        key = tuple(c.metrics[k] for k, _ in axes)
        if key in seen:
            continue
        if any(dominates(o.metrics, c.metrics, axes) for o in scored):
            continue
        seen.add(key)
        front.append(c)
    return front


def dominated_pairs(points, axes=AXES) -> int:
    """Number of (i, j) pairs within `points` (metric dicts or frontier
    point dicts) where one dominates the other — 0 for a true frontier
    (the bench invariant)."""
    ms = [p["metrics"] if "metrics" in p else p for p in points]
    return sum(1 for a in ms for b in ms
               if a is not b and dominates(a, b, axes))


# ---------------------------------------------------------------------------
# frontier-point verification (export -> reload -> re-verify)
# ---------------------------------------------------------------------------
def verify_point(space, cand: Candidate, *, rounding: str,
                 verify_images, out_dir=None) -> dict:
    """Export the candidate's model as `.capsbin` + plan JSON and run
    the full export gauntlet: static checker on the lowered program and
    bit-exact EdgeVM-vs-oracle verification of the reloaded artifact.
    Returns export_artifacts' report dict (raises on any failure)."""
    from repro.edge.export import export_artifacts
    qnet = space.build_qnet(cand.spec, rounding=rounding)
    if out_dir is not None:
        return export_artifacts(qnet, out_dir,
                                verify_images=verify_images, check=True)
    with tempfile.TemporaryDirectory() as tmp:
        return export_artifacts(qnet, tmp,
                                verify_images=verify_images, check=True)


# ---------------------------------------------------------------------------
# result doc
# ---------------------------------------------------------------------------
def build_doc(config: dict, baseline: Candidate, candidates,
              frontier, *, verification=None) -> dict:
    """Assemble the `repro.search/v1` result document.  `frontier` is
    the pareto() output; `verification[i]` (optional) is the export
    report of frontier point i."""
    points = []
    for i, c in enumerate(frontier):
        ver = (verification or {}).get(i, {})
        points.append({
            "point": i,
            "spec": c.spec.to_json(),
            "metrics": c.metrics,
            "plan": ver.get("plan"),
            "verified": bool(ver.get("verified", False)),
            "checked": bool(ver.get("checked", False)),
        })
    return {
        "schema": SEARCH_SCHEMA,
        "config": config,
        "baseline": baseline.to_json(),
        "evaluated": [c.to_json() for c in candidates],
        "frontier": points,
    }


def load_doc(path) -> dict:
    import json
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != SEARCH_SCHEMA:
        raise ValueError(f"{path}: schema {doc.get('schema')!r} is not "
                         f"{SEARCH_SCHEMA!r}")
    return doc


def frontier_table_rows(doc: dict) -> list:
    """Frontier points as `captrain.evalq.Table2Row`s (source="search")
    so searched operating points print alongside the PTQ/QAT baselines
    in the Table-2 harness format."""
    from repro.captrain.evalq import Table2Row
    cfg = doc["config"]
    base = doc["baseline"]["metrics"]
    rows = []
    for p in doc["frontier"]:
        spec = CandidateSpec.from_json(p["spec"])
        m = p["metrics"]
        rows.append(Table2Row(
            name=f"{cfg.get('model', '?')}#p{p['point']}",
            rounding=cfg.get("rounding", "floor"),
            acc_f32=float(doc.get("float_acc", float("nan"))),
            acc_ptq=float(m["acc"]),
            acc_qat=float(m.get("acc_qat", m["acc"])),
            saving_pct=100.0 * (1 - m["flash_packed_bytes"]
                                / max(1, base["flash_bytes"])),
            variant=(f"{spec.softmax or 'q7'}+"
                     f"{spec.squash or 'exact'}"),
            est_ms_m7=float(m["est_ms_m7"]),
            est_ms_gap8=float(m["est_ms_gap8"]),
            sat_pct=100.0 * float(m.get("sat_rate", float("nan"))),
            snr_db=float(m.get("snr_db", float("nan"))),
            flash_bytes=int(m["flash_bytes"]),
            ram_bytes=int(m["ram_bytes"]),
            source="search"))
    return rows


# ---------------------------------------------------------------------------
# point rebuild (the --from-search export path)
# ---------------------------------------------------------------------------
def rebuild_point(doc: dict, point: int):
    """Deterministically re-derive frontier point `point` from the doc's
    search config: re-run the seeded setup (train + calibrate), rebuild
    the candidate model, and assert its plan matches the stored one
    bit-for-bit.  Returns (qnet, point_entry, setup)."""
    entries = {p["point"]: p for p in doc["frontier"]}
    if point not in entries:
        raise ValueError(f"no frontier point {point}; doc has "
                         f"{sorted(entries)}")
    entry = entries[point]
    from repro.search.driver import SearchConfig, setup_space
    cfg = SearchConfig.from_json(doc["config"])
    st = setup_space(cfg)
    spec = CandidateSpec.from_json(entry["spec"])
    qnet = st.space.build_qnet(spec, rounding=cfg.rounding)
    got = plan_to_json(qnet.plan)
    if got != entry["plan"]:
        raise RuntimeError(
            f"rebuilt plan for point {point} drifted from the result "
            f"doc — the training/calibration path is no longer "
            f"deterministic for seed {cfg.seed}")
    return qnet, entry, st
