"""Candidate evaluation: one spec -> scored, verified `Candidate`.

The objective is where the search meets every verification layer the
repo already has.  A candidate is only `ok` if its lowered program
passes the static checker (`repro.analysis.check_program`), its probed
int8 run shows no int32 clipping and bounded saturation
(`repro.obs.numerics`), and the static bounds actually contained the
observed extremes (`check_containment`).  Scoring covers the paper's
three axes — accuracy (`captrain.evalq`), memory (`edge.arena`), and
estimated MCU latency (`edge.costmodel`) — plus `flash_packed_bytes`,
the virtual-bit-packed weight footprint that makes Q-CapsNets-style
frac reduction visible as a memory win even though the on-device
container stays int8.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.analysis import check_program
from repro.captrain.evalq import eval_q7
from repro.edge import lower, total_latency_ms
from repro.edge.arena import memory_report
from repro.obs.numerics import check_containment, run_numerics
from repro.search.space import CandidateSpec, SearchSpace

# reject candidates whose worst per-site saturation rate exceeds this
# (the numerics telemetry's "red" band; the default plan sits well below)
SAT_THRESHOLD = 0.35


def flash_packed_bytes(program) -> int:
    """Flash footprint with each weight blob packed at its *virtual*
    bit-width: the smallest signed width (>= 2 bits) holding the blob's
    actual int range.  Frac-bit reduction shrinks the occupied grid, so
    this is the memory axis where Q-CapsNets-style coarsening pays off
    — the int8-container `flash_bytes` only credits per-tensor pruning.
    Attr tables (the non-weight flash) are counted as-is."""
    packed = 0
    for op in program.ops:
        for w in op.weights.values():
            if w.dtype == np.int8:
                peak = int(np.abs(w.astype(np.int32)).max())
                bits = max(2, 1 + math.ceil(math.log2(peak + 1))) \
                    if peak else 2
                packed += math.ceil(int(w.size) * bits / 8)
            else:                       # int32 bias etc.: container width
                packed += int(w.nbytes)
    return packed + (program.flash_bytes - program.weight_bytes)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One evaluated spec: metrics + the verification verdict.  Rejected
    candidates keep their metrics (when computable) so the result doc
    shows *why* the space's edges are infeasible."""
    spec: CandidateSpec
    metrics: dict
    ok: bool
    reject_reason: str = ""

    def to_json(self) -> dict:
        return {"spec": self.spec.to_json(), "metrics": self.metrics,
                "ok": self.ok, "reject_reason": self.reject_reason}


class Objective:
    """Scores specs against one trained network + eval set, caching by
    spec identity so strategies can revisit points for free (the budget
    counts *unique* evaluations)."""

    def __init__(self, space: SearchSpace, images, labels, *,
                 rounding: str = "floor", numerics_n: int = 64,
                 sat_threshold: float = SAT_THRESHOLD, qat_eval=None):
        self.space = space
        self.images = np.asarray(images)
        self.labels = np.asarray(labels)
        self.rounding = rounding
        self.numerics_n = numerics_n
        self.sat_threshold = sat_threshold
        self.qat_eval = qat_eval        # spec -> QAT-refined accuracy
        self.cache: dict = {}
        self.evaluations = 0            # unique (non-cached) evaluations

    def evaluate(self, spec: CandidateSpec) -> Candidate:
        if spec.key in self.cache:
            return self.cache[spec.key]
        from repro import obs
        with obs.span("search.candidate", spec=spec.key):
            with obs.span("search.evaluate"):
                cand = self._evaluate(spec)
        self.evaluations += 1
        self.cache[spec.key] = cand
        return cand

    def _evaluate(self, spec: CandidateSpec) -> Candidate:
        qnet = self.space.build_qnet(spec, rounding=self.rounding)
        program = lower(qnet)
        metrics: dict = {}

        result = check_program(program)
        metrics["checker_findings"] = len(result.diagnostics)
        if not result.ok:
            return Candidate(spec, metrics, False,
                             "static checker: " + "; ".join(
                                 str(d) for d in result.diagnostics[:3]))

        mem = memory_report(program)
        metrics.update(
            flash_bytes=int(mem["flash_bytes"]),
            flash_packed_bytes=flash_packed_bytes(program),
            ram_bytes=int(mem["ram_bytes"]),
            arena_bytes=int(mem["arena_bytes"]),
            est_ms_m7=total_latency_ms(program, "cortex-m7"),
            est_ms_gap8=total_latency_ms(program, "gap8"))

        # probed pass: saturation/clip telemetry + q7-vs-f32 SNR, and the
        # static ranges must have contained what actually happened
        health = run_numerics(qnet, self.images[:self.numerics_n],
                              params=self.space.params, program=program)
        metrics.update(
            int32_clip=int(health.total_int32_clip()),
            sat_rate=float(health.worst_saturation_rate()),
            snr_db=float(health.min_snr_db()))
        if metrics["int32_clip"] > 0:
            return Candidate(spec, metrics, False,
                             f"numerics: {metrics['int32_clip']} int32 "
                             f"clip events")
        if metrics["sat_rate"] > self.sat_threshold:
            return Candidate(spec, metrics, False,
                             f"numerics: saturation {metrics['sat_rate']:.3f}"
                             f" > {self.sat_threshold}")
        contain = check_containment(program, health)
        if contain:
            return Candidate(spec, metrics, False,
                             "containment: " + "; ".join(contain[:3]))

        metrics["acc"] = eval_q7(qnet, self.images, self.labels)
        if self.qat_eval is not None:   # optional QAT-refined face
            metrics["acc_qat"] = float(self.qat_eval(spec))
        return Candidate(spec, metrics, True)
