"""Import a `.capsbin` artifact back into a servable `QuantCapsNet`.

`lower()` is a lossless flattening: every op record carries the full
typed plan and the int8 blobs.  This module is its inverse — rebuild the
`CapsNetConfig` geometry from the schedule, re-type the attrs into
Conv/PrimaryCaps/Routing plans, and wrap the blobs into a
`QuantCapsNet` — so the serving engine can serve EXACTLY the artifact
`export_caps` shipped (`ModelRegistry.install_artifact`), not a model
that was merely quantized the same way.

Round-trip contract (pinned in tests/test_edge.py):
  program -> to_qnet -> lower  ==  program   (same_as, bit for bit)
  to_qnet(program).forward     ==  EdgeVM(program).run
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.edge.program import EdgeProgram
from repro.nn.config import CapsNetConfig
from repro.nn.pipeline import CapsPipeline, QuantCapsNet
from repro.nn.plans import ConvPlan, PipelinePlan, PrimaryCapsPlan, \
    RoutingPlan
from repro.nn.variants import REGISTRY as _VARIANTS


def _impl(attrs: dict, kind: str) -> str:
    """An op's variant reference, defaulted for pre-variant artifacts
    (shared registry accessor); a tampered/unknown name is rejected
    with the registered ones listed."""
    return _VARIANTS.from_attrs(kind, attrs).name


def _conv_plan(attrs: dict) -> ConvPlan:
    return ConvPlan(
        in_frac=attrs["in_frac"], w_frac=attrs["w_frac"],
        b_frac=attrs["b_frac"], out_frac=attrs["out_frac"],
        out_shift=attrs["out_shift"], bias_shift=attrs["bias_shift"],
        w_frac_per_channel=tuple(attrs.get("w_frac_per_channel", ())),
        out_shift_per_channel=tuple(attrs.get("out_shift_per_channel", ())),
        bias_shift_per_channel=tuple(
            attrs.get("bias_shift_per_channel", ())))


def program_config(program: EdgeProgram) -> CapsNetConfig:
    """Rebuild the geometry config the program was lowered from."""
    convs = [op for op in program.ops if op.kind == "CONV_Q7"]
    pcaps = [op for op in program.ops if op.kind == "PRIMARY_CAPS_Q7"]
    routs = [op for op in program.ops if op.kind == "CAPS_ROUTING_Q7"]
    if len(pcaps) != 1 or len(routs) != 1:
        raise ValueError(
            f"{program.name}: expected one PRIMARY_CAPS_Q7 and one "
            f"CAPS_ROUTING_Q7 op, got {len(pcaps)}/{len(routs)} — not a "
            "pipeline this importer can rebuild")
    pc, rt = pcaps[0].attrs, routs[0].attrs
    cfg = CapsNetConfig(
        name=program.name,
        input_shape=tuple(program.input_tensor.shape),
        conv_filters=tuple(op.attrs["out_ch"] for op in convs),
        conv_kernels=tuple(op.attrs["kernel"] for op in convs),
        conv_strides=tuple(op.attrs["stride"] for op in convs),
        pcap_caps=pc["caps"], pcap_dim=pc["dim"],
        pcap_kernel=pc["kernel"], pcap_stride=pc["stride"],
        num_classes=rt["num_out"], caps_dim=rt["out_dim"],
        routings=rt["routings"])
    if cfg.num_input_caps != rt["num_in"]:
        raise ValueError(
            f"{program.name}: geometry mismatch — schedule implies "
            f"{cfg.num_input_caps} input capsules, routing op says "
            f"{rt['num_in']}")
    return cfg


def to_qnet(program: EdgeProgram, *, check: bool = True) -> QuantCapsNet:
    """EdgeProgram -> QuantCapsNet executing bit-identically to the VM.

    check (default on): run the static verifier first
    (repro.analysis.check_program), so a tampered or miscompiled
    artifact is rejected with op/tensor-precise diagnostics
    (CheckError, a ValueError) instead of being served."""
    if check:
        from repro.analysis import check_program
        check_program(program).raise_if_failed()
    cfg = program_config(program)
    routing = next(op for op in program.ops
                   if op.kind == "CAPS_ROUTING_Q7")
    per_channel = any("w_frac_per_channel" in op.attrs
                      for op in program.ops)
    pipeline = CapsPipeline.from_config(
        cfg, softmax_impl=_impl(routing.attrs, "softmax"),
        squash_impl=_impl(routing.attrs, "squash"),
        per_channel=per_channel)

    plans, qweights = {}, {}
    if len(pipeline.layers) != len(program.ops):
        raise ValueError(f"{program.name}: {len(program.ops)} ops for "
                         f"{len(pipeline.layers)} pipeline layers")
    for layer, op in zip(pipeline.layers, program.ops):
        a = op.attrs
        if op.kind == "CONV_Q7":
            plans[layer.name] = _conv_plan(a)
        elif op.kind == "PRIMARY_CAPS_Q7":
            plans[layer.name] = PrimaryCapsPlan(
                conv=_conv_plan(a), squash_out_frac=a["squash_out_frac"],
                squash_impl=_impl(a, "squash"))
        else:
            plans[layer.name] = RoutingPlan(
                uhat_shift=a["uhat_shift"], logit_frac=a["logit_frac"],
                caps_out_shifts=tuple(a["caps_out_shifts"]),
                caps_out_fracs=tuple(a["caps_out_fracs"]),
                agree_shifts=tuple(a["agree_shifts"]),
                softmax_impl=_impl(a, "softmax"), in_frac=a["in_frac"],
                W_frac=a["W_frac"], uhat_frac=a["uhat_frac"],
                squash_out_frac=a["squash_out_frac"],
                squash_impl=_impl(a, "squash"),
                W_frac_per_out=tuple(a.get("W_frac_per_out", ())),
                uhat_shift_per_out=tuple(
                    a.get("uhat_shift_per_out", ())))
        qweights[layer.name] = {k: jnp.asarray(w)
                                for k, w in op.weights.items()}

    plan = PipelinePlan(input_frac=program.input_frac, layers=plans)
    return QuantCapsNet(pipeline=pipeline, plan=plan, qweights=qweights,
                        rounding=program.rounding, backend="jnp")


def load_qnet(path, *, check: bool = True) -> QuantCapsNet:
    """One-call `.capsbin` file -> servable model (statically checked
    unless check=False)."""
    return to_qnet(EdgeProgram.load(path), check=check)
