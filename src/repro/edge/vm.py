"""EdgeVM — a pure-NumPy q7 interpreter for `EdgeProgram`s.

Executes the exported schedule with CMSIS-NN integer semantics — int8
operands, int32 accumulation, power-of-two arithmetic shift, saturation
to [-128, 127] — re-implemented here without jax so an artifact can be
verified on any host, exactly the way the MCU kernels would run it.

Bit-exactness contract: for programs lowered from a `QuantCapsNet`,
`EdgeVM(program).run(x_q)` equals `qnet.forward(x_q)` bit for bit, for
both rounding modes and per-tensor or per-channel conv plans
(tests/test_edge.py pins this for all paper configs).  The only
non-integer operator is the beyond-paper "precise" softmax variant,
which uses float32 like its jnp counterpart and is therefore matched in
value but not guaranteed to the last bit.
"""
from __future__ import annotations

import numpy as np

from repro.edge.program import EdgeOp, EdgeProgram

INT8_MIN, INT8_MAX = -128, 127
_SQUASH_GUARD_BITS = 10             # must match quant.int8_ops


# ---------------------------------------------------------------------------
# integer primitives (NumPy mirrors of repro.quant.int8_ops)
# ---------------------------------------------------------------------------
def _sat8(x):
    return np.clip(x, INT8_MIN, INT8_MAX).astype(np.int8)


def _rshift_sat8(acc, shift: int, rounding: str):
    acc = acc.astype(np.int32)
    if shift > 0:
        if rounding == "nearest":
            acc = acc + (1 << (shift - 1))
        acc = np.right_shift(acc, shift)
    elif shift < 0:
        acc = np.left_shift(acc, -shift)
    return _sat8(acc)


def _rshift_sat8_vec(acc, shifts, rounding: str):
    """Per-lane (per-channel) variant; mirrors int8_ops.rshift_sat8_vec."""
    acc = acc.astype(np.int32)
    shifts = np.asarray(shifts, np.int32)
    if rounding == "nearest":
        half = np.left_shift(np.int32(1), np.maximum(shifts - 1, 0))
        acc = acc + np.where(shifts > 0, half, 0)
    acc = np.right_shift(acc, np.maximum(shifts, 0))
    acc = np.left_shift(acc, np.maximum(-shifts, 0))
    return _sat8(acc)


def _conv2d_acc(x, w, stride: int):
    """VALID NHWC int conv via im2col, int32 accumulation (wrap-on-
    overflow, same as the XLA int32 conv — though no exported geometry
    gets near 2^31)."""
    kh, kw = w.shape[0], w.shape[1]
    win = np.lib.stride_tricks.sliding_window_view(x, (kh, kw), axis=(1, 2))
    win = win[:, ::stride, ::stride]            # [B,Ho,Wo,Cin,kh,kw]
    return np.einsum("bhwcij,ijco->bhwo", win.astype(np.int32),
                     w.astype(np.int32), dtype=np.int32)


def _isqrt_newton(n):
    """Vectorized Alg. 4 integer sqrt; mirrors int8_ops.isqrt_newton
    (fixed 32 Newton steps with the monotonicity guard)."""
    n = n.astype(np.int32)
    x = np.maximum(n // 2, 1)
    for _ in range(32):
        nxt = (x + n // np.maximum(x, 1)) // 2
        x = np.where(nxt < x, nxt, x)
    return np.where(n <= 1, n, x)


def _squash_q7(s, in_frac: int, out_frac: int):
    s32 = s.astype(np.int32)
    Q = np.sum(s32 * s32, axis=-1, keepdims=True, dtype=np.int32)
    S = _isqrt_newton(Q)
    P = _SQUASH_GUARD_BITS
    shift = out_frac - in_frac + P
    num = np.left_shift(S, shift) if shift >= 0 \
        else np.right_shift(S, -shift)
    den = (1 << in_frac) + np.right_shift(Q, in_frac)
    ratio = num // np.maximum(den, 1)
    v = np.right_shift(ratio * s32, P)
    return _sat8(v)


def _softmax_q7(x, in_frac: int):
    x32 = x.astype(np.int32)
    m = np.max(x32, axis=-1, keepdims=True)
    e = np.maximum(np.right_shift(x32 - m, in_frac), -20)
    p = np.left_shift(np.ones_like(e), 20 + e)
    tot = np.sum(p, axis=-1, keepdims=True, dtype=np.int32)
    c = np.left_shift(p, 7) // np.maximum(tot, 1)
    return np.clip(c, 0, INT8_MAX).astype(np.int8)


def _softmax_q7_precise(x, in_frac: int):
    xf = x.astype(np.float32) * np.float32(2.0 ** -in_frac)
    xf = xf - xf.max(axis=-1, keepdims=True)
    p = np.exp(xf)
    p = p / p.sum(axis=-1, keepdims=True)
    c = np.round(p.astype(np.float32) * 128.0)
    return np.clip(c, 0, INT8_MAX).astype(np.int8)


def _add_q7(a, b):
    return _sat8(a.astype(np.int32) + b.astype(np.int32))


# ---------------------------------------------------------------------------
# op execution
# ---------------------------------------------------------------------------
def _run_conv(op: EdgeOp, x, rounding: str, relu_override=None):
    a = op.attrs
    acc = _conv2d_acc(x, op.weights["w"], a["stride"])
    bias = op.weights["b"].astype(np.int32)
    if a.get("bias_shift_per_channel"):
        bs = np.asarray(a["bias_shift_per_channel"], np.int32)
        bias = np.left_shift(bias, np.maximum(bs, 0))
        bias = np.right_shift(bias, np.maximum(-bs, 0))
        acc = acc + bias
        y = _rshift_sat8_vec(acc, a["out_shift_per_channel"], rounding)
    else:
        bs = a["bias_shift"]
        bias = np.left_shift(bias, bs) if bs >= 0 \
            else np.right_shift(bias, -bs)
        acc = acc + bias
        y = _rshift_sat8(acc, a["out_shift"], rounding)
    relu = a["relu"] if relu_override is None else relu_override
    return np.maximum(y, 0).astype(np.int8) if relu else y


def _run_primary_caps(op: EdgeOp, x, rounding: str):
    a = op.attrs
    y = _run_conv(op, x, rounding, relu_override=False)
    u = y.reshape(y.shape[0], -1, a["dim"])
    return _squash_q7(u, a["squash_in_frac"], a["squash_out_frac"])


def _run_routing(op: EdgeOp, u, rounding: str):
    a = op.attrs
    W = op.weights["W"].astype(np.int32)
    acc = np.einsum("jiod,bid->bjio", W, u.astype(np.int32),
                    dtype=np.int32)
    u_hat = _rshift_sat8(acc, a["uhat_shift"], rounding)

    out_frac = a["squash_out_frac"]
    softmax = _softmax_q7 if a["softmax_impl"] == "q7" \
        else _softmax_q7_precise
    b = np.zeros(u_hat.shape[:3], np.int8)
    v = None
    for r in range(a["routings"]):
        c = softmax(b.swapaxes(1, 2), a["logit_frac"]).swapaxes(1, 2)
        acc = np.einsum("bji,bjio->bjo", c.astype(np.int32),
                        u_hat.astype(np.int32), dtype=np.int32)
        s_q = _rshift_sat8(acc, a["caps_out_shifts"][r], rounding)
        v = _squash_q7(s_q, a["caps_out_fracs"][r], out_frac)
        if r < a["routings"] - 1:
            acc = np.einsum("bjio,bjo->bji", u_hat.astype(np.int32),
                            v.astype(np.int32), dtype=np.int32)
            # agree_shifts assume a Q0.7 squash; compensate plan edits
            # exactly like the jnp backend does
            agr = _rshift_sat8(acc, a["agree_shifts"][r] + out_frac - 7,
                               rounding)
            b = _add_q7(b, agr)
    return v


_RUNNERS = {
    "CONV_Q7": _run_conv,
    "PRIMARY_CAPS_Q7": _run_primary_caps,
    "CAPS_ROUTING_Q7": _run_routing,
}


class EdgeVM:
    """Interpreter for one EdgeProgram.

        vm = EdgeVM(lower(qnet))
        v_q = vm.run(x_q)           # int8 [B, classes, caps_dim]

    `run` accepts a single sample (the program's per-sample input shape)
    or a batch with a leading axis, always as int8 already quantized to
    the program's input format (use `quantize_input` for floats)."""

    def __init__(self, program: EdgeProgram):
        self.program = program

    def quantize_input(self, x) -> np.ndarray:
        q = np.round(np.asarray(x, np.float32)
                     * (2.0 ** self.program.input_frac))
        return np.clip(q, INT8_MIN, INT8_MAX).astype(np.int8)

    def run(self, x_q: np.ndarray, *, trace: dict | None = None):
        p = self.program
        x_q = np.asarray(x_q)
        if x_q.dtype != np.int8:
            raise TypeError(f"EdgeVM.run wants int8 input in the "
                            f"program's Q format, got {x_q.dtype}")
        squeeze = x_q.shape == p.input_tensor.shape
        h = x_q[None] if squeeze else x_q
        if h.shape[1:] != p.input_tensor.shape:
            raise ValueError(f"input shape {x_q.shape} does not match "
                             f"program input {p.input_tensor.shape}")
        for op in p.ops:
            h = _RUNNERS[op.kind](op, h, p.rounding)
            if trace is not None:
                trace[op.name] = h
        return h[0] if squeeze else h


def execute(program: EdgeProgram, x_q) -> np.ndarray:
    """One-shot convenience: EdgeVM(program).run(x_q)."""
    return EdgeVM(program).run(x_q)
