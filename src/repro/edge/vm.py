"""EdgeVM — a pure-NumPy q7 interpreter for `EdgeProgram`s.

Executes the exported schedule with CMSIS-NN integer semantics — int8
operands, int32 accumulation, power-of-two arithmetic shift, saturation
to [-128, 127] — in pure NumPy, exactly the way the MCU kernels would
run it.  Softmax/squash operators are resolved through the
operator-variant registry's NumPy faces (`repro.nn.variants`, the same
single source of truth the jnp backends and the C emitter read), so a
schedule naming an unregistered variant fails loudly with the
registered names listed instead of silently mis-executing.

Bit-exactness contract: for programs lowered from a `QuantCapsNet`,
`EdgeVM(program).run(x_q)` equals `qnet.forward(x_q)` bit for bit, for
both rounding modes, per-tensor or per-channel conv plans, and every
registered operator variant (tests/test_edge.py + tests/test_variants.py
pin this).  The only non-integer operator is the beyond-paper "precise"
softmax variant, which uses float32 like its jnp counterpart and is
therefore matched in value but not guaranteed to the last bit.
"""
from __future__ import annotations

import time

import numpy as np

from repro import obs
from repro.obs import numerics as _health
from repro.edge.program import EdgeOp, EdgeProgram
from repro.nn.variants import REGISTRY as _VARIANTS

INT8_MIN, INT8_MAX = -128, 127


def _np_variant(kind: str, attrs: dict):
    """Resolve an op's variant attr to its NumPy face (shared registry
    accessor: defaults for pre-variant artifacts, raises with the
    registered names listed for unknown ones)."""
    return _VARIANTS.from_attrs(kind, attrs).np_q7


# ---------------------------------------------------------------------------
# integer primitives (NumPy mirrors of repro.quant.int8_ops; the
# softmax/squash mirrors live with their variants in repro.nn.variants)
# ---------------------------------------------------------------------------
def _sat8(x):
    return np.clip(x, INT8_MIN, INT8_MAX).astype(np.int8)


def _rshift_sat8(acc, shift: int, rounding: str):
    acc = acc.astype(np.int32)
    if shift > 0:
        if rounding == "nearest":
            acc = acc + (1 << (shift - 1))
        acc = np.right_shift(acc, shift)
    elif shift < 0:
        acc = np.left_shift(acc, -shift)
    return _sat8(acc)


def _rshift_sat8_vec(acc, shifts, rounding: str):
    """Per-lane (per-channel) variant; mirrors int8_ops.rshift_sat8_vec."""
    acc = acc.astype(np.int32)
    shifts = np.asarray(shifts, np.int32)
    if rounding == "nearest":
        half = np.left_shift(np.int32(1), np.maximum(shifts - 1, 0))
        acc = acc + np.where(shifts > 0, half, 0)
    acc = np.right_shift(acc, np.maximum(shifts, 0))
    acc = np.left_shift(acc, np.maximum(-shifts, 0))
    return _sat8(acc)


def _conv2d_acc(x, w, stride: int):
    """VALID NHWC int conv via im2col, int32 accumulation (wrap-on-
    overflow, same as the XLA int32 conv; `_assert_acc_bound` enforces
    the statically-proven bound lower() records, so a geometry that
    could wrap is rejected rather than silently wrong)."""
    kh, kw = w.shape[0], w.shape[1]
    win = np.lib.stride_tricks.sliding_window_view(x, (kh, kw), axis=(1, 2))
    win = win[:, ::stride, ::stride]            # [B,Ho,Wo,Cin,kh,kw]
    return np.einsum("bhwcij,ijco->bhwo", win.astype(np.int32),
                     w.astype(np.int32), dtype=np.int32)


def _add_q7(a, b):
    return _sat8(a.astype(np.int32) + b.astype(np.int32))


# ---------------------------------------------------------------------------
# op execution
# ---------------------------------------------------------------------------
def _run_conv(op: EdgeOp, x, rounding: str, relu_override=None):
    a = op.attrs
    acc = _conv2d_acc(x, op.weights["w"], a["stride"])
    bias = op.weights["b"].astype(np.int32)
    if a.get("bias_shift_per_channel"):
        bs = np.asarray(a["bias_shift_per_channel"], np.int32)
        bias = np.left_shift(bias, np.maximum(bs, 0))
        bias = np.right_shift(bias, np.maximum(-bs, 0))
    else:
        bs = a["bias_shift"]
        bias = np.left_shift(bias, bs) if bs >= 0 \
            else np.right_shift(bias, -bs)
    acc = acc + bias
    _assert_acc_bound(op, acc)
    if _health._PROBE is not None:     # pure observer — never alters acc
        _health._PROBE.observe_requant(
            acc, a.get("out_shift_per_channel") or a["out_shift"],
            rounding, site="out", bound=a.get("acc_bound"))
    if a.get("out_shift_per_channel"):
        y = _rshift_sat8_vec(acc, a["out_shift_per_channel"], rounding)
    else:
        y = _rshift_sat8(acc, a["out_shift"], rounding)
    relu = a["relu"] if relu_override is None else relu_override
    return np.maximum(y, 0).astype(np.int8) if relu else y


def _assert_acc_bound(op: EdgeOp, acc) -> None:
    """`lower()` records the statically-derived worst-case |int32
    accumulator| (repro.analysis.ranges) as an `acc_bound` attr; the VM
    enforces it so a wrap the checker proved impossible can never
    happen silently here either (pre-acc_bound artifacts skip it)."""
    bound = op.attrs.get("acc_bound")
    if bound is None or not acc.size:
        return
    peak = int(np.abs(acc.astype(np.int64)).max())
    if peak > bound:
        raise AssertionError(
            f"{op.name}: |int32 accumulator| reached {peak}, above the "
            f"statically derived acc_bound {bound} — the program's "
            f"attrs disagree with its weights; rerun "
            f"repro.analysis.check_program on this artifact")


def _run_primary_caps(op: EdgeOp, x, rounding: str):
    a = op.attrs
    y = _run_conv(op, x, rounding, relu_override=False)
    u = y.reshape(y.shape[0], -1, a["dim"])
    return _np_variant("squash", a)(u, a["squash_in_frac"],
                                    a["squash_out_frac"])


def _run_routing(op: EdgeOp, u, rounding: str):
    a = op.attrs
    W = op.weights["W"].astype(np.int32)
    acc = np.einsum("jiod,bid->bjio", W, u.astype(np.int32),
                    dtype=np.int32)
    if a.get("uhat_shift_per_out"):
        # per-output-capsule W formats (RoutingPlan.per_out): acc is
        # [B,J,I,O], so the length-J table must broadcast on axis 1
        sh = np.asarray(a["uhat_shift_per_out"], np.int32)[None, :, None,
                                                           None]
        if _health._PROBE is not None:
            _health._PROBE.observe_requant(acc, sh, rounding, site="uhat")
        u_hat = _rshift_sat8_vec(acc, sh, rounding)
    else:
        if _health._PROBE is not None:
            _health._PROBE.observe_requant(acc, a["uhat_shift"], rounding,
                                           site="uhat")
        u_hat = _rshift_sat8(acc, a["uhat_shift"], rounding)

    out_frac = a["squash_out_frac"]
    softmax = _np_variant("softmax", a)
    squash = _np_variant("squash", a)
    b = np.zeros(u_hat.shape[:3], np.int8)
    v = None
    for r in range(a["routings"]):
        c = softmax(b.swapaxes(1, 2), a["logit_frac"]).swapaxes(1, 2)
        acc = np.einsum("bji,bjio->bjo", c.astype(np.int32),
                        u_hat.astype(np.int32), dtype=np.int32)
        if _health._PROBE is not None:
            _health._PROBE.observe_requant(acc, a["caps_out_shifts"][r],
                                           rounding, site=f"s[{r}]")
        s_q = _rshift_sat8(acc, a["caps_out_shifts"][r], rounding)
        v = squash(s_q, a["caps_out_fracs"][r], out_frac)
        if r < a["routings"] - 1:
            acc = np.einsum("bjio,bjo->bji", u_hat.astype(np.int32),
                            v.astype(np.int32), dtype=np.int32)
            # agree_shifts assume a Q0.7 squash; compensate plan edits
            # exactly like the jnp backend does
            if _health._PROBE is not None:
                _health._PROBE.observe_requant(
                    acc, a["agree_shifts"][r] + out_frac - 7, rounding,
                    site=f"agree[{r}]")
            agr = _rshift_sat8(acc, a["agree_shifts"][r] + out_frac - 7,
                               rounding)
            b = _add_q7(b, agr)
    return v


_RUNNERS = {
    "CONV_Q7": _run_conv,
    "PRIMARY_CAPS_Q7": _run_primary_caps,
    "CAPS_ROUTING_Q7": _run_routing,
}


class EdgeVM:
    """Interpreter for one EdgeProgram.

        vm = EdgeVM(lower(qnet))
        v_q = vm.run(x_q)           # int8 [B, classes, caps_dim]

    `run` accepts a single sample (the program's per-sample input shape)
    or a batch with a leading axis, always as int8 already quantized to
    the program's input format (use `quantize_input` for floats).

    Profile rows carry `op_index` (schedule position) next to name/kind
    — the join key `repro.obs.analyze.costmodel_drift` uses to line
    measured rows up against `costmodel.estimate_program` rows."""

    def __init__(self, program: EdgeProgram):
        self.program = program

    def quantize_input(self, x) -> np.ndarray:
        q = np.round(np.asarray(x, np.float32)
                     * (2.0 ** self.program.input_frac))
        return np.clip(q, INT8_MIN, INT8_MAX).astype(np.int8)

    def run(self, x_q: np.ndarray, *, trace: dict | None = None,
            profile: list | None = None):
        """Execute the schedule.  `trace` captures every intermediate
        activation (tests use it to pin per-layer bits).  `profile`
        appends one {"op_index", "name", "kind", "wall_s"} row per op
        — the measured
        host-side counterpart of the static `costmodel` estimate.  Both
        are pure observation: the op loop computes identical bits with
        or without them, and when neither is requested (and no ambient
        obs tracer is installed) the plain loop runs untouched."""
        p = self.program
        x_q = np.asarray(x_q)
        if x_q.dtype != np.int8:
            raise TypeError(f"EdgeVM.run wants int8 input in the "
                            f"program's Q format, got {x_q.dtype}")
        squeeze = x_q.shape == p.input_tensor.shape
        h = x_q[None] if squeeze else x_q
        if h.shape[1:] != p.input_tensor.shape:
            raise ValueError(f"input shape {x_q.shape} does not match "
                             f"program input {p.input_tensor.shape}")
        probe = _health._PROBE
        if trace is None and profile is None and probe is None \
                and obs.get_tracer() is None:
            for op in p.ops:                     # hot path: zero obs cost
                h = _RUNNERS[op.kind](op, h, p.rounding)
            return h[0] if squeeze else h
        with obs.span("edgevm.run", program=p.name, batch=h.shape[0]):
            for i, op in enumerate(p.ops):
                if probe is not None:
                    probe.begin_op(i, op.name, op.kind)
                with obs.span(f"edgevm.{op.name}", kind=op.kind):
                    t0 = time.perf_counter()
                    h = _RUNNERS[op.kind](op, h, p.rounding)
                    wall = time.perf_counter() - t0
                if probe is not None:
                    probe.observe_output(h, frac=p.tensor(op.output).frac)
                if profile is not None:
                    profile.append({"op_index": i, "name": op.name,
                                    "kind": op.kind, "wall_s": wall})
                if trace is not None:
                    trace[op.name] = h
        return h[0] if squeeze else h


def execute(program: EdgeProgram, x_q) -> np.ndarray:
    """One-shot convenience: EdgeVM(program).run(x_q)."""
    return EdgeVM(program).run(x_q)
