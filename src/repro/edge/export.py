"""One-call export: QuantCapsNet -> on-disk MCU artifact, verified.

    result = export_artifacts(qnet, out_dir, stem="edge_tiny",
                              verify_images=images)

writes `<stem>.capsbin` + `<stem>.manifest.json` + `<stem>.c/.h`,
reloads the binary from disk, and re-verifies the reloaded program in
the NumPy VM against `qnet.forward` bit for bit — so "it exported"
always means "the artifact executes identically", with no hardware in
the loop.
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from repro import obs
from repro.edge.arena import format_report, memory_report, plan_arena
from repro.edge.emit_c import save_c
from repro.edge.lower import lower
from repro.edge.program import EdgeProgram
from repro.edge.vm import EdgeVM


def export_artifacts(qnet, out_dir, stem: str | None = None, *,
                     verify_images=None, check: bool = True) -> dict:
    """Lower, plan, statically check, serialize, emit C, and
    (optionally) verify.

    check (default on): run the full static verifier
    (repro.analysis.check_program — int32 range proofs, plan shift
    algebra, arena aliasing) on the lowered program BEFORE anything is
    written; findings raise a CheckError listing every diagnostic.

    verify_images: float images [N,H,W,C] in [0,1]; when given, the
    `.capsbin` is reloaded from disk and executed in the EdgeVM, and a
    mismatch with `qnet.forward` raises — a failed export never leaves a
    silently-wrong artifact behind.  Returns paths, the memory report,
    and the number of verified images."""
    out_dir = Path(out_dir)
    with obs.span("export.lower"):
        program = lower(qnet, name=stem)
    stem = program.name
    with obs.span("export.arena", program=stem):
        plan = plan_arena(program)

    if check:
        from repro.analysis import check_program
        with obs.span("export.check", program=stem):
            check_program(program, arena=plan).raise_if_failed()

    with obs.span("export.save", program=stem):
        paths = program.save(out_dir / stem)
    with obs.span("export.emit_c", program=stem):
        paths.update(save_c(program, out_dir, plan))
    report = memory_report(program, plan)

    verified = 0
    if verify_images is not None:
        with obs.span("export.verify", program=stem):
            reloaded = EdgeProgram.load(paths["capsbin"])
            if not program.same_as(reloaded):
                raise AssertionError(f"{paths['capsbin']}: serialize/load "
                                     "round-trip changed the program")
            x_q = np.asarray(qnet.quantize_input(np.asarray(verify_images)))
            v_vm = EdgeVM(reloaded).run(x_q)
            v_host = np.asarray(qnet.forward(x_q))
            if not np.array_equal(v_vm, v_host):
                raise AssertionError(
                    f"{paths['capsbin']}: VM output differs from "
                    f"QuantCapsNet.forward on {len(x_q)} verify images "
                    f"(max |diff| "
                    f"{np.abs(v_vm.astype(np.int32) - v_host.astype(np.int32)).max()})")
            verified = int(len(x_q))

    return {"paths": paths, "report": report, "program": program,
            "arena": plan, "verified": verified, "checked": check}


def format_export(result: dict) -> str:
    lines = [format_report(result["report"])]
    lines.append("  artifacts: "
                 + ", ".join(str(p) for p in result["paths"].values()))
    if result.get("checked"):
        lines.append("  static checks clean (repro.analysis: ranges, "
                     "plan, arena)")
    if result["verified"]:
        lines.append(f"  VM re-verified bit-exact on "
                     f"{result['verified']} images (reloaded from disk)")
    return "\n".join(lines)
