"""CMSIS-NN-style C emitter for `EdgeProgram`s.

Emits a self-contained `.c`/`.h` pair in the idiom of the paper's
deployment target: `const q7_t` weight arrays in flash, the shift and
format decisions as `#define`s, a static activation arena laid out by
the planner, and an ordered layer-call schedule against the paper's
kernel API — `arm_convolve_HWC_q7_basic` / `arm_relu_q7` from CMSIS-NN
plus the paper's capsule extensions (`capsnet_squash_q7`,
`capsnet_dynamic_routing_q7`, and the per-channel conv variant).  The
kernel implementations are the MCU vendor library's; the generated file
declares their prototypes so the artifact documents the exact contract.

Output is deterministic for a given program (golden-file tested).
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.edge.arena import ArenaPlan, plan_arena
from repro.edge.program import EdgeOp, EdgeProgram
from repro.nn.variants import REGISTRY as _VARIANTS

_PER_LINE = 12

_PROTOTYPES = """\
/* CMSIS-NN kernels (vendor library).  Shifts are int16_t, not CMSIS's
 * uint16_t: virtual Qm.n formats (paper Sec. 4) make bias_shift negative
 * when the bias format exceeds the accumulator's, meaning a right
 * shift of the bias instead of a left one. */
void arm_convolve_HWC_q7_basic(const q7_t *Im_in, uint16_t dim_im_in,
    uint16_t ch_im_in, const q7_t *wt, uint16_t ch_im_out,
    uint16_t dim_kernel, uint16_t padding, uint16_t stride,
    const q7_t *bias, int16_t bias_shift, int16_t out_shift,
    q7_t *Im_out, uint16_t dim_im_out, q15_t *bufferA, q7_t *bufferB);
void arm_relu_q7(q7_t *data, uint16_t size);
/* paper extensions to CMSIS-NN (Alg. 4/5, Eq. 8) */
void capsnet_convolve_HWC_q7_per_channel(const q7_t *Im_in,
    uint16_t dim_im_in, uint16_t ch_im_in, const q7_t *wt,
    uint16_t ch_im_out, uint16_t dim_kernel, uint16_t padding,
    uint16_t stride, const q7_t *bias, const int8_t *bias_shift_per_ch,
    const int8_t *out_shift_per_ch, q7_t *Im_out, uint16_t dim_im_out,
    q15_t *bufferA, q7_t *bufferB);
void capsnet_squash_q7(q7_t *caps, uint16_t num_caps, uint16_t caps_dim,
    uint16_t in_frac, uint16_t out_frac);
void capsnet_dynamic_routing_q7(const q7_t *u, const q7_t *W,
    uint16_t num_out, uint16_t num_in, uint16_t out_dim,
    uint16_t in_dim, uint16_t routings, int16_t uhat_shift,
    uint16_t logit_frac, const int8_t *caps_out_shifts,
    const int8_t *caps_out_fracs, const int8_t *agree_shifts,
    uint16_t squash_out_frac, q7_t *v_out, q7_t *bufferA);
"""

_SQUASH_PROTO = """\
void {sym}(q7_t *caps, uint16_t num_caps, uint16_t caps_dim,
    uint16_t in_frac, uint16_t out_frac);"""

_ROUTING_PROTO = """\
void {sym}(const q7_t *u, const q7_t *W,
    uint16_t num_out, uint16_t num_in, uint16_t out_dim,
    uint16_t in_dim, uint16_t routings, int16_t uhat_shift,
    uint16_t logit_frac, const int8_t *caps_out_shifts,
    const int8_t *caps_out_fracs, const int8_t *agree_shifts,
    uint16_t squash_out_frac, q7_t *v_out, q7_t *bufferA);"""

# per-output-capsule W formats (RoutingPlan.per_out): the u_hat
# requantization shift becomes a length-num_out table, one entry per
# output capsule (the routing analogue of the per-channel conv)
_ROUTING_PER_OUT_PROTO = """\
void {sym}(const q7_t *u, const q7_t *W,
    uint16_t num_out, uint16_t num_in, uint16_t out_dim,
    uint16_t in_dim, uint16_t routings,
    const int8_t *uhat_shift_per_out,
    uint16_t logit_frac, const int8_t *caps_out_shifts,
    const int8_t *caps_out_fracs, const int8_t *agree_shifts,
    uint16_t squash_out_frac, q7_t *v_out, q7_t *bufferA);"""


def _variant(kind: str, attrs: dict):
    return _VARIANTS.from_attrs(kind, attrs)


def _squash_symbol(attrs: dict) -> str:
    return _variant("squash", attrs).c_symbol


def _routing_symbol(attrs: dict) -> str:
    """The routing kernel symbol, suffixed per non-default operator
    variant (the ISLPED'22 approximate kernels are distinct entry
    points, so the artifact documents exactly which arithmetic ran) and
    per-out when the plan carries per-output-capsule W formats."""
    sym = ("capsnet_dynamic_routing_q7"
           + _variant("softmax", attrs).c_suffix
           + _variant("squash", attrs).c_suffix)
    if attrs.get("uhat_shift_per_out"):
        sym += "_per_out"
    return sym


def _variant_prototypes(program: EdgeProgram) -> list:
    """Prototypes for non-default variant kernels the schedule calls
    (deterministic: schedule order, deduped)."""
    protos = []
    for op in program.ops:
        if op.kind == "PRIMARY_CAPS_Q7" \
                and _variant("squash", op.attrs).c_suffix:
            protos.append(_SQUASH_PROTO.format(
                sym=_squash_symbol(op.attrs)))
        elif op.kind == "CAPS_ROUTING_Q7":
            sym = _routing_symbol(op.attrs)
            if sym != "capsnet_dynamic_routing_q7":
                proto = _ROUTING_PER_OUT_PROTO \
                    if op.attrs.get("uhat_shift_per_out") else _ROUTING_PROTO
                protos.append(proto.format(sym=sym))
    if not protos:
        return []
    seen, out = set(), ["/* ISLPED'22 approximate-operator variants "
                        "(repro.nn.variants) */"]
    for p in protos:
        if p not in seen:
            seen.add(p)
            out.append(p)
    out.append("")
    return out


def _carray(name: str, arr: np.ndarray, ctype: str) -> str:
    flat = arr.reshape(-1)
    lines = [f"const {ctype} {name}[{flat.size}] = {{"]
    for i in range(0, flat.size, _PER_LINE):
        chunk = ", ".join(str(int(v)) for v in flat[i:i + _PER_LINE])
        tail = "," if i + _PER_LINE < flat.size else ""
        lines.append(f"    {chunk}{tail}")
    lines.append("};")
    return "\n".join(lines)


def _defines(prefix: str, attrs: dict, keys) -> list:
    return [f"#define {prefix}_{k.upper()} {attrs[k]}"
            for k in keys if k in attrs]


def _shift_table(prefix: str, key: str, values) -> str:
    return _carray(f"{prefix}_{key}", np.asarray(values, np.int8),
                   "int8_t")


def _conv_call(op: EdgeOp, prog: EdgeProgram, src: str, dst: str) -> list:
    a, p = op.attrs, op.name
    dim_in = prog.tensor(op.inputs[0]).shape[0]     # square feature maps
    out_t = prog.tensor(op.output)
    # PRIMARY_CAPS output is [n_caps, dim]; its conv writes the same
    # buffer at the conv's square spatial dim before the in-place squash
    dim_out = out_t.shape[0] if len(out_t.shape) == 3 else \
        int(round((out_t.size // a["out_ch"]) ** 0.5))
    per_ch = bool(a.get("out_shift_per_channel"))
    fn = "capsnet_convolve_HWC_q7_per_channel" if per_ch \
        else "arm_convolve_HWC_q7_basic"
    bias_arg = f"{p}_bias_shift_per_ch" if per_ch \
        else f"{p.upper()}_BIAS_SHIFT"
    out_arg = f"{p}_out_shift_per_ch" if per_ch \
        else f"{p.upper()}_OUT_SHIFT"
    return [
        f"    {fn}({src}, {dim_in}, {a['in_ch']}, {p}_w, {a['out_ch']},",
        f"        {a['kernel']}, 0, {a['stride']}, {p}_b, {bias_arg},",
        f"        {out_arg}, {dst}, {dim_out}, scratch, NULL);",
    ]


def _emit_op(op: EdgeOp, prog: EdgeProgram, plan: ArenaPlan) -> list:
    def buf(tid: int) -> str:
        if tid == 0:
            return "input"
        off = plan.offsets[tid]
        return f"arena + {off}" if off else "arena"

    src, dst = buf(op.inputs[0]), buf(op.output)
    out_t = prog.tensor(op.output)
    lines = [f"    /* {op.name}: {op.kind} -> "
             f"{'x'.join(str(d) for d in out_t.shape)} q{out_t.frac} */"]
    a, p = op.attrs, op.name
    if op.kind == "CONV_Q7":
        lines += _conv_call(op, prog, src, dst)
        if a["relu"]:
            lines.append(f"    arm_relu_q7({dst}, {out_t.size});")
    elif op.kind == "PRIMARY_CAPS_Q7":
        lines += _conv_call(op, prog, src, dst)
        n_caps, dim = out_t.shape
        lines.append(
            f"    {_squash_symbol(a)}({dst}, {n_caps}, {dim}, "
            f"{p.upper()}_SQUASH_IN_FRAC, {p.upper()}_SQUASH_OUT_FRAC);")
    elif op.kind == "CAPS_ROUTING_Q7":
        uhat_arg = f"{p}_uhat_shift_per_out" \
            if a.get("uhat_shift_per_out") else f"{p.upper()}_UHAT_SHIFT"
        lines += [
            f"    {_routing_symbol(a)}({src}, {p}_W, {a['num_out']},",
            f"        {a['num_in']}, {a['out_dim']}, {a['in_dim']}, "
            f"{a['routings']},",
            f"        {uhat_arg}, {p.upper()}_LOGIT_FRAC, "
            f"{p}_caps_out_shifts,",
            f"        {p}_caps_out_fracs, {p}_agree_shifts, "
            f"{p.upper()}_SQUASH_OUT_FRAC,",
            f"        {dst}, (q7_t *)scratch);",
        ]
    return lines


_CONV_DEFINE_KEYS = ("kernel", "stride", "in_ch", "out_ch", "in_frac",
                     "w_frac", "b_frac", "out_frac", "out_shift",
                     "bias_shift")
_PCAP_DEFINE_KEYS = _CONV_DEFINE_KEYS + ("caps", "dim", "squash_in_frac",
                                         "squash_out_frac")
_ROUTING_DEFINE_KEYS = ("num_out", "num_in", "out_dim", "in_dim",
                        "routings", "in_frac", "W_frac", "uhat_frac",
                        "uhat_shift", "logit_frac", "squash_out_frac")


def emit_c(program: EdgeProgram, plan: ArenaPlan | None = None) -> dict:
    """Return {"c": str, "h": str} for the program (+arena plan)."""
    plan = plan or plan_arena(program)
    stem = program.name
    guard = f"CAPSNET_{stem.upper()}_H"
    scratch = plan.scratch_bytes    # 2-byte aligned by plan_arena

    # ---------------- header ----------------
    h = [f"/* Auto-generated by repro.edge.emit_c from EdgeProgram "
         f"{stem!r}.", f" * Schedule: "
         + " -> ".join(op.name for op in program.ops)
         + f"; rounding={program.rounding}.", " * Do not edit. */",
         f"#ifndef {guard}", f"#define {guard}", "",
         "#include <stdint.h>", "",
         "typedef int8_t q7_t;", "typedef int16_t q15_t;",
         "typedef int32_t q31_t;", "",
         f"#define {stem.upper()}_INPUT_FRAC {program.input_frac}",
         f"#define {stem.upper()}_INPUT_BYTES "
         f"{program.input_tensor.size}",
         f"#define {stem.upper()}_OUTPUT_BYTES "
         f"{program.output_tensor.size}",
         f"#define {stem.upper()}_ARENA_BYTES {plan.arena_bytes}",
         f"#define {stem.upper()}_SCRATCH_BYTES {scratch}", ""]
    c = [f'#include "{stem}.h"', ""]

    for op in program.ops:
        a, p = op.attrs, op.name
        keys = {"CONV_Q7": _CONV_DEFINE_KEYS,
                "PRIMARY_CAPS_Q7": _PCAP_DEFINE_KEYS,
                "CAPS_ROUTING_Q7": _ROUTING_DEFINE_KEYS}[op.kind]
        h.append(f"/* {p}: {op.kind} */")
        h += _defines(p.upper(), a, keys)
        for wname in sorted(op.weights):
            w = op.weights[wname]
            ctype = "q7_t" if w.dtype == np.int8 else "q31_t"
            h.append(f"extern const {ctype} {p}_{wname}[{w.size}];")
            c.append(_carray(f"{p}_{wname}", w, ctype))
            c.append("")
        for key in ("out_shift_per_channel", "bias_shift_per_channel"):
            if a.get(key):
                short = key.replace("_per_channel", "_per_ch")
                h.append(f"extern const int8_t {p}_{short}"
                         f"[{len(a[key])}];")
                c.append(_shift_table(p, short, a[key]))
                c.append("")
        for key in ("caps_out_shifts", "caps_out_fracs", "agree_shifts",
                    "W_frac_per_out", "uhat_shift_per_out"):
            if key in a:
                h.append(f"extern const int8_t {p}_{key}[{len(a[key])}];")
                c.append(_shift_table(p, key, a[key]))
                c.append("")
        h.append("")

    h += [_PROTOTYPES]
    h += _variant_prototypes(program)
    h += [f"void {stem}_run(const q7_t *input, q7_t *output);", "",
          f"#endif /* {guard} */", ""]

    # ---------------- run function ----------------
    # scratch is declared q15_t so the conv bufferA cast is always
    # 2-byte aligned (a q7_t array may land on an odd address)
    c += [f"static q7_t arena[{stem.upper()}_ARENA_BYTES];",
          f"static q15_t scratch[({stem.upper()}_SCRATCH_BYTES + 1) / 2];",
          "",
          f"void {stem}_run(const q7_t *input, q7_t *output)", "{"]
    for op in program.ops:
        c += _emit_op(op, program, plan)
    out = program.ops[-1].output
    off = plan.offsets[out]
    src = f"arena + {off}" if off else "arena"
    c += [f"    for (int i = 0; i < {stem.upper()}_OUTPUT_BYTES; i++)",
          f"        output[i] = ({src})[i];", "}", ""]

    return {"c": "\n".join(c), "h": "\n".join(h)}


def save_c(program: EdgeProgram, out_dir, plan: ArenaPlan | None = None
           ) -> dict:
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    src = emit_c(program, plan)
    paths = {"c": out_dir / f"{program.name}.c",
             "h": out_dir / f"{program.name}.h"}
    paths["c"].write_text(src["c"])
    paths["h"].write_text(src["h"])
    return paths
