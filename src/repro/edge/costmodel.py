"""Static MCU cycle-cost model over `EdgeProgram` geometry.

The paper's headline numbers are latencies — 119.94 ms primary-caps /
90.60 ms caps layer on a Cortex-M7 @ 480 MHz, 7.02 / 38.03 ms on the
GAP-8 cluster @ 170 MHz (abstract; "medium-sized kernels" = the
smallNORB "M" geometry of Table 1) — but nothing in this repo could
estimate what an exported program would cost on the target parts.  This
module closes that: it derives per-op workload counts (int8 MACs +
non-MAC element operations) purely from the program's geometry and maps
them to cycles through per-profile coefficients CALIBRATED so the "M"
layer shapes reproduce the paper's figures exactly.

Model (two coefficients per profile, both folding in the load/store
traffic of the CMSIS-NN/PULP-NN kernels they were fit on):

  CONV_Q7 / PRIMARY_CAPS_Q7:  cycles = macs * conv_cycles_per_mac
      macs = out_h*out_w*out_ch * k*k*in_ch  (im2col matmul; the bias /
      requant / relu / squash element work rides inside the coefficient,
      as it is <1% of the MAC count for every shipped geometry)

  CAPS_ROUTING_Q7:  cycles = (macs + elems) * routing_cycles_per_op

Non-default plans (approximate softmax/squash variants, per-channel
conv / per-out routing requant tables) add a signed "overhead_ops"
count on top — zero for default plans, so the calibration pin is
untouched, negative for the cheaper ISLPED'22 approximate operators.
      macs  = u_hat (J*I*O*D) + per-iteration coupling (r * J*I*O)
              + agreement ((r-1) * J*I*O)
      elems = softmax (r * J*I) + squash (r * J*O)
      Routing is memory- and bookkeeping-bound, not MAC-bound, which is
      why its per-op coefficient is an order of magnitude above conv's —
      exactly the ratio the paper's tables encode.

This is an *estimate*, not a simulator: it extrapolates the paper's
measured points across geometries by workload ratio.  Its job is to be
the latency axis of `table2_rows` and the Q-CapsNets-style Pareto
search (ROADMAP item 3), and to rank design points consistently — both
need a deterministic, hardware-free number, not a cycle-accurate one.
`tests/test_obs.py` pins the calibration: on the "M" geometry both
profiles reproduce the paper's four latencies within CALIB_REL_TOL.
"""
from __future__ import annotations

import dataclasses

from repro.edge.program import EdgeOp, EdgeProgram

# relative tolerance the calibration is pinned to (the coefficients
# below are rounded to 6 decimals; reproduction error is ~1e-5)
CALIB_REL_TOL = 1e-4

# paper latencies (ms) on the "M" layer geometry — the calibration targets
PAPER_LATENCY_MS = {
    "cortex-m7": {"primary_caps": 119.94, "caps_routing": 90.60},
    "gap8": {"primary_caps": 7.02, "caps_routing": 38.03},
}


@dataclasses.dataclass(frozen=True)
class McuProfile:
    """One target part: clock + calibrated cycle coefficients."""
    name: str
    part: str                        # human-readable silicon name
    freq_hz: float
    conv_cycles_per_mac: float
    routing_cycles_per_op: float

    def ms(self, cycles: float) -> float:
        return cycles / self.freq_hz * 1e3


# Coefficients = paper_latency * freq / workload(M geometry), where the
# M workload counts come from the SAME count functions below:
#   pcap(M):    26x26x32 -> k7 s2 -> 10x10x64       = 10_035_200 MACs
#   routing(M): J=5, I=1600, O=6, D=4, r=3          =    456_090 ops
MCU_PROFILES = {
    "cortex-m7": McuProfile(
        name="cortex-m7", part="STM32H755ZIT6U Cortex-M7",
        freq_hz=480e6,
        conv_cycles_per_mac=5.736926,      # 119.94ms * 480MHz / 10_035_200
        routing_cycles_per_op=95.349602),  # 90.60ms * 480MHz / 456_090
    "gap8": McuProfile(
        name="gap8", part="GAP-8 RV32IMCXpulp (8-core cluster)",
        freq_hz=170e6,
        conv_cycles_per_mac=0.118921,      # 7.02ms * 170MHz / 10_035_200
        routing_cycles_per_op=14.175053),  # 38.03ms * 170MHz / 456_090
}


def get_profile(profile) -> McuProfile:
    """Resolve a profile name (or pass an McuProfile through)."""
    if isinstance(profile, McuProfile):
        return profile
    try:
        return MCU_PROFILES[profile]
    except KeyError:
        raise ValueError(f"unknown MCU profile {profile!r}; have "
                         f"{sorted(MCU_PROFILES)}")


# ---------------------------------------------------------------------------
# workload counts (pure geometry; no weights, no execution)
# ---------------------------------------------------------------------------
# Variant/table surcharges, expressed as EXTRA element operations on top
# of the default-plan counts ("overhead_ops"), so default programs keep
# bit-identical estimates to the calibrated model (the test pin).  The
# factors are relative elementwise costs vs the default operator: the
# ISLPED'22 approximate softmax/squash do strictly less work per element
# (factor < 1 -> negative overhead), the float "precise" softmax does
# far more.  Per-channel/per-out requant tables add one table lookup +
# variable shift per output element.
SOFTMAX_ELEM_FACTOR = {"q7": 1.0, "precise": 8.0, "approx": 0.5}
SQUASH_ELEM_FACTOR = {"exact": 1.0, "approx": 0.5}
PER_CHANNEL_CONV_ELEM_FACTOR = 4.0   # extra elem-ops per output element
PER_OUT_ROUTING_ELEM_FACTOR = 1.0    # extra elem-ops per u_hat element


def conv_out_hw(in_h: int, in_w: int, kernel: int, stride: int) -> tuple:
    return ((in_h - kernel) // stride + 1,
            (in_w - kernel) // stride + 1)


def op_counts(program: EdgeProgram, op: EdgeOp) -> dict:
    """Workload of one schedule entry, derived from its attrs and its
    input tensor's shape: int8 MACs, non-MAC element ops, and the int8
    bytes the kernel reads (weights + input) and writes (output)."""
    a = op.attrs
    in_shape = program.tensor(op.inputs[0]).shape
    out_size = program.tensor(op.output).size
    if op.kind in ("CONV_Q7", "PRIMARY_CAPS_Q7"):
        oh, ow = conv_out_hw(in_shape[0], in_shape[1],
                             a["kernel"], a["stride"])
        macs = oh * ow * a["out_ch"] * a["kernel"] ** 2 * a["in_ch"]
        elems = oh * ow * a["out_ch"]            # bias+requant(+relu)
        overhead = 0.0
        if a.get("out_shift_per_channel"):       # per-channel requant table
            overhead += elems * PER_CHANNEL_CONV_ELEM_FACTOR
        if op.kind == "PRIMARY_CAPS_Q7":
            elems += out_size                    # squash over the capsules
            sq = SQUASH_ELEM_FACTOR.get(a.get("squash_impl", "exact"), 1.0)
            overhead += out_size * (sq - 1.0)
    elif op.kind == "CAPS_ROUTING_Q7":
        j, i, o, d = a["num_out"], a["num_in"], a["out_dim"], a["in_dim"]
        r = a["routings"]
        macs = (j * i * o * d                    # u_hat = W x u
                + r * j * i * o                  # coupling s = c . u_hat
                + (r - 1) * j * i * o)           # agreement u_hat . v
        elems = r * j * i + r * j * o            # softmax + squash
        sm = SOFTMAX_ELEM_FACTOR.get(a.get("softmax_impl", "q7"), 1.0)
        sq = SQUASH_ELEM_FACTOR.get(a.get("squash_impl", "exact"), 1.0)
        overhead = (r * j * i * (sm - 1.0)       # softmax variant delta
                    + r * j * o * (sq - 1.0))    # squash variant delta
        if a.get("uhat_shift_per_out"):          # per-out requant table
            overhead += j * i * o * PER_OUT_ROUTING_ELEM_FACTOR
    else:
        raise ValueError(f"no cost model for op kind {op.kind!r}")
    return {
        "macs": int(macs),
        "elems": int(elems),
        "overhead_ops": float(overhead),
        "load_bytes": int(op.weight_bytes
                          + program.tensor(op.inputs[0]).nbytes),
        "store_bytes": int(out_size),
    }


def op_cycles(counts: dict, kind: str, profile: McuProfile) -> float:
    overhead = counts.get("overhead_ops", 0.0)
    if kind in ("CONV_Q7", "PRIMARY_CAPS_Q7"):
        return (counts["macs"] + overhead) * profile.conv_cycles_per_mac
    if kind == "CAPS_ROUTING_Q7":
        return ((counts["macs"] + counts["elems"] + overhead)
                * profile.routing_cycles_per_op)
    raise ValueError(f"no cost model for op kind {kind!r}")


# ---------------------------------------------------------------------------
# program-level estimate
# ---------------------------------------------------------------------------
def estimate_program(program: EdgeProgram, profile) -> dict:
    """Per-op and total cycle/latency estimate of one batch-1 inference
    of `program` on `profile` (name or McuProfile)."""
    p = get_profile(profile)
    rows = []
    for i, op in enumerate(program.ops):
        c = op_counts(program, op)
        cycles = op_cycles(c, op.kind, p)
        rows.append({"op_index": i, "name": op.name, "kind": op.kind,
                     **c, "cycles": cycles, "ms": p.ms(cycles)})
    total = sum(r["cycles"] for r in rows)
    return {
        "name": program.name,
        "profile": p.name,
        "part": p.part,
        "freq_mhz": p.freq_hz / 1e6,
        "rows": rows,
        "total_cycles": total,
        "total_ms": p.ms(total),
    }


def estimate_all(program: EdgeProgram) -> dict:
    """{profile name: estimate} for every registered MCU profile."""
    return {name: estimate_program(program, name) for name in MCU_PROFILES}


def total_latency_ms(program: EdgeProgram, profile) -> float:
    return estimate_program(program, profile)["total_ms"]


def format_estimate(est: dict) -> str:
    lines = [f"[{est['name']}] estimated cost on {est['part']} "
             f"({est['profile']}, {est['freq_mhz']:.0f} MHz):"]
    lines.append(f"  {'op':<8}{'kind':<18}{'MACs':>12}{'elems':>10}"
                 f"{'cycles':>14}{'ms':>10}")
    for r in est["rows"]:
        lines.append(f"  {r['name']:<8}{r['kind']:<18}{r['macs']:>12,}"
                     f"{r['elems']:>10,}{r['cycles']:>14,.0f}"
                     f"{r['ms']:>10.2f}")
    lines.append(f"  total: {est['total_cycles']:,.0f} cycles = "
                 f"{est['total_ms']:.2f} ms "
                 f"({1e3 / est['total_ms']:.1f} inf/s)")
    return "\n".join(lines)


def format_estimates(program: EdgeProgram) -> str:
    """Both MCU profiles' tables for one program (the `--profile` CLI
    output)."""
    return "\n".join(format_estimate(e)
                     for e in estimate_all(program).values())
