"""Lower a calibrated `QuantCapsNet` into an `EdgeProgram`.

The walk mirrors `CapsPipeline.forward_q7` one-to-one: each layer
becomes one schedule entry whose attrs are a flat copy of its typed plan
(ConvPlan / PrimaryCapsPlan / RoutingPlan) and whose weight blobs are
the already-quantized int8 arrays.  Activation shapes are per-sample
(no batch dim) — the MCU artifact serves batch 1; the VM re-vectorizes
over a leading batch axis when testing against the host model.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.analysis.ranges import annotate_acc_bounds
from repro.edge.program import EdgeOp, EdgeProgram, TensorSpec
from repro.nn.layers import CapsuleRouting, PrimaryCaps, QuantConv2D
from repro.nn.pipeline import QuantCapsNet


def _conv_attrs(layer: QuantConv2D, plan) -> dict:
    attrs = {
        "kernel": layer.kernel, "stride": layer.stride,
        "in_ch": layer.in_ch, "out_ch": layer.out_ch,
        "relu": layer.relu,
        "in_frac": plan.in_frac, "w_frac": plan.w_frac,
        "b_frac": plan.b_frac, "out_frac": plan.out_frac,
        "out_shift": plan.out_shift, "bias_shift": plan.bias_shift,
    }
    if plan.per_channel:
        attrs["w_frac_per_channel"] = tuple(plan.w_frac_per_channel)
        attrs["out_shift_per_channel"] = tuple(plan.out_shift_per_channel)
        attrs["bias_shift_per_channel"] = tuple(plan.bias_shift_per_channel)
    return attrs


def _np(x):
    return np.asarray(jax.device_get(x))


def lower(qnet: QuantCapsNet, name: str | None = None) -> EdgeProgram:
    """Compile any quantized CapsNet (per-tensor or per-channel plans,
    either rounding mode) into the flat MCU schedule."""
    cfg = qnet.pipeline.cfg
    name = name or cfg.name
    h, w = cfg.input_shape[0], cfg.input_shape[1]

    tensors = [TensorSpec(0, "input", tuple(cfg.input_shape),
                          qnet.plan.input_frac)]
    ops = []

    def new_tensor(tname, shape, frac) -> int:
        tensors.append(TensorSpec(len(tensors), tname, tuple(shape), frac))
        return len(tensors) - 1

    cur = 0
    for layer in qnet.pipeline.layers:
        plan = qnet.plan[layer.name]
        qw = {k: _np(v) for k, v in qnet.qweights[layer.name].items()}
        if isinstance(layer, PrimaryCaps):
            conv = layer.conv
            h = (h - conv.kernel) // conv.stride + 1
            w = (w - conv.kernel) // conv.stride + 1
            attrs = _conv_attrs(conv, plan.conv)
            attrs.update(caps=layer.caps, dim=layer.dim,
                         squash_in_frac=plan.conv.out_frac,
                         squash_out_frac=plan.squash_out_frac,
                         squash_impl=plan.squash_impl)
            out = new_tensor(f"{layer.name}.caps",
                             (h * w * layer.caps, layer.dim),
                             plan.squash_out_frac)
            ops.append(EdgeOp("PRIMARY_CAPS_Q7", layer.name, (cur,), out,
                              attrs, qw))
        elif isinstance(layer, QuantConv2D):
            h = (h - layer.kernel) // layer.stride + 1
            w = (w - layer.kernel) // layer.stride + 1
            out = new_tensor(f"{layer.name}.out", (h, w, layer.out_ch),
                             plan.out_frac)
            ops.append(EdgeOp("CONV_Q7", layer.name, (cur,), out,
                              _conv_attrs(layer, plan), qw))
        elif isinstance(layer, CapsuleRouting):
            attrs = {
                "num_out": layer.num_out, "num_in": layer.num_in,
                "out_dim": layer.out_dim, "in_dim": layer.in_dim,
                "routings": layer.routings,
                "in_frac": plan.in_frac, "W_frac": plan.W_frac,
                "uhat_frac": plan.uhat_frac, "uhat_shift": plan.uhat_shift,
                "logit_frac": plan.logit_frac,
                "caps_out_shifts": tuple(plan.caps_out_shifts),
                "caps_out_fracs": tuple(plan.caps_out_fracs),
                "agree_shifts": tuple(plan.agree_shifts),
                "softmax_impl": plan.softmax_impl,
                "squash_out_frac": plan.squash_out_frac,
                "squash_impl": plan.squash_impl,
            }
            if plan.per_out:
                attrs["W_frac_per_out"] = tuple(plan.W_frac_per_out)
                attrs["uhat_shift_per_out"] = \
                    tuple(plan.uhat_shift_per_out)
            out = new_tensor(f"{layer.name}.v",
                             (layer.num_out, layer.out_dim),
                             plan.out_frac)
            ops.append(EdgeOp("CAPS_ROUTING_Q7", layer.name, (cur,), out,
                              attrs, qw))
        else:
            raise TypeError(
                f"no lowering for layer {layer.name!r} "
                f"({type(layer).__name__}); teach repro.edge.lower about "
                "new CapsLayer kinds before exporting them")
        cur = out

    program = EdgeProgram(name=name, rounding=qnet.rounding,
                          input_frac=qnet.plan.input_frac,
                          tensors=tuple(tensors), ops=tuple(ops))
    # every conv-accumulating op carries its statically-derived
    # worst-case |int32 accumulator| (repro.analysis.ranges); the VM
    # asserts it at run time, so the checker and the VM cannot
    # silently disagree about wrap safety
    return annotate_acc_bounds(program)


def describe(program: EdgeProgram) -> str:
    """One line per schedule entry (the CLI's program dump)."""
    lines = [f"EdgeProgram {program.name!r} rounding={program.rounding} "
             f"input={program.input_tensor.shape} "
             f"Q{7 - program.input_frac}.{program.input_frac}"]
    for op in program.ops:
        o = program.tensor(op.output)
        lines.append(f"  {op.kind:<16} {op.name:<6} -> {o.shape} "
                     f"frac={o.frac} weights={op.weight_bytes}B")
    return "\n".join(lines)
