"""Static arena planner + the Table-2-style memory report.

MCU deployments have no allocator: every activation tensor gets a fixed
offset in ONE static buffer, assigned at export time from liveness.  The
planner is the standard greedy-by-size scheme (as used by TFLite-Micro's
arena planner): place tensors largest-first at the lowest offset that
does not overlap any already-placed tensor whose live range intersects.
Peak arena is therefore <= the naive sum of all activation sizes, and
usually close to the two largest concurrently-live tensors.

Per-op scratch (the CMSIS-NN `bufferA` im2col buffer, routing's resident
u_hat) is transient within one op, so it overlays a single shared
region sized by the worst op rather than joining the liveness problem.
"""
from __future__ import annotations

import dataclasses

from repro.edge.program import EdgeProgram


@dataclasses.dataclass(frozen=True)
class ArenaPlan:
    offsets: dict                   # tensor id -> byte offset
    lifetimes: dict                 # tensor id -> (first_step, last_step)
    arena_bytes: int                # peak of the activation arena
    scratch_bytes: int              # shared transient region (worst op)
    naive_bytes: int                # sum of all activation sizes

    @property
    def ram_bytes(self) -> int:
        return self.arena_bytes + self.scratch_bytes


def lifetimes(program: EdgeProgram) -> dict:
    """Live range of each tensor in schedule steps: a tensor defined by
    op i is live [i, last consuming op]; the input is live from step 0;
    the final output survives past the last op (the caller reads it)."""
    n = len(program.ops)
    life = {0: [0, 0]}
    for i, op in enumerate(program.ops):
        life[op.output] = [i, i]
        for tid in op.inputs:
            life[tid][1] = max(life[tid][1], i)
    life[program.ops[-1].output][1] = n
    return {tid: tuple(v) for tid, v in life.items()}


def assign_offsets(blocks) -> dict:
    """Greedy-by-size offset assignment.

    blocks: iterable of (key, size_bytes, (start, end)) with inclusive
    live ranges.  Returns key -> offset such that blocks with
    intersecting ranges never overlap in [offset, offset+size)."""
    order = sorted(blocks, key=lambda b: (-b[1], b[0]))
    placed = []                     # (offset, size, start, end)
    offsets = {}
    for key, size, (start, end) in order:
        conflicts = sorted((off, sz) for off, sz, s, e in placed
                           if not (e < start or end < s))
        offset = 0
        for off, sz in conflicts:
            if offset + size <= off:
                break
            offset = max(offset, off + sz)
        offsets[key] = offset
        placed.append((offset, size, start, end))
    return offsets


def op_scratch_bytes(op) -> int:
    """Transient working memory of one kernel call, in bytes.

    conv / primary caps: the CMSIS-NN im2col `bufferA` — a double buffer
    of q15 columns, 2 * (k*k*in_ch) * sizeof(q15).  Routing: u_hat stays
    resident across iterations (J*I*O int8) plus the logit/coupling
    planes (2 * J*I) and the pre-squash capsule s (J*O)."""
    a = op.attrs
    if op.kind in ("CONV_Q7", "PRIMARY_CAPS_Q7"):
        return 2 * 2 * a["kernel"] * a["kernel"] * a["in_ch"]
    if op.kind == "CAPS_ROUTING_Q7":
        j, i, o = a["num_out"], a["num_in"], a["out_dim"]
        return j * i * o + 2 * j * i + j * o
    raise ValueError(op.kind)


def plan_arena(program: EdgeProgram) -> ArenaPlan:
    """The input tensor (tid 0) is the CALLER's buffer — the emitted C
    reads it through the `input` pointer — so it joins neither the
    arena nor the naive-allocator comparison."""
    life = lifetimes(program)
    sizes = {tid: program.tensor(tid).nbytes for tid in life}
    arena_tids = [tid for tid in sorted(life) if tid != 0]
    offsets = assign_offsets(
        [(tid, sizes[tid], life[tid]) for tid in arena_tids])
    peak = max(offsets[tid] + sizes[tid] for tid in offsets)
    scratch = max(op_scratch_bytes(op) for op in program.ops)
    scratch += scratch % 2          # q15 scratch region: keep 2-byte
    #                                 aligned (emit_c declares q15_t[])
    return ArenaPlan(offsets=offsets, lifetimes=life, arena_bytes=peak,
                     scratch_bytes=scratch,
                     naive_bytes=sum(sizes[t] for t in arena_tids))


# ---------------------------------------------------------------------------
# memory report (paper Table 2: flash = weights, RAM = activations)
# ---------------------------------------------------------------------------
def memory_report(program: EdgeProgram, plan: ArenaPlan | None = None,
                  profile=None) -> dict:
    """Per-layer flash/RAM breakdown; with `profile` (an MCU profile
    name or `costmodel.McuProfile`) every row additionally carries the
    static cycle/latency estimate for that part, and the report gains
    `est_total_{cycles,ms}` — the paper's Table-2 footprint and its
    latency tables in one view."""
    plan = plan or plan_arena(program)
    est = None
    if profile is not None:
        from repro.edge import costmodel
        est = costmodel.estimate_program(program, profile)
    rows = []
    for i, op in enumerate(program.ops):
        out = program.tensor(op.output)
        rows.append({
            "name": op.name, "kind": op.kind,
            "weight_bytes": op.weight_bytes,
            "act_bytes": out.nbytes,
            "act_offset": plan.offsets[op.output],
            "scratch_bytes": op_scratch_bytes(op),
        })
        if est is not None:
            rows[-1]["est_cycles"] = est["rows"][i]["cycles"]
            rows[-1]["est_ms"] = est["rows"][i]["ms"]
    weight_elems = sum(int(w.size) for op in program.ops
                       for w in op.weights.values())
    arena_elems = plan.arena_bytes          # int8: 1 byte per element
    int8_total = program.flash_bytes + plan.arena_bytes
    fp32_total = 4 * weight_elems + 4 * arena_elems
    extra = {} if est is None else {
        "profile": est["profile"],
        "est_total_cycles": est["total_cycles"],
        "est_total_ms": est["total_ms"],
    }
    return {
        "name": program.name,
        "rows": rows,
        **extra,
        "input_bytes": program.input_tensor.nbytes,   # caller's buffer
        "flash_bytes": program.flash_bytes,
        "weight_bytes": program.weight_bytes,
        "arena_bytes": plan.arena_bytes,
        "scratch_bytes": plan.scratch_bytes,
        "ram_bytes": plan.ram_bytes,
        "naive_act_bytes": plan.naive_bytes,
        "fp32_total_bytes": fp32_total,
        "int8_total_bytes": int8_total,
        "saving_pct": 100.0 * (1.0 - int8_total / fp32_total),
    }


def format_report(report: dict) -> str:
    lines = [f"[{report['name']}] per-layer memory plan:"]
    for r in report["rows"]:
        lines.append(
            f"  {r['name']:<6} {r['kind']:<16} "
            f"flash={r['weight_bytes']:>8d}B  "
            f"act={r['act_bytes']:>7d}B@+{r['act_offset']:<7d} "
            f"scratch={r['scratch_bytes']}B"
            + (f"  est={r['est_ms']:.2f}ms" if "est_ms" in r else ""))
    lines.append(
        f"  flash {report['flash_bytes'] / 1000:.1f} KB "
        f"(weights {report['weight_bytes'] / 1000:.1f} KB + tables) | "
        f"RAM {report['ram_bytes'] / 1000:.1f} KB "
        f"(arena {report['arena_bytes']}B of naive "
        f"{report['naive_act_bytes']}B + scratch "
        f"{report['scratch_bytes']}B; caller input buffer "
        f"{report['input_bytes']}B)")
    lines.append(
        f"  total int8 {report['int8_total_bytes'] / 1000:.2f} KB vs fp32 "
        f"{report['fp32_total_bytes'] / 1000:.2f} KB -> "
        f"{report['saving_pct']:.1f}% smaller")
    if "est_total_ms" in report:
        lines.append(
            f"  est. latency on {report['profile']}: "
            f"{report['est_total_cycles']:,.0f} cycles = "
            f"{report['est_total_ms']:.2f} ms/inference")
    return "\n".join(lines)
