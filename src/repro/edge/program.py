"""EdgeProgram — the MCU export IR (see README.md in this package).

A compiled CapsNet is a flat schedule of three op kinds (`CONV_Q7`,
`PRIMARY_CAPS_Q7`, `CAPS_ROUTING_Q7`) over per-sample activation
tensors.  Every op record carries exactly the Qm.n formats, power-of-two
shifts, and int8 weight blobs of the typed plan it was lowered from —
nothing is re-derived downstream, so the VM, the arena planner, and the
C emitter all read one source of truth.

Serialization is a single binary artifact (`.capsbin`) holding a JSON
header plus 16-byte-aligned raw weight blobs, with the same header also
written next to it as a human-readable `.manifest.json`.  `load()` reads
the `.capsbin` alone and round-trips bit-exactly (`same_as`).
"""
from __future__ import annotations

import dataclasses
import json
import struct
from pathlib import Path

import numpy as np

MAGIC = b"CAPSBIN\x01"
VERSION = 1
_ALIGN = 16

OP_KINDS = ("CONV_Q7", "PRIMARY_CAPS_Q7", "CAPS_ROUTING_Q7")


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One activation tensor: per-sample shape (no batch dim) + format."""
    tid: int
    name: str                       # e.g. "input", "conv0.out"
    shape: tuple                    # ints, per sample
    frac: int                       # Qm.n fractional bits of the int8 data

    @property
    def size(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64))

    @property
    def nbytes(self) -> int:        # activations are always int8
        return self.size


@dataclasses.dataclass(frozen=True, eq=False)
class EdgeOp:
    """One schedule entry: kind + attrs (ints / int tuples / strings,
    JSON-safe) + named weight blobs (int8/int32 numpy arrays)."""
    kind: str
    name: str
    inputs: tuple                   # tensor ids read
    output: int                     # tensor id written
    attrs: dict
    weights: dict

    def __post_init__(self):
        if self.kind not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.kind!r}; "
                             f"have {OP_KINDS}")

    @property
    def weight_bytes(self) -> int:
        return sum(int(w.nbytes) for w in self.weights.values())

    def attr_scalars(self) -> int:
        """int32 table entries this op needs at runtime (shifts/formats);
        the flash-side analogue of plans.plan_scalars."""
        n = 0
        for v in self.attrs.values():
            if isinstance(v, bool):
                continue
            if isinstance(v, int):
                n += 1
            elif isinstance(v, tuple):
                n += len(v)
        return n


@dataclasses.dataclass(frozen=True, eq=False)
class EdgeProgram:
    name: str
    rounding: str                   # "floor" | "nearest"
    input_frac: int
    tensors: tuple                  # TensorSpec, indexed by tid
    ops: tuple                      # EdgeOp, in execution order

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------
    def tensor(self, tid: int) -> TensorSpec:
        t = self.tensors[tid]
        assert t.tid == tid
        return t

    @property
    def input_tensor(self) -> TensorSpec:
        return self.tensors[0]

    @property
    def output_tensor(self) -> TensorSpec:
        return self.tensor(self.ops[-1].output)

    @property
    def weight_bytes(self) -> int:
        return sum(op.weight_bytes for op in self.ops)

    @property
    def flash_bytes(self) -> int:
        """Read-only footprint: int8 weights + the int32 shift/format
        tables (1 for input_frac + each op's attr scalars)."""
        return self.weight_bytes + 4 * (1 + sum(op.attr_scalars()
                                                for op in self.ops))

    def same_as(self, other: "EdgeProgram") -> bool:
        """Structural + bit equality (dataclass eq is off: numpy leaves)."""
        if self.header() != other.header():
            return False
        for a, b in zip(self.ops, other.ops):
            for k in a.weights:
                if a.weights[k].dtype != b.weights[k].dtype or \
                        not np.array_equal(a.weights[k], b.weights[k]):
                    return False
        return True

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------
    def header(self) -> dict:
        """The JSON header/manifest (everything but the blob payloads)."""
        ops = []
        offset = 0
        for op in self.ops:
            wmeta = {}
            for wname in sorted(op.weights):
                w = op.weights[wname]
                offset = _align(offset)
                wmeta[wname] = {"dtype": str(w.dtype),
                                "shape": list(w.shape),
                                "offset": offset,
                                "nbytes": int(w.nbytes)}
                offset += int(w.nbytes)
            ops.append({"kind": op.kind, "name": op.name,
                        "inputs": list(op.inputs), "output": op.output,
                        "attrs": _attrs_to_json(op.attrs),
                        "weights": wmeta})
        return {
            "format": "capsbin", "version": VERSION,
            "name": self.name, "rounding": self.rounding,
            "input_frac": self.input_frac,
            "tensors": [{"tid": t.tid, "name": t.name,
                         "shape": list(t.shape), "frac": t.frac}
                        for t in self.tensors],
            "ops": ops,
        }

    def save(self, stem) -> dict:
        """Write `<stem>.capsbin` + `<stem>.manifest.json`; return paths."""
        stem = Path(stem)
        stem.parent.mkdir(parents=True, exist_ok=True)
        header = self.header()
        hbytes = json.dumps(header, sort_keys=True).encode()
        payload = bytearray()
        for op in self.ops:
            for wname in sorted(op.weights):
                while len(payload) % _ALIGN:
                    payload.append(0)
                payload += op.weights[wname].tobytes()
        blob = MAGIC + struct.pack("<I", len(hbytes)) + hbytes
        blob += b"\x00" * (_align(len(blob)) - len(blob))
        blob += bytes(payload)

        capsbin = stem.with_suffix(".capsbin")
        manifest = stem.with_suffix(".manifest.json")
        capsbin.write_bytes(blob)
        manifest.write_text(json.dumps(header, sort_keys=True, indent=2)
                            + "\n")
        return {"capsbin": capsbin, "manifest": manifest}

    @classmethod
    def load(cls, path) -> "EdgeProgram":
        raw = Path(path).read_bytes()
        if raw[:len(MAGIC)] != MAGIC:
            raise ValueError(f"{path}: not a capsbin artifact")
        (hlen,) = struct.unpack_from("<I", raw, len(MAGIC))
        hstart = len(MAGIC) + 4
        header = json.loads(raw[hstart:hstart + hlen].decode())
        if header.get("version") != VERSION:
            raise ValueError(f"{path}: capsbin version "
                             f"{header.get('version')} != {VERSION}")
        payload = raw[_align(hstart + hlen):]

        tensors = tuple(TensorSpec(t["tid"], t["name"], tuple(t["shape"]),
                                   t["frac"]) for t in header["tensors"])
        ops = []
        for o in header["ops"]:
            weights = {}
            for wname, m in o["weights"].items():
                # the header's blob metadata must be internally
                # consistent with the payload BEFORE frombuffer touches
                # it — a tampered shape/nbytes/offset is a loud
                # malformed-artifact error, not a silent misread
                count = int(np.prod(m["shape"], dtype=np.int64))
                want = count * np.dtype(m["dtype"]).itemsize
                if int(m["nbytes"]) != want:
                    raise ValueError(
                        f"{path}: blob {o['name']}/{wname} declares "
                        f"{m['nbytes']} bytes but shape {m['shape']} x "
                        f"{m['dtype']} needs {want}")
                if m["offset"] < 0 or m["offset"] + want > len(payload):
                    raise ValueError(
                        f"{path}: blob {o['name']}/{wname} at offset "
                        f"{m['offset']} (+{want}B) runs past the "
                        f"{len(payload)}-byte payload")
                a = np.frombuffer(payload, dtype=np.dtype(m["dtype"]),
                                  count=count, offset=m["offset"])
                weights[wname] = a.reshape(m["shape"]).copy()
            ops.append(EdgeOp(o["kind"], o["name"], tuple(o["inputs"]),
                              o["output"], _attrs_from_json(o["attrs"]),
                              weights))
        return cls(name=header["name"], rounding=header["rounding"],
                   input_frac=header["input_frac"], tensors=tensors,
                   ops=tuple(ops))


def _align(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


def _attrs_to_json(attrs: dict) -> dict:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, tuple):
            out[k] = {"tuple": [int(x) for x in v]}
        elif isinstance(v, (bool, int, str)):
            out[k] = v
        else:
            raise TypeError(f"attr {k}={v!r} is not JSON-safe")
    return out


def _attrs_from_json(attrs: dict) -> dict:
    return {k: tuple(v["tuple"]) if isinstance(v, dict) else v
            for k, v in attrs.items()}
