"""MCU export compiler for quantized CapsNets (see README.md here).

QuantCapsNet -> lower() -> EdgeProgram -> { plan_arena() memory plan,
EdgeVM bit-exact execution, emit_c() CMSIS-NN-style sources,
save()/load() single-file artifact }.
"""
from repro.edge.arena import (ArenaPlan, assign_offsets,  # noqa: F401
                              format_report, lifetimes, memory_report,
                              op_scratch_bytes, plan_arena)
from repro.edge.costmodel import (MCU_PROFILES, McuProfile,  # noqa: F401
                                  estimate_all, estimate_program,
                                  format_estimate, format_estimates,
                                  get_profile, total_latency_ms)
from repro.edge.emit_c import emit_c, save_c  # noqa: F401
from repro.edge.export import export_artifacts, format_export  # noqa: F401
from repro.edge.importer import (load_qnet, program_config,  # noqa: F401
                                 to_qnet)
from repro.edge.lower import describe, lower  # noqa: F401
from repro.edge.program import (EdgeOp, EdgeProgram,  # noqa: F401
                                TensorSpec)
from repro.edge.vm import EdgeVM, execute  # noqa: F401
