"""Mamba (selective SSM, Mamba-1) mixer.

Projections/conv run in parallel over the sequence (MXU-visible matmuls);
the recurrence runs as a chunked time scan (`scan_utils.chunked_scan`) with
an O(B * ED * N) carry, giving honest FLOP accounting under cost_analysis
(while-body cost x trip count) and bounded remat memory.

Decode is a single-step state update: O(1) in sequence length, which is why
jamba/xlstm run the long_500k cell (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.api import BATCH, shard
from repro.models import layers
from repro.models.scan_utils import chunked_scan, pick_chunk


def init_mamba(key, cfg) -> dict:
    d, ed = cfg.d_model, cfg.ssm_inner
    n, r, kc = cfg.ssm_state_dim, cfg.dt_rank, cfg.ssm_conv_dim
    ks = jax.random.split(key, 6)
    dt = layers.DEFAULT_DTYPE
    s = d ** -0.5
    return {
        "in_proj":  (jax.random.normal(ks[0], (d, 2 * ed), jnp.float32) * s).astype(dt),
        "conv_w":   (jax.random.normal(ks[1], (kc, ed), jnp.float32) * 0.2).astype(dt),
        "conv_b":   jnp.zeros((ed,), dt),
        "x_proj":   (jax.random.normal(ks[2], (ed, r + 2 * n), jnp.float32) * ed ** -0.5).astype(dt),
        "dt_proj":  (jax.random.normal(ks[3], (r, ed), jnp.float32) * r ** -0.5).astype(dt),
        "dt_bias":  jnp.zeros((ed,), jnp.float32),
        "A_log":    jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (ed, 1))),
        "D":        jnp.ones((ed,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (ed, d), jnp.float32) * ed ** -0.5).astype(dt),
    }


def _causal_conv(u, w, b, state=None):
    """u [B,S,ED]; w [K,ED] depthwise causal conv.  state [B,K-1,ED] holds the
    last K-1 inputs from the previous segment (or zeros)."""
    K = w.shape[0]
    B, S, ED = u.shape
    if state is None:
        state = jnp.zeros((B, K - 1, ED), u.dtype)
    up = jnp.concatenate([state, u], axis=1)          # [B, S+K-1, ED]
    y = jnp.zeros((B, S, ED), jnp.float32)
    for j in range(K):
        y = y + up[:, j:j + S].astype(jnp.float32) * w[j].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    new_state = up[:, -(K - 1):]
    return jax.nn.silu(y).astype(u.dtype), new_state


def _ssm_scan(u, dt, Bt, Ct, A, h0, chunk):
    """u,dt [B,S,ED]; Bt,Ct [B,S,N]; A [ED,N]; h0 [B,ED,N] fp32.
    Returns y [B,S,ED] fp32, hT."""
    def body(h, xs):
        u_t, dt_t, b_t, c_t = xs            # [B,ED],[B,ED],[B,N],[B,N]
        dA = jnp.exp(dt_t[..., None] * A[None])            # [B,ED,N]
        dBu = (dt_t * u_t)[..., None] * b_t[:, None, :]    # [B,ED,N]
        h = dA * h + dBu
        y_t = jnp.einsum("ben,bn->be", h, c_t)
        return h, y_t

    xs = (u.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1),
          Bt.swapaxes(0, 1).astype(jnp.float32),
          Ct.swapaxes(0, 1).astype(jnp.float32))
    hT, ys = chunked_scan(body, h0, xs, chunk=chunk)
    return ys.swapaxes(0, 1), hT


def mamba_apply(params, x, cfg, *, mode: str, cache=None):
    """x [B,S,D] -> (y [B,S,D], new_cache).  cache {"conv","ssm"}."""
    B, S, D = x.shape
    ed, n, r = cfg.ssm_inner, cfg.ssm_state_dim, cfg.dt_rank

    xz = layers.dense(x, params["in_proj"])
    xz = shard(xz, BATCH, None, "model")
    u, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, params["conv_w"], params["conv_b"],
                               conv_state)

    bcr = layers.dense(u, params["x_proj"])               # [B,S,r+2n]
    dt_r, Bt, Ct = jnp.split(bcr, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        layers.dense(dt_r, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                         # [ED,N]

    h0 = (cache["ssm"].astype(jnp.float32) if cache is not None
          else jnp.zeros((B, ed, n), jnp.float32))

    if mode == "decode":                                   # S == 1
        def body(h, _):
            dA = jnp.exp(dt[:, 0][..., None] * A[None])
            dBu = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] \
                * Bt[:, 0].astype(jnp.float32)[:, None, :]
            h = dA * h + dBu
            y = jnp.einsum("ben,bn->be", h, Ct[:, 0].astype(jnp.float32))
            return h, y
        hT, y = body(h0, None)
        ys = y[:, None]
    else:
        ys, hT = _ssm_scan(u, dt, Bt, Ct, A, h0, chunk=pick_chunk(S, 64))

    ys = ys + params["D"] * u.astype(jnp.float32)
    out = (ys * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = layers.dense(out, params["out_proj"])
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"conv": new_conv, "ssm": hT.astype(jnp.float32)}
    return out, new_cache


def init_mamba_cache(cfg, batch: int, dtype=jnp.bfloat16) -> dict:
    ed, n, kc = cfg.ssm_inner, cfg.ssm_state_dim, cfg.ssm_conv_dim
    return {"conv": jnp.zeros((batch, kc - 1, ed), dtype),
            "ssm": jnp.zeros((batch, ed, n), jnp.float32)}
