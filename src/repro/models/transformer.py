"""Model assembly: heterogeneous block stacks (attention / SWA / mamba /
mLSTM / sLSTM mixers x mlp / moe / none FFNs), scanned over pattern cycles.

Parameters for each pattern position are stacked over `num_cycles` on a
leading axis and consumed by `lax.scan` — HLO size is O(pattern length), not
O(depth), which keeps 80-layer compiles tractable and (verified) makes XLA
cost_analysis multiply body FLOPs by the trip count.

Three entry points per model: `train_loss`, `prefill`, `decode_step`.
Enc-dec (seamless) and VLM (paligemma, prefix-LM) wrap the same machinery.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.api import BATCH, SEQ, shard
from repro.models import attention, layers, mamba, moe, xlstm
from repro.models.layers import init_norm, rms_norm


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def decode_alloc(seq_len: int) -> int:
    """KV allocation for decode cells: seq_len filled + headroom, divisible
    by 512 so every sharding layout (model=16, data*model=256) divides it."""
    return round_up(seq_len + 1, 512)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def init_block(key, cfg, kind) -> dict:
    mixer, ffn = kind
    k1, k2 = jax.random.split(key)
    p = {"norm1": init_norm(cfg.d_model)}
    if mixer in ("attn", "swa"):
        p["attn"] = attention.init_attn(k1, cfg)
    elif mixer == "mamba":
        p["mamba"] = mamba.init_mamba(k1, cfg)
    elif mixer == "mlstm":
        p["mlstm"] = xlstm.init_mlstm(k1, cfg)
    elif mixer == "slstm":
        p["slstm"] = xlstm.init_slstm(k1, cfg)
    else:
        raise ValueError(mixer)
    if ffn == "mlp":
        p["norm2"] = init_norm(cfg.d_model)
        p["mlp"] = layers.init_mlp(k2, cfg.d_model, cfg.d_ff)
    elif ffn == "moe":
        p["norm2"] = init_norm(cfg.d_model)
        p["moe"] = moe.init_moe(k2, cfg)
    return p


def block_apply(cfg, kind, p, x, *, mode, cache, pos, prefix_len):
    """x [B,S,D] -> (x, new_cache, aux)."""
    mixer, ffn = kind
    h = rms_norm(x, p["norm1"]["scale"], cfg.norm_eps)
    new_cache = None
    if mixer in ("attn", "swa"):
        window = cfg.window_size if mixer == "swa" else 0
        h, new_cache = attention.attn_apply(
            cfg, p["attn"], h, mode=mode, cache=cache, pos=pos,
            prefix_len=prefix_len, window=window)
    elif mixer == "mamba":
        h, new_cache = mamba.mamba_apply(p["mamba"], h, cfg, mode=mode,
                                         cache=cache)
    elif mixer == "mlstm":
        h, new_cache = xlstm.mlstm_apply(p["mlstm"], h, cfg, mode=mode,
                                         cache=cache)
    elif mixer == "slstm":
        h, new_cache = xlstm.slstm_apply(p["slstm"], h, cfg, mode=mode,
                                         cache=cache)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if ffn == "mlp":
        x = x + layers.mlp(p["mlp"], rms_norm(x, p["norm2"]["scale"],
                                              cfg.norm_eps))
    elif ffn == "moe":
        h2, aux = moe.moe_apply(p["moe"],
                                rms_norm(x, p["norm2"]["scale"], cfg.norm_eps),
                                cfg, is_decode=(mode == "decode"))
        x = x + h2
    return x, new_cache, aux


def _resid_shard(x, mode):
    if mode == "decode" or x.shape[0] < 2:
        return shard(x, BATCH if x.shape[0] > 1 else None, None, None)
    return shard(x, BATCH, SEQ, None)


def run_stack(cfg, blocks, stack_params, x, *, mode, caches=None,
              pos=None, prefix_len=0, bidir=False):
    """Scan the pattern-cycle over depth.

    stack_params: tuple (per pattern position) of param trees with leading
    num_cycles axis.  caches: matching tuple of cache trees (or None).
    Returns (x, new_caches, aux_sum).
    """
    n_pos = len(blocks)
    if caches is None:
        caches = tuple({} for _ in range(n_pos))

    def body(carry, xs):
        x, aux = carry
        p_sl, c_sl = xs
        x = _resid_shard(x, mode)
        new_c = []
        for i, kind in enumerate(blocks):
            cache_i = c_sl[i] if c_sl[i] else None
            if bidir and kind[0] == "attn":
                # encoder: bidirectional attention (no cache)
                h = rms_norm(x, p_sl[i]["norm1"]["scale"], cfg.norm_eps)
                h, _ = attention.attn_apply(
                    cfg, p_sl[i]["attn"], h, mode="train", cache=None,
                    pos=None, prefix_len=2 ** 30, window=0)
                x = x + h
                x = x + layers.mlp(
                    p_sl[i]["mlp"],
                    rms_norm(x, p_sl[i]["norm2"]["scale"], cfg.norm_eps))
                a = jnp.zeros((), jnp.float32)
                nc = None
            else:
                x, nc, a = block_apply(cfg, kind, p_sl[i], x, mode=mode,
                                       cache=cache_i, pos=pos,
                                       prefix_len=prefix_len)
            new_c.append(nc if nc is not None else {})
            aux = aux + a
        x = _resid_shard(x, mode)
        return (x, aux), tuple(new_c)

    if mode == "train":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stack_params, caches))
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# chunked LM loss (bounded memory at 256k vocab)
# ---------------------------------------------------------------------------
def lm_loss(x, head_w, targets, mask=None, seq_chunk: int = 512):
    """x [B,S,D], head_w [D,V], targets [B,S] -> mean xent (fp32)."""
    B, S, D = x.shape
    c = min(seq_chunk, S)
    while S % c:
        c -= 1
    n = S // c
    wt = head_w.swapaxes(0, 1)  # [V, D]
    xs = (x.reshape(B, n, c, D).swapaxes(0, 1),
          targets.reshape(B, n, c).swapaxes(0, 1),
          (mask.reshape(B, n, c).swapaxes(0, 1) if mask is not None
           else jnp.ones((n, B, c), jnp.float32)))

    def body(acc, xs_i):
        xc, tc, mc = xs_i
        logits = jnp.einsum("bcd,dv->bcv", xc, head_w,
                            preferred_element_type=jnp.float32)
        m = jnp.max(logits, axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        lab = jnp.take(wt, tc, axis=0)                    # [B,c,D]
        lab_logit = jnp.einsum("bcd,bcd->bc", xc.astype(jnp.float32),
                               lab.astype(jnp.float32))
        nll = (lse - lab_logit) * mc.astype(jnp.float32)
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mc)), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), xs)
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# decoder-only LM (incl. VLM prefix variant)
# ---------------------------------------------------------------------------
class LM:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- params -------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, 4 + len(cfg.blocks))
        params = {
            "embed": layers.init_embed(keys[0], cfg.padded_vocab, cfg.d_model),
            "final_norm": init_norm(cfg.d_model),
            "lm_head": layers.init_lm_head(keys[1], cfg.d_model,
                                           cfg.padded_vocab),
            "blocks": self._init_blocks(keys[2], cfg.blocks, cfg.num_cycles),
        }
        if cfg.frontend is not None:
            params["frontend"] = layers.init_dense(
                keys[3], cfg.d_model, cfg.d_model)
        return params

    def _init_blocks(self, key, blocks, cycles):
        out = []
        for i, kind in enumerate(blocks):
            ks = jax.random.split(jax.random.fold_in(key, i), cycles)
            out.append(jax.vmap(
                lambda k, kind=kind: init_block(k, self.cfg, kind))(ks))
        return tuple(out)

    # -- embedding of a batch (handles vlm prefix) ---------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        x = layers.embed_lookup(params["embed"], batch["inputs"])
        prefix_len = 0
        if cfg.frontend is not None and "prefix_embeds" in batch:
            pre = layers.dense(batch["prefix_embeds"].astype(x.dtype),
                               params["frontend"]["w"])
            x = jnp.concatenate([pre, x], axis=1)
            prefix_len = pre.shape[1]
        if not cfg.prefix_bidir:
            prefix_len = 0
        return x, prefix_len

    # -- train ----------------------------------------------------------------
    def train_loss(self, params, batch):
        cfg = self.cfg
        x, prefix_len = self._embed(params, batch)
        x, _, aux = run_stack(cfg, cfg.blocks, params["blocks"], x,
                              mode="train", prefix_len=prefix_len)
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        # loss over the text positions only (skip any prefix)
        if prefix_len:
            x = x[:, prefix_len:]
        loss = lm_loss(x, params["lm_head"]["w"], batch["targets"],
                       batch.get("mask"))
        if cfg.num_experts:
            loss = loss + cfg.router_aux_coef * aux
        return loss, {"loss": loss, "aux": aux}

    # -- caches ---------------------------------------------------------------
    def _cache_proto(self, kind, batch, alloc):
        cfg = self.cfg
        mixer = kind[0]
        if mixer == "attn":
            return attention.init_attn_cache(cfg, batch, alloc)
        if mixer == "swa":
            return attention.init_attn_cache(cfg, batch,
                                             min(cfg.window_size, alloc))
        if mixer == "mamba":
            return mamba.init_mamba_cache(cfg, batch)
        if mixer == "mlstm":
            return xlstm.init_mlstm_cache(cfg, batch)
        if mixer == "slstm":
            return xlstm.init_slstm_cache(cfg, batch)
        raise ValueError(mixer)

    def init_cache(self, batch: int, alloc: int, stacked: bool = True):
        C = self.cfg.num_cycles
        out = []
        for kind in self.cfg.blocks:
            proto = jax.eval_shape(lambda k=kind: self._cache_proto(k, batch,
                                                                    alloc))
            out.append(jax.tree.map(
                lambda s: jnp.zeros((C,) + s.shape, s.dtype), proto))
        caches = tuple(out)
        if stacked:
            return caches
        # unrolled layout: tuple over cycles of per-position caches
        return tuple(
            tuple(jax.tree.map(lambda a: a[ci], pos_cache)
                  for pos_cache in caches)
            for ci in range(C))

    # -- prefill / decode -----------------------------------------------------
    def prefill(self, params, batch, alloc: int | None = None):
        cfg = self.cfg
        x, prefix_len = self._embed(params, batch)
        B, S = x.shape[0], x.shape[1]
        caches = self.init_cache(B, alloc or S)
        x, caches, _ = run_stack(cfg, cfg.blocks, params["blocks"], x,
                                 mode="prefill", caches=caches,
                                 prefix_len=prefix_len)
        x = rms_norm(x[:, -1:], params["final_norm"]["scale"], cfg.norm_eps)
        logits = layers.lm_logits(params["lm_head"], x)[:, 0]
        if cfg.decode_unroll:
            C = cfg.num_cycles
            caches = tuple(
                tuple(jax.tree.map(lambda a: a[ci], pc) for pc in caches)
                for ci in range(C))
        return logits, caches

    def decode_step(self, params, caches, token, pos):
        """token [B,1] int32; pos scalar int32 (same position per row).

        With cfg.decode_unroll the layer loop is a python loop: per-layer
        caches are separate top-level (donated) buffers that XLA updates
        in place — a scanned cache would be fully rewritten every step
        (EXPERIMENTS.md §Perf C3)."""
        cfg = self.cfg
        x = layers.embed_lookup(params["embed"], token)
        if not cfg.decode_unroll:
            x, caches, _ = run_stack(cfg, cfg.blocks, params["blocks"], x,
                                     mode="decode", caches=caches, pos=pos)
        else:
            new_caches = []
            for ci in range(cfg.num_cycles):
                p_sl = jax.tree.map(lambda a: a[ci], params["blocks"])
                x = _resid_shard(x, "decode")
                new_c = []
                for i, kind in enumerate(cfg.blocks):
                    x, nc, _ = block_apply(
                        cfg, kind, p_sl[i], x, mode="decode",
                        cache=caches[ci][i], pos=pos, prefix_len=0)
                    new_c.append(nc if nc is not None else {})
                new_caches.append(tuple(new_c))
            caches = tuple(new_caches)
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = layers.lm_logits(params["lm_head"], x)[:, 0]
        return logits, caches


# ---------------------------------------------------------------------------
# encoder-decoder (seamless-m4t): frame-embedding encoder + token decoder
# ---------------------------------------------------------------------------
ENC_BLOCK = (("attn", "mlp"),)


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key) -> dict:
        cfg = self.cfg
        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        enc_cycles = cfg.num_encoder_layers
        dec_cycles = cfg.num_cycles
        lm = LM(cfg)
        return {
            "frontend": layers.init_dense(k1, cfg.d_model, cfg.d_model),
            "embed": layers.init_embed(k2, cfg.padded_vocab, cfg.d_model),
            "enc_blocks": lm._init_blocks(k3, ENC_BLOCK, enc_cycles),
            "enc_norm": init_norm(cfg.d_model),
            "dec_blocks": self._init_dec_blocks(k4, dec_cycles),
            "final_norm": init_norm(cfg.d_model),
            "lm_head": layers.init_lm_head(k5, cfg.d_model, cfg.padded_vocab),
        }

    def _init_dec_blocks(self, key, cycles):
        cfg = self.cfg

        def one(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "norm1": init_norm(cfg.d_model),
                "self": attention.init_attn(k1, cfg),
                "norm2": init_norm(cfg.d_model),
                "cross": attention.init_attn(k2, cfg),
                "norm3": init_norm(cfg.d_model),
                "mlp": layers.init_mlp(k3, cfg.d_model, cfg.d_ff),
            }
        ks = jax.random.split(key, cycles)
        return (jax.vmap(one)(ks),)

    def encode(self, params, frames):
        cfg = self.cfg
        x = layers.dense(frames.astype(layers.DEFAULT_DTYPE),
                         params["frontend"]["w"])
        x, _, _ = run_stack(cfg, ENC_BLOCK, params["enc_blocks"], x,
                            mode="train", bidir=True)
        return rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)

    def _dec_stack(self, params, x, enc_out, *, mode, caches=None, pos=None):
        cfg = self.cfg
        if caches is None:
            caches = ({},)

        def body(carry, xs):
            x, _ = carry
            p, c = xs
            c = c[0] if c[0] else None
            x = _resid_shard(x, mode)
            h = rms_norm(x, p[0]["norm1"]["scale"], cfg.norm_eps)
            h, self_c = attention.attn_apply(
                cfg, p[0]["self"], h, mode=mode,
                cache=None if c is None else c["self"], pos=pos)
            x = x + h
            h = rms_norm(x, p[0]["norm2"]["scale"], cfg.norm_eps)
            if mode == "decode":
                h, cross_c = attention.attn_apply(
                    cfg, p[0]["cross"], h, mode="decode",
                    cache=c["cross"], pos=pos, is_cross=True)
            else:
                h, cross_c = attention.attn_apply(
                    cfg, p[0]["cross"], h, mode=mode,
                    cache=None if c is None else c["cross"],
                    kv_override=enc_out)
            x = x + h
            x = x + layers.mlp(p[0]["mlp"],
                               rms_norm(x, p[0]["norm3"]["scale"],
                                        cfg.norm_eps))
            new_c = {} if self_c is None else {"self": self_c,
                                               "cross": cross_c}
            return (x, carry[1]), (new_c,)

        if mode == "train":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, _), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["dec_blocks"], caches))
        return x, new_caches

    def train_loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = layers.embed_lookup(params["embed"], batch["inputs"])
        x, _ = self._dec_stack(params, x, enc_out, mode="train")
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        loss = lm_loss(x, params["lm_head"]["w"], batch["targets"],
                       batch.get("mask"))
        return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32)}

    def init_cache(self, batch: int, alloc: int, src_len: int):
        cfg = self.cfg
        C = cfg.num_cycles
        proto = {
            "self": jax.eval_shape(
                lambda: attention.init_attn_cache(cfg, batch, alloc)),
            "cross": jax.eval_shape(
                lambda: attention.init_attn_cache(cfg, batch, src_len)),
        }
        return (jax.tree.map(lambda s: jnp.zeros((C,) + s.shape, s.dtype),
                             proto),)

    def prefill(self, params, batch, alloc: int | None = None):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = layers.embed_lookup(params["embed"], batch["inputs"])
        B, S = x.shape[0], x.shape[1]
        caches = self.init_cache(B, alloc or S, enc_out.shape[1])
        x, caches = self._dec_stack(params, x, enc_out, mode="prefill",
                                    caches=caches)
        x = rms_norm(x[:, -1:], params["final_norm"]["scale"], cfg.norm_eps)
        return layers.lm_logits(params["lm_head"], x)[:, 0], caches

    def decode_step(self, params, caches, token, pos):
        cfg = self.cfg
        x = layers.embed_lookup(params["embed"], token)
        x, caches = self._dec_stack(params, x, None, mode="decode",
                                    caches=caches, pos=pos)
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        return layers.lm_logits(params["lm_head"], x)[:, 0], caches


def build_model(cfg):
    return EncDecLM(cfg) if cfg.is_encoder_decoder else LM(cfg)
