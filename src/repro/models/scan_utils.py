"""Chunked time scans with rematerialization.

A plain `lax.scan` over S timesteps saves every per-step carry for the
backward pass (O(S * |carry|) memory).  `chunked_scan` nests two scans —
outer over S/chunk chunks (whose boundary carries ARE saved), inner over
chunk steps wrapped in `jax.checkpoint` (recomputed during backward) — so
saved memory drops to O(S/chunk * |carry|) with one extra forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def chunked_scan(body, carry, xs, chunk: int = 64, remat: bool = True):
    """Like lax.scan(body, carry, xs) over leading axis S of every xs leaf,
    but chunked for memory.  S must be divisible by chunk (callers pad)."""
    S = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if S <= chunk:
        return jax.lax.scan(body, carry, xs)
    assert S % chunk == 0, f"seq {S} not divisible by chunk {chunk}"
    nc = S // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape((nc, chunk) + a.shape[1:]), xs)

    def inner(c, x_chunk):
        return jax.lax.scan(body, c, x_chunk)

    if remat:
        inner = jax.checkpoint(inner,
                               policy=jax.checkpoint_policies.nothing_saveable)

    carry, ys_c = jax.lax.scan(inner, carry, xs_c)
    ys = jax.tree.map(
        lambda a: a.reshape((S,) + a.shape[2:]), ys_c)
    return carry, ys


def pick_chunk(S: int, target: int = 64) -> int:
    c = min(target, S)
    while S % c:
        c -= 1
    return c
