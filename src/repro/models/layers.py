"""Core layer primitives: norms, RoPE, dense projections, embeddings.

All parameters are plain dicts of jnp arrays; every init function has a
matching structure so `jax.eval_shape` can derive ShapeDtypeStruct trees for
the dry-run without allocating.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.api import BATCH, SEQ, shard

DEFAULT_DTYPE = jnp.bfloat16


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 accumulation, cast back to x.dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_norm(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def dense(x: jax.Array, w, b: jax.Array | None = None) -> jax.Array:
    """Dense projection; dispatches to the W8A8 path when `w` is a
    quantized leaf {"q","n"} (repro.quant.lm_quant)."""
    if isinstance(w, dict) and "q" in w:
        from repro.quant.lm_quant import q_dense
        y = q_dense(x, w, out_dtype=x.dtype)
    else:
        y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def init_dense(key, d_in: int, d_out: int, bias: bool = False,
               dtype=DEFAULT_DTYPE, scale: float | None = None) -> dict:
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions [...,] int -> (sin, cos) [..., head_dim/2] fp32."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B, S, N, Dh], positions [B, S] (or [S]) -> rotated x (same dtype)."""
    sin, cos = rope_angles(positions, x.shape[-1], theta)
    # broadcast over the head axis
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------
def init_embed(key, vocab: int, d: int, dtype=DEFAULT_DTYPE) -> dict:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * (d ** -0.5)).astype(dtype)}


def embed_lookup(params: dict, tokens: jax.Array) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    return shard(out, BATCH, None, None)


def init_lm_head(key, d: int, vocab: int, dtype=DEFAULT_DTYPE) -> dict:
    return {"w": (jax.random.normal(key, (d, vocab), jnp.float32)
                  * (d ** -0.5)).astype(dtype)}


def lm_logits(params: dict, x: jax.Array) -> jax.Array:
    return dense(x, params["w"])


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, f: int, dtype=DEFAULT_DTYPE) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, f ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d, f), jnp.float32) * s_in).astype(dtype),
        "w_up":   (jax.random.normal(k2, (d, f), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d), jnp.float32) * s_out).astype(dtype),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    g = dense(x, params["w_gate"])
    u = dense(x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard(h, BATCH, None, "model")
    return dense(h, params["w_down"])


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array | None = None) -> jax.Array:
    """Mean cross entropy; logits [..., V] (fp32 accum), labels int [...]."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    label_logit = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
