"""Mixture-of-Experts: top-k router with capacity-limited, sort-free
scatter/gather dispatch (GShard-style groups).

Dispatch is *gather-based*, not einsum-based: tokens are scattered into a
[G, E, C, D] buffer by (expert, position-in-expert) slot and gathered back,
so dispatch costs **bytes, not FLOPs** — XLA's cost_analysis then reports
only real expert matmul FLOPs (plus the capacity_factor overprovision),
keeping the roofline honest.  The classic one-hot einsum dispatch would add
a G*S*E*C*D FLOP term that is 100x the expert compute at these sizes.

Groups: train/prefill group per batch row (keeps the dispatch local to the
data shard under GSPMD); decode uses a single group over the batch.

`moe_apply_ep` (shard_map all-to-all expert parallelism) lives in
`repro.models.moe_ep` and is the beyond-paper optimized path (§Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.api import BATCH, shard
from repro.models import layers


def init_moe(key, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, f ** -0.5
    dt = layers.DEFAULT_DTYPE
    return {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in).astype(dt),
        "w_up":   (jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out).astype(dt),
    }


def capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.experts_per_tok * cfg.capacity_factor
            / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4, >= 4


def moe_apply(params: dict, x: jax.Array, cfg, *, is_decode: bool = False):
    """x [B, S, D] -> (y [B, S, D], aux_loss scalar fp32)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_tok

    if is_decode:
        xg = x.reshape(1, B * S, D)           # one group over the batch
    else:
        xg = x                                 # group per batch row
    # NOTE (§Perf E1, refuted): the dominant MoE-train collectives are f32
    # all-reduces of dispatch-buffer-sized tensors over 'model' in the
    # BACKWARD pass (343 GB/dev/layer on phi-3.5).  Constraining the
    # forward tokens to unshard seq here did not move them (16.02 ->
    # 16.06 s) — the reduction belongs to the scatter/gather VJPs, which
    # only an explicit shard_map all-to-all EP dispatch removes (designed
    # in DESIGN.md §4; future work).
    G, T, _ = xg.shape
    C = capacity(T, cfg)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)      # [G,T,K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch): E * sum_e f_e * p_e -------------
    me = jnp.mean(probs, axis=1)                               # [G,E]
    ce = jnp.mean(jax.nn.one_hot(eidx[..., 0], E), axis=1)     # [G,E] top-1
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * E

    # ---- slot assignment --------------------------------------------------
    e_flat = eidx.reshape(G, T * K)                             # [G, TK]
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)         # [G, TK, E]
    pos = jnp.sum(jnp.cumsum(onehot, axis=1) * onehot, axis=-1) - 1  # [G,TK]
    keep = pos < C
    slot = jnp.where(keep, e_flat * C + pos, E * C)             # drop slot

    src = jnp.repeat(jnp.arange(T), K)                          # [TK]
    tok = jnp.take(xg, src, axis=1)                             # [G, TK, D]

    buf = jnp.zeros((G, E * C, D), xg.dtype)
    buf = jax.vmap(lambda b, s, t: b.at[s].set(t, mode="drop"))(buf, slot, tok)
    h = buf.reshape(G, E, C, D)
    if not is_decode:
        h = shard(h, BATCH, None, None, None)

    # ---- expert computation (SwiGLU; W8A8-aware) ---------------------------
    def expert_mm(spec, x_, w):
        if isinstance(w, dict) and "q" in w:
            from repro.quant.lm_quant import q_einsum
            return q_einsum(spec, x_, w, out_dtype=x_.dtype)
        return jnp.einsum(spec, x_, w)

    g = expert_mm("gecd,edf->gecf", h, params["w_gate"])
    u = expert_mm("gecd,edf->gecf", h, params["w_up"])
    a = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    y = expert_mm("gecf,efd->gecd", a, params["w_down"])

    # ---- combine ----------------------------------------------------------
    y_flat = y.reshape(G, E * C, D)
    out_tok = jax.vmap(lambda yy, s: jnp.take(yy, s, axis=0, mode="fill",
                                              fill_value=0))(y_flat, slot)
    out_tok = jnp.where(keep[..., None], out_tok, 0)
    out_tok = out_tok.reshape(G, T, K, D)
    out = jnp.einsum("gtkd,gtk->gtd", out_tok, gates.astype(out_tok.dtype))
    return out.reshape(B, S, D), aux.astype(jnp.float32)
