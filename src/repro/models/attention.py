"""Attention: chunked online-softmax (flash-style) prefill/train path,
cached decode path, GQA/MQA, sliding windows (ring-buffer cache), qk-norm,
prefix-LM masking.

The train/prefill path is pure JAX (scan over q-chunks x kv-chunks with
running max/denominator) so that (a) activation memory stays O(S * chunk)
instead of O(S^2) and (b) XLA cost_analysis sees every FLOP (Pallas
custom-calls would hide them from the roofline; see DESIGN.md §6).

Sliding-window layers slice a static [q_chunk + window] KV strip per q-chunk
(honest O(S*(window+chunk)) FLOPs).  Global causal layers compute the full
masked rectangle: HLO_FLOPs ~ 2x the causal ideal, which is deliberately
visible in the MODEL_FLOPS/HLO_FLOPs roofline ratio (EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.api import BATCH, shard
from repro.models import layers
from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_attn(key, cfg) -> dict:
    d = cfg.d_model
    h_eff = cfg.num_heads + cfg.head_pad
    qdim = h_eff * cfg.head_dim
    kdim = cfg.num_kv_heads * cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    wq = jax.random.normal(ks[0], (d, qdim), jnp.float32) * s
    if cfg.head_pad:  # zero the padded query heads (function-preserving)
        wq = wq.at[:, cfg.num_heads * cfg.head_dim:].set(0.0)
    p = {
        "wq": wq.astype(layers.DEFAULT_DTYPE),
        "wk": (jax.random.normal(ks[1], (d, kdim), jnp.float32) * s).astype(layers.DEFAULT_DTYPE),
        "wv": (jax.random.normal(ks[2], (d, kdim), jnp.float32) * s).astype(layers.DEFAULT_DTYPE),
        "wo": _zero_pad_rows(
            jax.random.normal(ks[3], (qdim, d), jnp.float32)
            * (qdim ** -0.5), cfg).astype(layers.DEFAULT_DTYPE),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qdim,), layers.DEFAULT_DTYPE)
        p["bk"] = jnp.zeros((kdim,), layers.DEFAULT_DTYPE)
        p["bv"] = jnp.zeros((kdim,), layers.DEFAULT_DTYPE)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((cfg.head_dim,), jnp.float32)
    return p


def _zero_pad_rows(wo, cfg):
    if cfg.head_pad:
        wo = wo.at[cfg.num_heads * cfg.head_dim:].set(0.0)
    return wo


def _repeat_kv(x: jax.Array, groups: int) -> jax.Array:
    """[B, S, K, Dh] -> [B, S, K*groups, Dh] (GQA -> MHA expansion)."""
    if groups == 1:
        return x
    return jnp.repeat(x, groups, axis=2)


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (n assumed power-of-2-ish)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# chunked online-softmax attention (train / prefill)
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal=True, window=0, prefix_len=0,
                    q_chunk=512, kv_chunk=1024):
    """q [B,Sq,H,Dh]; k,v [B,Sk,K,Dh].  Positions are array indices.

    Returns [B,Sq,H,Dh] in q.dtype, with fp32 softmax accumulation.
    """
    B, Sq, H, Dh = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = Dh ** -0.5
    qc = _pick_chunk(Sq, q_chunk)
    nq = Sq // qc

    qb = q.reshape(B, nq, qc, H, Dh).transpose(1, 0, 2, 3, 4)

    if window > 0:
        # static KV strip per q-chunk: [window + qc]
        strip = window + qc
        pad = max(strip - Sk, 0)
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

        def q_body(_, xs):
            q_blk, qi = xs
            q0 = qi * qc
            start = jnp.clip(q0 - window + pad, 0, Sk + pad - strip)
            ks_ = jax.lax.dynamic_slice_in_dim(kp, start, strip, axis=1)
            vs_ = jax.lax.dynamic_slice_in_dim(vp, start, strip, axis=1)
            # padded index i holds position i - pad
            kv_pos = start - pad + jnp.arange(strip)
            q_pos = q0 + jnp.arange(qc)
            o = _attend_block(q_blk, ks_, vs_, q_pos, kv_pos, causal, window,
                              prefix_len, G, scale, kv_chunk)
            return None, o

        _, ob = jax.lax.scan(q_body, None, (qb, jnp.arange(nq)))
    else:
        def q_body(_, xs):
            q_blk, qi = xs
            q_pos = qi * qc + jnp.arange(qc)
            kv_pos = jnp.arange(Sk)
            o = _attend_block(q_blk, k, v, q_pos, kv_pos, causal, 0,
                              prefix_len, G, scale, kv_chunk)
            return None, o

        _, ob = jax.lax.scan(q_body, None, (qb, jnp.arange(nq)))

    return ob.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh)


def _attend_block(q_blk, k, v, q_pos, kv_pos, causal, window, prefix_len,
                  G, scale, kv_chunk):
    """One q-chunk against a KV strip, inner scan over KV chunks.

    q_blk [B,qc,H,Dh]; k,v [B,Skv,K,Dh]; q_pos [qc]; kv_pos [Skv].
    """
    B, qc, H, Dh = q_blk.shape
    Skv = k.shape[1]
    kc = _pick_chunk(Skv, kv_chunk)
    nk = Skv // kc
    kb = k.reshape(B, nk, kc, -1, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kc, -1, Dh).transpose(1, 0, 2, 3, 4)
    pb = kv_pos.reshape(nk, kc)
    qf = q_blk.astype(jnp.float32) * scale

    K = H // G
    qg = qf.reshape(B, qc, K, G, Dh)

    def kv_body(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, kp = xs                       # [B,kc,K,Dh]
        # grouped-query einsum: the G-fold KV repeat is implicit
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(q_blk.dtype), k_blk,
                       preferred_element_type=jnp.float32)
        mask = _mask(q_pos[:, None], kp[None, :], causal, window, prefix_len)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, qc), jnp.float32)
    a0 = jnp.zeros((B, K, G, qc, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]        # [B,K,G,qc,Dh]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, qc, H, Dh)
    return out.astype(q_blk.dtype)


def _mask(qp, kp, causal, window, prefix_len):
    ok = (kp <= qp) if causal else (kp >= 0)
    if window > 0:
        ok &= kp > qp - window
    if prefix_len > 0:
        ok |= (kp < prefix_len) & (qp < prefix_len)
    ok &= kp >= 0
    return ok


# ---------------------------------------------------------------------------
# int8 KV cache (paper's Qm.n format on the cache; §Perf C5)
# ---------------------------------------------------------------------------
def quantize_kv(x):
    """x [B,S,K,Dh] -> (int8 values, int8 exponents [B,S,K]).
    Per-(position, head) power-of-two scales: q = round(x * 2^e)."""
    xf = x.astype(jnp.float32)
    max_abs = jnp.max(jnp.abs(xf), axis=-1)
    e = jnp.clip(jnp.floor(jnp.log2(127.0 / jnp.maximum(max_abs, 1e-30))),
                 -24, 24)
    q = jnp.clip(jnp.round(xf * jnp.exp2(e)[..., None]), -128, 127)
    return q.astype(jnp.int8), e.astype(jnp.int8)


def _int8_cached_attention(q, cache, kv_pos, q_pos, ax):
    """Decode attention on the int8 cache.

    QK^T runs as a pure int8 x int8 -> int32 einsum (the MXU's 2x-rate
    path; the paper's matmul_q7 pattern with dynamic instead of static
    exponents) descaled by the pow2 exponents.  The PV product folds the
    per-position v exponents into the probabilities (they cannot factor
    out of an integer accumulation), so v is dequantized in-register —
    v still LIVES in HBM as int8 (half the cache bytes).
    """
    B, Q, H, Dh = q.shape
    K = cache["k"].shape[2]
    G = H // K
    kq, ke = cache["k"], cache["k_e"]
    vq, ve = cache["v"], cache["v_e"]
    if ax is not None:
        b, seq = ax
        q = shard(q, b, None, None, None)
        kq = shard(kq, b, seq, None, None)
        vq = shard(vq, b, seq, None, None)
    qq, qe = quantize_kv(q)                        # [B,Q,H,Dh], [B,Q,H]
    qg = qq.reshape(B, Q, K, G, Dh)
    acc = jnp.einsum("bqkgd,bskd->bkgqs", qg, kq,
                     preferred_element_type=jnp.int32)
    scale = Dh ** -0.5
    qe_g = qe.reshape(B, Q, K, G).transpose(0, 2, 3, 1)      # [B,K,G,Q]
    de = jnp.exp2(-(qe_g[..., None].astype(jnp.float32)
                    + ke.transpose(0, 2, 1)[:, :, None, None, :]
                    .astype(jnp.float32)))
    s = acc.astype(jnp.float32) * de * scale
    ok = (kv_pos <= q_pos) & (kv_pos >= 0)
    s = jnp.where(ok[None, None, None, None, :], s, NEG_INF)
    if ax is not None:
        s = shard(s, ax[0], None, None, None, ax[1])
    p = jax.nn.softmax(s, axis=-1)
    pw = p * jnp.exp2(-ve.transpose(0, 2, 1)[:, :, None, None, :]
                      .astype(jnp.float32))
    o = jnp.einsum("bkgqs,bskd->bkgqd", pw.astype(jnp.bfloat16),
                   vq.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    if ax is not None:
        o = shard(o, ax[0], None, None, None, None)
    return o.reshape(B, K, G, Q, Dh).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Q, H, Dh).astype(jnp.bfloat16)


def _decode_seq_axes(batch: int):
    """Cache sharding layout at decode (must mirror sharding.cache_specs):
    batch over DP + seq over 'model' when the batch shards; otherwise seq
    over ('data','model').  Returns (batch_axes, seq_axes) or None."""
    from repro.dist.api import current_mesh, dp_size
    mesh = current_mesh()
    if mesh is None:
        return None
    shardable = batch % dp_size(mesh) == 0 and batch >= dp_size(mesh)
    if shardable:
        return BATCH, "model"
    return None, ("data", "model")


def cached_attention(q, k_cache, v_cache, kv_pos, q_pos, groups):
    """q [B,1,H,Dh]; caches [B,S,K,Dh]; kv_pos [S] (position per slot, may be
    invalid/negative); q_pos scalar.  fp32 softmax over the whole cache.

    Sharding: sequence-sharded attention.  The cache stays sharded on its
    seq dim; q is replicated over 'model'; every chip computes all heads
    over its seq shard and the softmax/output reductions psum over the seq
    axes.  Without these constraints GSPMD head-shards the scores and
    ALL-GATHERS the whole KV cache over 'model' per layer (measured:
    18.5 GB/dev/layer on gemma3 decode_32k — EXPERIMENTS.md §Perf C1).
    """
    B, Q, H, Dh = q.shape
    K = k_cache.shape[2]
    G = H // K
    ax = _decode_seq_axes(B)
    if ax is not None:
        b, seq = ax
        q = shard(q, b, None, None, None)
        k_cache = shard(k_cache, b, seq, None, None)
        v_cache = shard(v_cache, b, seq, None, None)
    scale = Dh ** -0.5
    # grouped-query einsum: never materialize the G-fold repeated cache
    # (an explicit repeat costs G x cache bytes: 8x for qwen2 — §Perf C2)
    qg = (q * scale).reshape(B, Q, K, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    ok = (kv_pos <= q_pos) & (kv_pos >= 0)
    s = jnp.where(ok[None, None, None, None, :], s, NEG_INF)
    if ax is not None:
        s = shard(s, ax[0], None, None, None, ax[1])
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    if ax is not None:
        o = shard(o, ax[0], None, None, None, None)
    return o.reshape(B, Q, H, Dh).astype(q.dtype)


def ring_positions(q_pos, alloc: int):
    """Position stored in each ring slot i after writes up to q_pos:
    largest p <= q_pos with p % alloc == i (negative -> never written)."""
    i = jnp.arange(alloc)
    return q_pos - ((q_pos - i) % alloc)


# ---------------------------------------------------------------------------
# full attention mixer (projections + rope + dispatch by mode)
# ---------------------------------------------------------------------------
def attn_apply(cfg, params, x, *, mode: str, cache=None, pos=None,
               prefix_len: int = 0, window: int = 0,
               kv_override=None, is_cross: bool = False):
    """x [B,S,D].  mode: train | prefill | decode.
    cache: {"k","v"} [B,S_alloc,K,Dh] for prefill(out)/decode(in+out).
    kv_override: encoder hidden states [B,Skv,D] for cross-attention at
    train/prefill (decode cross reads the cache only, is_cross=True).
    Returns (out [B,S,D], new_cache).
    """
    is_cross = is_cross or (kv_override is not None)
    B, S, D = x.shape
    H = cfg.num_heads + cfg.head_pad
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    G = H // K

    q = layers.dense(x, params["wq"], params.get("bq")).reshape(B, S, H, Dh)
    if kv_override is not None:
        x_kv = kv_override
        Skv = x_kv.shape[1]
        k = layers.dense(x_kv, params["wk"], params.get("bk")).reshape(B, Skv, K, Dh)
        v = layers.dense(x_kv, params["wv"], params.get("bv")).reshape(B, Skv, K, Dh)
    elif is_cross and mode == "decode":
        k = v = None  # encoder K/V already live in the cache
    else:
        k = layers.dense(x, params["wk"], params.get("bk")).reshape(B, S, K, Dh)
        v = layers.dense(x, params["wv"], params.get("bv")).reshape(B, S, K, Dh)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        if k is not None:
            k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    use_rope = cfg.rope_theta > 0 and not is_cross
    if mode in ("train", "prefill"):
        if use_rope:
            positions = jnp.arange(S)[None, :]
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        q = shard(q, BATCH, None, "model", None)
        k = shard(k, BATCH, None, None, None)
        v = shard(v, BATCH, None, None, None)
        causal = kv_override is None
        # NOTE (§Perf D1, refuted): wrapping this call in jax.checkpoint
        # (flash-style bwd recompute instead of scan-grad p-saves) traded
        # the saved-tensor traffic for an equal recompute-read traffic at
        # these shapes (qwen2 train: 67.9s -> 72.1s memory term), so the
        # scan-grad saves are kept.
        o = flash_attention(q, k, v, causal=causal, window=window,
                            prefix_len=prefix_len)
        new_cache = None
        if mode == "prefill" and cache is not None:
            new_cache = _fill_cache(cache, k, v, window)
        out = layers.dense(o.reshape(B, S, H * Dh), params["wo"])
        return out, new_cache

    # ---- decode: S == 1 -------------------------------------------------
    assert mode == "decode"
    if use_rope:
        positions = jnp.full((B, 1), pos)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if is_cross:
        # cross-attention at decode reads the (static) encoder cache
        kv_pos_arr = jnp.arange(cache["k"].shape[1])
        o = cached_attention(q, cache["k"], cache["v"], kv_pos_arr,
                             jnp.asarray(2**30), G)
        out = layers.dense(o.reshape(B, 1, H * Dh), params["wo"])
        return out, cache
    alloc = cache["k"].shape[1]
    if window > 0 and alloc <= window:
        slot = pos % alloc
        kv_pos_arr = ring_positions(pos, alloc)
    else:
        slot = pos
        kv_pos_arr = jnp.arange(alloc)
        if window > 0:  # full cache but windowed layer: mask stale slots
            kv_pos_arr = jnp.where(kv_pos_arr > pos - window, kv_pos_arr, -1)
    dus = lambda buf, upd: jax.lax.dynamic_update_slice_in_dim(
        buf, upd, slot, axis=1)
    if cfg.kv_cache_int8:
        kq, ke = quantize_kv(k)
        vq, ve = quantize_kv(v)
        new_cache = {"k": dus(cache["k"], kq), "k_e": dus(cache["k_e"], ke),
                     "v": dus(cache["v"], vq), "v_e": dus(cache["v_e"], ve)}
        o = _int8_cached_attention(q, new_cache, kv_pos_arr, pos,
                                   _decode_seq_axes(B))
    else:
        new_cache = {"k": dus(cache["k"], k), "v": dus(cache["v"], v)}
        o = cached_attention(q, new_cache["k"], new_cache["v"], kv_pos_arr,
                             pos, G)
    out = layers.dense(o.reshape(B, 1, H * Dh), params["wo"])
    return out, new_cache


def _fill_cache(cache, k, v, window: int):
    """Write prefill K/V into an allocated cache (ring layout for SWA;
    int8 caches quantize on write)."""
    alloc = cache["k"].shape[1]
    S = k.shape[1]
    int8 = "k_e" in cache
    parts = {}
    if int8:
        parts["k"], parts["k_e"] = quantize_kv(k)
        parts["v"], parts["v_e"] = quantize_kv(v)
    else:
        parts["k"], parts["v"] = k, v
    out = {}
    for name, val in parts.items():
        if window > 0 and alloc <= window:
            take = min(S, alloc)
            last = val[:, -take:]
            # ring invariant: position p lives in slot p % alloc
            shift = (S - take) % alloc if take < alloc else S % alloc
            last = jnp.roll(last, shift, axis=1)
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], last, 0, axis=1)
        else:
            out[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], val, 0, axis=1)
    return out


def init_attn_cache(cfg, batch: int, alloc: int, dtype=jnp.bfloat16) -> dict:
    K, Dh = cfg.num_kv_heads, cfg.head_dim
    if getattr(cfg, "kv_cache_int8", False):
        return {"k": jnp.zeros((batch, alloc, K, Dh), jnp.int8),
                "k_e": jnp.zeros((batch, alloc, K), jnp.int8),
                "v": jnp.zeros((batch, alloc, K, Dh), jnp.int8),
                "v_e": jnp.zeros((batch, alloc, K), jnp.int8)}
    return {"k": jnp.zeros((batch, alloc, K, Dh), dtype),
            "v": jnp.zeros((batch, alloc, K, Dh), dtype)}
