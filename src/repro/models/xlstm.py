"""xLSTM mixers: mLSTM (matrix memory, exp-gated linear attention) and
sLSTM (scalar memory with block-diagonal recurrent gates).

Both run as chunked time scans (honest FLOPs, bounded remat memory).  The
mLSTM here follows the xLSTM paper's stabilized exponential gating (running
max m); the block carries its own up/down projections (projection factor 2)
since the assignment specifies d_ff = 0.  sLSTM blocks append the paper's
pf = 4/3 gated FFN.  Decode is O(1)-state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.api import BATCH, shard
from repro.models import layers
from repro.models.scan_utils import chunked_scan, pick_chunk


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg) -> dict:
    d, ed, h = cfg.d_model, cfg.xlstm_inner, cfg.num_heads
    ks = jax.random.split(key, 7)
    dt = layers.DEFAULT_DTYPE
    s, si = d ** -0.5, ed ** -0.5
    return {
        "up_proj":  (jax.random.normal(ks[0], (d, 2 * ed), jnp.float32) * s).astype(dt),
        "wq": (jax.random.normal(ks[1], (ed, ed), jnp.float32) * si).astype(dt),
        "wk": (jax.random.normal(ks[2], (ed, ed), jnp.float32) * si).astype(dt),
        "wv": (jax.random.normal(ks[3], (ed, ed), jnp.float32) * si).astype(dt),
        "wi": (jax.random.normal(ks[4], (ed, h), jnp.float32) * si).astype(jnp.float32),
        "bi": jnp.zeros((h,), jnp.float32),
        "wf": (jax.random.normal(ks[5], (ed, h), jnp.float32) * si).astype(jnp.float32),
        "bf": jnp.full((h,), 3.0, jnp.float32),   # open forget gates at init
        "down_proj": (jax.random.normal(ks[6], (ed, d), jnp.float32) * si).astype(dt),
    }


def _mlstm_chunked(q, k, v, ig, logf, C0, n0, m0, chunk: int):
    """Chunkwise-parallel mLSTM (closed form within chunks; §Perf A1).

    Exactly equivalent to the per-step recurrence (tested to fp32
    tolerance): within a chunk, with F_t = cumsum(logf) and stabilizer
    m_t = F_t + max(m0, cummax(i_t - F_t)),
        h_t = [exp(F_t + m0 - m_t) C0 q_t + sum_{s<=t} D_ts (k_s.q_t) v_s]
              / max(|n_t . q_t|, exp(-m_t)),
        D_ts = exp(F_t - F_s + i_s - m_t).
    The matrix state is read/written once per CHUNK instead of once per
    step — a (chunk)x HBM-traffic reduction on the dominant term.

    q,k,v [B,S,H,dh] fp32; ig/logf [B,S,H]; carry C0 [B,H,dv,dk],
    n0 [B,H,dk], m0 [B,H].  Returns (h [B,S,H,dv], (C,n,m)).
    """
    B, S, H, dh = q.shape
    nc = S // chunk
    r = lambda a: a.reshape(B, nc, chunk, H, -1).transpose(1, 0, 3, 2, 4)
    rq, rk, rv = r(q), r(k), r(v)                       # [nc,B,H,c,dh]
    rg = lambda a: a.reshape(B, nc, chunk, H).transpose(1, 0, 3, 2)
    ri, rf = rg(ig), rg(logf)                           # [nc,B,H,c]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def body(carry, xs):
        C0, n0, m0 = carry
        qt, kt, vt, it, ft = xs
        F = jnp.cumsum(ft, -1)
        b = jax.lax.cummax(it - F, axis=it.ndim - 1)
        m = F + jnp.maximum(m0[..., None], b)           # [B,H,c]
        di = jnp.exp(F + m0[..., None] - m)
        logD = (F[..., :, None] - F[..., None, :]
                + it[..., None, :] - m[..., :, None])
        D = jnp.where(tri, jnp.exp(logD), 0.0)
        G = jnp.einsum("bhtk,bhsk->bhts", qt, kt)
        inter = jnp.einsum("bhvk,bhtk->bhtv", C0, qt) * di[..., None]
        num = inter + jnp.einsum("bhts,bhsv->bhtv", G * D, vt)
        nvec = (n0[..., None, :] * di[..., None]
                + jnp.einsum("bhts,bhsk->bhtk", D, kt))
        den = jnp.maximum(jnp.abs(jnp.einsum("bhtk,bhtk->bht", nvec, qt)),
                          jnp.exp(-m))
        h = num / den[..., None]                        # [B,H,c,dv]
        mc, Fc = m[..., -1], F[..., -1]
        w = jnp.exp(Fc[..., None] - F + it - mc[..., None])
        decay = jnp.exp(Fc + m0 - mc)
        Cn = decay[..., None, None] * C0 \
            + jnp.einsum("bhs,bhsv,bhsk->bhvk", w, vt, kt)
        nn = decay[..., None] * n0 + jnp.einsum("bhs,bhsk->bhk", w, kt)
        return (Cn, nn, mc), h

    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), (rq, rk, rv, ri, rf))
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, -1)
    return h, (C, n, m)


def mlstm_apply(params, x, cfg, *, mode: str, cache=None):
    """x [B,S,D] -> (y, new_cache {C,n,m})."""
    B, S, D = x.shape
    ed, H = cfg.xlstm_inner, cfg.num_heads
    dh = ed // H

    up = layers.dense(x, params["up_proj"])
    up = shard(up, BATCH, None, "model")
    inner, z = jnp.split(up, 2, axis=-1)

    q = layers.dense(inner, params["wq"]).reshape(B, S, H, dh) * dh ** -0.5
    k = layers.dense(inner, params["wk"]).reshape(B, S, H, dh) * dh ** -0.5
    v = layers.dense(inner, params["wv"]).reshape(B, S, H, dh)
    ig = (jnp.einsum("bse,eh->bsh", inner.astype(jnp.float32), params["wi"])
          + params["bi"])
    fg = (jnp.einsum("bse,eh->bsh", inner.astype(jnp.float32), params["wf"])
          + params["bf"])

    if cache is not None:
        C0 = cache["C"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"]
    else:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)

    def body(carry, xs):
        C, n, m = carry
        q_t, k_t, v_t, i_t, f_t = xs         # [B,H,dh] x3, [B,H] x2
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        fp = jnp.exp(logf + m - m_new)
        ip = jnp.exp(i_t - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :])        # [B,H,dv,dk]
        n = fp[..., None] * n + ip[..., None] * k_t
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)),
                          jnp.exp(-m_new))
        h_t = num / den[..., None]
        return (C, n, m_new), h_t

    xs = (q.swapaxes(0, 1).astype(jnp.float32),
          k.swapaxes(0, 1).astype(jnp.float32),
          v.swapaxes(0, 1).astype(jnp.float32),
          ig.swapaxes(0, 1), fg.swapaxes(0, 1))
    chunk = pick_chunk(S, cfg.xlstm_chunk)
    if mode == "decode":
        (C, n, m), h = body((C0, n0, m0),
                            jax.tree.map(lambda a: a[0], xs))
        hs = h[:, None]                      # [B,1,H,dh]
    elif cfg.xlstm_impl == "chunked" and S % chunk == 0 and S > 1:
        logf = jax.nn.log_sigmoid(fg)        # [B,S,H]
        hs, (C, n, m) = _mlstm_chunked(
            q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), ig, logf, C0, n0, m0, chunk)
    else:
        (C, n, m), hs = chunked_scan(body, (C0, n0, m0), xs,
                                     chunk=chunk)
        hs = hs.swapaxes(0, 1)               # [B,S,H,dh]

    out = hs.reshape(B, S if mode != "decode" else 1, ed).astype(x.dtype)
    out = out * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = layers.dense(out, params["down_proj"])
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"C": C, "n": n, "m": m}
    return out, new_cache


def init_mlstm_cache(cfg, batch: int) -> dict:
    ed, H = cfg.xlstm_inner, cfg.num_heads
    dh = ed // H
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, cfg) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    # pf = 4/3, rounded up to a multiple of 128 so the (data, model) 16-way
    # sharding divides it (2731 -> 2816 for d=2048; noted in DESIGN.md)
    ff = -(-(-(-4 * d // 3)) // 128) * 128
    ks = jax.random.split(key, 4)
    dt = layers.DEFAULT_DTYPE
    s = d ** -0.5
    return {
        "wx": (jax.random.normal(ks[0], (d, 4 * d), jnp.float32) * s).astype(dt),
        "bx": jnp.zeros((4 * d,), jnp.float32),
        "r":  (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32)
               * dh ** -0.5).astype(dt),
        "ffn_up": (jax.random.normal(ks[2], (d, 2 * ff), jnp.float32) * s).astype(dt),
        "ffn_down": (jax.random.normal(ks[3], (ff, d), jnp.float32)
                     * ff ** -0.5).astype(dt),
    }


def slstm_apply(params, x, cfg, *, mode: str, cache=None):
    """x [B,S,D] -> (y, new_cache {c,n,m,h})."""
    B, S, D = x.shape
    H = cfg.num_heads
    dh = D // H

    gx = (layers.dense(x, params["wx"]).astype(jnp.float32)
          + params["bx"])                    # [B,S,4D]

    if cache is not None:
        c0, n0, m0, h0 = (cache["c"], cache["n"], cache["m"], cache["h"])
    else:
        z = jnp.zeros((B, D), jnp.float32)
        c0, n0, m0, h0 = z, z + 1e-6, z - 1e30, z

    r = params["r"]

    def body(carry, gx_t):
        c, n, m, h = carry
        hh = h.reshape(B, H, dh)
        rec = jnp.einsum("bhd,hdf->bhf", hh, r.astype(jnp.float32))
        # layout: per head, [i f z o] each dh wide (gx re-interleaved below)
        g = (gx_t + rec.reshape(B, H * 4 * dh)).reshape(B, H, 4, dh)
        gi, gf, gz, go = g[:, :, 0], g[:, :, 1], g[:, :, 2], g[:, :, 3]
        mh = m.reshape(B, H, dh)
        m_new = jnp.maximum(gf + mh, gi)
        fp = jnp.exp(gf + mh - m_new)
        ip = jnp.exp(gi - m_new)
        ch = fp * c.reshape(B, H, dh) + ip * jnp.tanh(gz)
        nh = fp * n.reshape(B, H, dh) + ip
        hh_new = jax.nn.sigmoid(go) * ch / jnp.maximum(nh, 1e-6)
        flat = lambda a: a.reshape(B, D)
        return (flat(ch), flat(nh), flat(m_new), flat(hh_new)), flat(hh_new)

    # recurrent weight layout fix: wx produces [i f z o] blocks of D each;
    # re-interleave to per-head [i f z o] once, outside the scan.
    gx = gx.reshape(B, S, 4, H, dh).transpose(0, 1, 3, 2, 4).reshape(B, S, 4 * D)

    if mode == "decode":
        (c, n, m, h), y = body((c0, n0, m0, h0), gx[:, 0])
        ys = y[:, None]
    else:
        (c, n, m, h), ys = chunked_scan(body, (c0, n0, m0, h0),
                                        gx.swapaxes(0, 1),
                                        chunk=pick_chunk(S, 64))
        ys = ys.swapaxes(0, 1)

    out = ys.astype(x.dtype)
    # pf=4/3 gated FFN
    uu = layers.dense(out, params["ffn_up"])
    u1, u2 = jnp.split(uu, 2, axis=-1)
    out = layers.dense(
        jax.nn.gelu(u1.astype(jnp.float32)).astype(x.dtype) * u2,
        params["ffn_down"])
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"c": c, "n": n, "m": m, "h": h}
    return out, new_cache


def init_slstm_cache(cfg, batch: int) -> dict:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "m": z - 1e30, "h": z}
