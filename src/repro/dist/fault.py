"""Fault tolerance: elastic mesh selection, crash-restart driver, and
straggler-aware step timing.

`run_with_restarts` wraps the whole training loop: the step callable is
rebuilt from the latest checkpoint on every attempt, so a node failure
costs at most `ckpt_every` steps of work.  `choose_mesh` re-plans the
(pod, data, model) factorization after capacity loss — model parallelism
is fixed by the sharded layer widths, so only pod/data flex.
"""
from __future__ import annotations

import time


def choose_mesh(chips: int, model: int = 16) -> tuple:
    """Factor `chips` into (pod, data, model) with the model axis fixed.

    data is kept as close to 16-wide as possible; losing hosts shrinks
    the data axis (e.g. 480 chips -> (2, 15, 16)).  Raises ValueError
    when `chips` does not factor (training cannot proceed elastically).
    """
    if chips <= 0 or chips % model:
        raise ValueError(f"{chips} chips do not factor over model={model}")
    rest = chips // model
    pod = max(1, -(-rest // 16))            # ceil(rest / 16)
    while pod <= rest and rest % pod:
        pod += 1
    if pod > rest:
        raise ValueError(f"{chips} chips do not factor over model={model}")
    return (pod, rest // pod, model)


def run_with_restarts(fn, max_restarts: int = 2, backoff_s: float = 5.0):
    """Call `fn(attempt)` until it returns, restarting on any exception up
    to `max_restarts` times with linear backoff.  The callable is expected
    to resume from its own checkpoints."""
    attempt = 0
    while True:
        try:
            return fn(attempt)
        except Exception as e:                      # noqa: BLE001
            if attempt >= max_restarts:
                raise
            attempt += 1
            print(f"[restart {attempt}/{max_restarts}] {type(e).__name__}: "
                  f"{e}")
            time.sleep(backoff_s * attempt)


class StepTimer:
    """Wall-clock step timer with a running mean for straggler detection
    (a step is a straggler when it exceeds `factor` x the running mean)."""

    def __init__(self, factor: float = 3.0, warmup: int = 3):
        self.factor = factor
        self.warmup = warmup
        self._t0 = None
        self._n = 0
        self._mean = 0.0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self._n += 1
        # running mean, excluding compile-dominated warmup steps
        if self._n > self.warmup:
            k = self._n - self.warmup
            self._mean += (dt - self._mean) / k
        return dt

    def is_straggler(self, dt: float) -> bool:
        return self._n > self.warmup + 1 and self._mean > 0 \
            and dt > self.factor * self._mean
