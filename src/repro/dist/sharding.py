"""PartitionSpec rules for every tree the launch layer ships to devices.

Policy (GSPMD does the rest):
  params     — tensor parallel: the trailing (output-feature) axis of
               every >=2D weight shards over "model"; vectors replicate.
  opt state  — moment trees mirror the param rule; scalars replicate.
  batches    — leading axis over the BATCH (pod x data) axes when the
               global batch divides the DP ways, else replicated.
  caches     — mirrors models.attention._decode_seq_axes: batch over DP
               plus seq over "model" when the batch shards, otherwise seq
               over ("data", "model").
Every spec goes through `api.fspec` at conversion time, so the same rules
serve 2-axis and 3-axis meshes.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.api import BATCH, dp_size, fspec


def _leaf_spec(leaf) -> P:
    if len(leaf.shape) >= 2 and leaf.shape[-1] > 1:
        return P(*([None] * (len(leaf.shape) - 1) + ["model"]))
    return P()


def param_specs(tree):
    """One PartitionSpec per parameter leaf (ndim-matched, see policy)."""
    return jax.tree.map(_leaf_spec, tree)


def opt_state_specs(opt_state, params):
    """Specs for an optimizer-state dict: entries shaped like the param
    tree (m/v moments) inherit param specs; everything else replicates."""
    ptree = jax.tree_util.tree_structure(params)

    def per_entry(sub):
        if jax.tree_util.tree_structure(sub) == ptree:
            return param_specs(sub)
        return jax.tree.map(lambda _: P(), sub)

    return {k: per_entry(v) for k, v in opt_state.items()}


def batch_specs(batch, global_batch: int, mesh):
    """Shard the leading axis of every batch leaf over DP when it divides."""
    dp = dp_size(mesh)
    shardable = dp > 1 and global_batch % dp == 0 and global_batch >= dp

    def spec(leaf):
        if shardable and len(leaf.shape) >= 1 \
                and leaf.shape[0] == global_batch:
            return P(*([BATCH] + [None] * (len(leaf.shape) - 1)))
        return P()

    return jax.tree.map(spec, batch)


def cache_specs(cache, global_batch: int, mesh, stacked: bool = True):
    """Decode-cache specs (stacked caches carry a leading layer axis)."""
    dp = dp_size(mesh)
    shardable = dp > 1 and global_batch % dp == 0 and global_batch >= dp
    off = 1 if stacked else 0
    b_ax, s_ax = (BATCH, "model") if shardable else (None, ("data", "model"))

    def spec(leaf):
        nd = len(leaf.shape)
        if nd < off + 2:
            return P()
        ent = [None] * nd
        ent[off] = b_ax
        ent[off + 1] = s_ax
        return P(*ent)

    return jax.tree.map(spec, cache)


def to_shardings(spec: P, mesh) -> NamedSharding:
    """PartitionSpec -> NamedSharding, filtering axes the mesh lacks."""
    return NamedSharding(mesh, fspec(mesh, *spec))
