"""Distribution layer: mesh-aware sharding helpers, fault tolerance, and
the trip-count-aware HLO cost model.

Submodules:
  api          — logical axis names (BATCH/SEQ), `shard` constraints, mesh
                 introspection (`current_mesh`, `dp_size`, `fspec`).
  sharding     — PartitionSpec rules for params / optimizer state /
                 batches / decode caches, and NamedSharding conversion.
  fault        — elastic mesh choice, crash-restart driver, step timing.
  hlo_analysis — post-optimization HLO text cost model (flops, bytes,
                 collectives) that multiplies while bodies by trip counts.
"""
