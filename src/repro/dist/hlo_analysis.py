"""Trip-count-aware cost model over post-optimization HLO text.

XLA's own `Compiled.cost_analysis()` counts each while body ONCE, but every
layer loop in this codebase is a `jax.lax.scan` — so a 48-layer model would
report 1/48th of its real flops.  This module re-derives per-device flops /
memory traffic / collective bytes from `compiled.as_text()`, multiplying
each while body by its trip count (nested loops multiply through).

Trip counts come from the `known_trip_count` backend_config when XLA
annotated it, else from the loop condition's `compare(iv, constant)`
pattern; loops with dynamic bounds fall back to 1 (a documented
underestimate, not a crash).

Only dot and convolution contribute flops (elementwise traffic is covered
by the byte terms — on the roofline it is bandwidth, not compute).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8,
    "s64": 8, "u64": 8, "f64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")

# collective ops (async "-done" halves are skipped; "-start" carries shape)
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose operands/results are aliases or compile-time data: no traffic
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
             "constant", "iota", "after-all", "partition-id", "replica-id"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _shapes(type_str: str) -> list:
    """All (dtype, dims tuple) array shapes mentioned in a type string."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        out.append((dtype, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_type: str
    rest: str            # operand list + attributes (metadata stripped)

    def attr_comp(self, key: str):
        m = re.search(rf"{key}=%([\w\.\-]+)", self.rest)
        return m.group(1) if m else None


@dataclasses.dataclass
class HloCost:
    """Per-device cost terms (trip-count-weighted)."""
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_bytes_by_kind: dict = dataclasses.field(default_factory=dict)
    collective_count_by_kind: dict = dataclasses.field(default_factory=dict)
    n_whiles: int = 0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        self.collective_bytes += mult * other.collective_bytes
        for k, v in other.collective_bytes_by_kind.items():
            self.collective_bytes_by_kind[k] = \
                self.collective_bytes_by_kind.get(k, 0.0) + mult * v
        for k, v in other.collective_count_by_kind.items():
            self.collective_count_by_kind[k] = \
                self.collective_count_by_kind.get(k, 0) + int(mult * v)
        self.n_whiles += other.n_whiles


def _parse_module(text: str):
    """-> (comps: name -> [Instr], entry_name)."""
    comps, entry, cur = {}, None, None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{"):
                m = _COMP_RE.match(line)
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        # strip metadata/backend_config noise before shape scanning,
        # keeping known_trip_count (consumed via the raw line below)
        s = line.strip()
        m = _INSTR_RE.match(s)
        if m:
            rest = m.group(4)
            cut = rest.find(", metadata=")
            core = rest if cut < 0 else rest[:cut]
            trip = re.search(r'known_trip_count[^}]*?"n":"(\d+)"', rest)
            if trip:
                core += f', known_trip_count_n={trip.group(1)}'
            comps[cur].append(Instr(m.group(1), m.group(3), m.group(2),
                                    core))
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _dot_flops(ins: Instr) -> float:
    operands = _shapes(ins.rest.split(", lhs_contracting_dims")[0])
    out = _shapes(ins.out_type)
    if not operands or not out:
        return 0.0
    lhs = operands[0][1]
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    cdims = [int(d) for d in m.group(1).split(",") if d] if m else []
    contract = _prod([lhs[d] for d in cdims if d < len(lhs)]) if cdims else 1
    return 2.0 * _prod(out[0][1]) * contract


def _conv_flops(ins: Instr) -> float:
    operands = _shapes(ins.rest.split(", window=")[0])
    out = _shapes(ins.out_type)
    if len(operands) < 2 or not out:
        return 0.0
    rhs = operands[1][1]
    out_dims = out[0][1]
    cout = rhs[-1]
    m = re.search(r"dim_labels=\w+_(\w+)->(\w+)", ins.rest)
    if m:
        rhs_labels, out_labels = m.group(1), m.group(2)
        if "o" in rhs_labels and len(rhs_labels) == len(rhs):
            cout = rhs[rhs_labels.index("o")]
        elif "f" in out_labels and len(out_labels) == len(out_dims):
            cout = out_dims[out_labels.index("f")]
    return 2.0 * _prod(out_dims) * _prod(rhs) / max(cout, 1)


def _trip_count(ins: Instr, comps: dict) -> int:
    m = re.search(r"known_trip_count_n=(\d+)", ins.rest)
    if m:
        return int(m.group(1))
    cond = ins.attr_comp("condition")
    if cond and cond in comps:
        # a constant's Instr.rest is what followed "constant(": "8)..."
        consts = {i.name: int(v.group(1)) for i in comps[cond]
                  if i.opcode == "constant"
                  and (v := re.match(r"(-?\d+)\)", i.rest))}
        for i in comps[cond]:
            if i.opcode == "compare":
                d = re.search(r"direction=(\w+)", i.rest)
                ops = re.findall(r"%([\w\.\-]+)", i.rest.split(
                    ", direction=")[0])
                for o in ops:
                    if o in consts:
                        n = consts[o]
                        return n + 1 if d and d.group(1) == "LE" else n
    return 1


def _instr_bytes(ins: Instr) -> float:
    if ins.opcode in _FREE_OPS:
        return 0.0
    stop = ins.rest.find("), ")
    operand_str = ins.rest if stop < 0 else ins.rest[:stop]
    return _shape_bytes(ins.out_type) + _shape_bytes(operand_str)


def _comp_cost(name: str, comps: dict, memo: dict) -> HloCost:
    if name in memo:
        return memo[name]
    memo[name] = HloCost()          # cycle guard (HLO is acyclic anyway)
    cost = HloCost()
    for ins in comps.get(name, ()):
        op = ins.opcode
        if op == "while":
            trip = _trip_count(ins, comps)
            body = ins.attr_comp("body")
            cond = ins.attr_comp("condition")
            if body:
                cost.add(_comp_cost(body, comps, memo), trip)
            if cond:
                cost.add(_comp_cost(cond, comps, memo), trip)
            cost.n_whiles += 1
        elif op == "fusion":
            called = ins.attr_comp("calls")
            if called:
                inner = _comp_cost(called, comps, memo)
                cost.flops += inner.flops          # inner bytes stay
                cost.n_whiles += inner.n_whiles    # in registers/VMEM
            cost.hbm_bytes += _instr_bytes(ins)
        elif op in ("call", "async-start"):
            called = ins.attr_comp("to_apply") or ins.attr_comp("calls")
            if called:
                cost.add(_comp_cost(called, comps, memo))
        elif op == "conditional":
            branches = re.findall(r"%([\w\.\-]+)", ins.rest)
            sub = [_comp_cost(b, comps, memo) for b in branches
                   if b in comps]
            if sub:
                cost.add(max(sub, key=lambda c: c.flops))
        elif op == "dot":
            cost.flops += _dot_flops(ins)
            cost.hbm_bytes += _instr_bytes(ins)
        elif op == "convolution":
            cost.flops += _conv_flops(ins)
            cost.hbm_bytes += _instr_bytes(ins)
        elif any(op == c or op == c + "-start" for c in _COLLECTIVES):
            kind = op[:-6] if op.endswith("-start") else op
            nbytes = _shape_bytes(ins.out_type)
            cost.collective_bytes += nbytes
            cost.collective_bytes_by_kind[kind] = \
                cost.collective_bytes_by_kind.get(kind, 0.0) + nbytes
            cost.collective_count_by_kind[kind] = \
                cost.collective_count_by_kind.get(kind, 0) + 1
            cost.hbm_bytes += _instr_bytes(ins)
        else:
            if op.endswith("-done"):
                continue
            sub = ins.attr_comp("to_apply")     # reduce / scatter / sort
            if sub:
                cost.add(_comp_cost(sub, comps, memo))
            cost.hbm_bytes += _instr_bytes(ins)
    memo[name] = cost
    return cost


def analyze_hlo(hlo_text: str) -> HloCost:
    """Cost of one execution of the ENTRY computation (per device for an
    SPMD-partitioned module, whole program otherwise)."""
    comps, entry = _parse_module(hlo_text)
    if entry is None:
        return HloCost()
    return _comp_cost(entry, comps, {})


def analyze_collectives(hlo_text: str) -> dict:
    """Collective traffic summary of a compiled module's HLO text."""
    cost = analyze_hlo(hlo_text)
    return {
        "total_bytes": cost.collective_bytes,
        "bytes_by_kind": cost.collective_bytes_by_kind,
        "count_by_kind": cost.collective_count_by_kind,
    }
