"""Logical sharding axes and mesh-aware constraint helpers.

Models speak in LOGICAL axes — `BATCH` (data parallel, spanning the pod
and data mesh axes) and `SEQ` (sequence parallel over the model axis) —
and `fspec` translates a logical spec into a `PartitionSpec` valid for
whatever mesh is active, silently dropping axes the mesh does not have.
That is what lets the same model code run on a ("data", "model") single
pod, a ("pod", "data", "model") multi-pod, or a 1-device test process.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# `with mesh:` state only has internal accessors pre-jax-0.5; resolve one
# at import so a dependency bump degrades loudly here, not deep in a jit
try:
    from jax.interpreters.pxla import thread_resources as _thread_resources
except ImportError:                              # moved in newer jax
    from jax._src.mesh import thread_resources as _thread_resources

# logical axes: data parallelism spans pod x data; sequence parallelism
# reuses the model axis (tensor and sequence sharding never coexist on
# the same tensor dimension).
BATCH = ("pod", "data")
SEQ = "model"


def current_mesh() -> Mesh | None:
    """The mesh of the innermost `with mesh:` context, or None."""
    m = _thread_resources.env.physical_mesh
    return None if m.empty else m


def dp_size(mesh) -> int:
    """Total data-parallel ways (product of the BATCH axes present)."""
    if mesh is None:
        return 1
    return int(np.prod([mesh.shape[a] for a in BATCH if a in mesh.axis_names],
                       initial=1))


def fspec(mesh, *axes) -> P:
    """Filter a logical spec down to the axes `mesh` actually has.

    Each entry is None, an axis name, or a tuple of axis names; names not
    in `mesh.axis_names` are dropped.  A tuple that filters down to one
    name collapses to the bare name (PartitionSpec treats them as
    distinct), and to None when nothing survives.
    """
    names = set(mesh.axis_names)
    out = []
    for ax in axes:
        if ax is None:
            out.append(None)
        elif isinstance(ax, (tuple, list)):
            kept = tuple(a for a in ax if a in names)
            out.append(kept[0] if len(kept) == 1 else (kept or None))
        else:
            out.append(ax if ax in names else None)
    return P(*out)


def shard(x, *axes):
    """`with_sharding_constraint(x, fspec(mesh, *axes))` under the active
    mesh; identity when no mesh is active (tests, single device)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, fspec(mesh, *axes)))
