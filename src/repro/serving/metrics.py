"""Serving metrics: the numbers the ROADMAP north-star is judged by.

Per-request latency (p50/p95/p99 from enqueue to completion), queue depth
at submit time, wave occupancy (real rows / bucket rows — padding the
scheduler paid for XLA shape stability), and aggregate images/sec over
the first-submit -> last-completion window.

Everything is recorded through an injectable clock (the engine passes its
own), so scheduler tests can drive a fake clock and pin exact numbers.

The low-level accessors (`latency_percentile`, `occupancy`,
`images_per_s`) return nan on an empty window — pinned behavior callers
rely on for branchless math.  The presentation layer is explicit
instead: `summary()` carries an `empty` flag with None for every
undefined figure, and `report()` says "no completed requests" rather
than formatting nan.

An optional obs.MetricsRegistry mirrors every recording into labeled
process metrics (serve.requests_total, serve.latency_seconds,
serve.queue_depth, serve.wave_occupancy) so one registry snapshot sees
serving next to the pallas/registry counters.
"""
from __future__ import annotations

import numpy as np

from repro import obs


class ServeMetrics:
    def __init__(self, registry: obs.MetricsRegistry | None = None):
        self.latencies_s: list = []          # one per completed request
        self.waves: list = []                # dicts: bucket/n_real/exec_s
        self.queue_depths: list = []         # depth sampled at each submit
        self.t_first_submit: float | None = None
        self.t_last_done: float | None = None
        self.registry = registry
        if registry is not None:
            self._c_requests = registry.counter(
                "serve.requests_total", help="completed requests by bucket")
            self._h_latency = registry.histogram(
                "serve.latency_seconds",
                help="enqueue->completion latency")
            self._g_queue = registry.gauge(
                "serve.queue_depth", help="queue depth at last submit")
            self._g_occupancy = registry.gauge(
                "serve.wave_occupancy", help="real rows / bucket of the "
                "last wave")

    # ------------------------------------------------------------------
    # recording (called by the engine)
    # ------------------------------------------------------------------
    def record_submit(self, t: float, queue_depth: int) -> None:
        if self.t_first_submit is None:
            self.t_first_submit = t
        self.queue_depths.append(queue_depth)
        if self.registry is not None:
            self._g_queue.set(queue_depth)

    def record_wave(self, *, bucket: int, n_real: int, exec_s: float,
                    t_done: float, latencies_s) -> None:
        self.waves.append(
            {"bucket": bucket, "n_real": n_real, "exec_s": exec_s})
        self.latencies_s.extend(latencies_s)
        self.t_last_done = t_done
        if self.registry is not None:
            self._c_requests.inc(n_real, bucket=str(bucket))
            for lat in latencies_s:
                self._h_latency.observe(lat)
            self._g_occupancy.set(n_real / bucket)

    # ------------------------------------------------------------------
    # derived figures
    # ------------------------------------------------------------------
    @property
    def images_done(self) -> int:
        return len(self.latencies_s)

    @property
    def waves_run(self) -> int:
        return len(self.waves)

    def latency_percentile(self, p: float) -> float:
        """p-th percentile request latency in seconds (nan when empty)."""
        if not self.latencies_s:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_s), p))

    def occupancy(self) -> float:
        """Mean fraction of wave rows that carried a real request."""
        if not self.waves:
            return float("nan")
        return float(np.mean([w["n_real"] / w["bucket"] for w in self.waves]))

    def images_per_s(self) -> float:
        """Aggregate throughput over the serving window (wall clock from
        first submit to last completion; falls back to summed exec time
        for a zero-width window, e.g. under a frozen fake clock)."""
        if not self.images_done:
            return float("nan")
        wall = 0.0
        if self.t_first_submit is not None and self.t_last_done is not None:
            wall = self.t_last_done - self.t_first_submit
        if wall <= 0.0:
            wall = sum(w["exec_s"] for w in self.waves)
        return self.images_done / wall if wall > 0 else float("nan")

    def max_queue_depth(self) -> int:
        return max(self.queue_depths, default=0)

    def summary(self) -> dict:
        """JSON-safe summary: undefined figures (empty window, frozen
        clock) are None, never nan, and `empty` says which state the
        window is in — consumers branch on the flag, not on nan
        propagation."""
        def _figure(x: float):
            return None if not np.isfinite(x) else float(x)
        empty = self.images_done == 0
        return {
            "empty": empty,
            "images": self.images_done,
            "waves": self.waves_run,
            "p50_ms": _figure(self.latency_percentile(50) * 1e3),
            "p95_ms": _figure(self.latency_percentile(95) * 1e3),
            "p99_ms": _figure(self.latency_percentile(99) * 1e3),
            "occupancy": _figure(self.occupancy()),
            "images_per_s": _figure(self.images_per_s()),
            "max_queue_depth": self.max_queue_depth(),
        }

    def report(self) -> str:
        s = self.summary()
        if s["empty"]:
            return ("serve: no completed requests "
                    f"(queued submits: {len(self.queue_depths)}, "
                    f"max queue {s['max_queue_depth']})")
        def _ms(x):
            return "n/a" if x is None else f"{x:.1f}"
        ips = ("n/a" if s["images_per_s"] is None
               else f"{s['images_per_s']:.1f}")
        occ = ("n/a" if s["occupancy"] is None
               else f"{s['occupancy']:.2f}")
        return (f"serve: {s['images']} imgs in {s['waves']} waves | "
                f"latency p50 {_ms(s['p50_ms'])} / p95 {_ms(s['p95_ms'])} "
                f"/ p99 {_ms(s['p99_ms'])} ms | occupancy {occ} | "
                f"{ips} img/s | max queue {s['max_queue_depth']}")
