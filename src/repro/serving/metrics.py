"""Serving metrics: the numbers the ROADMAP north-star is judged by.

Per-request latency (p50/p95/p99 from enqueue to completion), queue depth
at submit time, wave occupancy (real rows / bucket rows — padding the
scheduler paid for XLA shape stability), and aggregate images/sec over
the first-submit -> last-completion window.

Everything is recorded through an injectable clock (the engine passes its
own), so scheduler tests can drive a fake clock and pin exact numbers.
"""
from __future__ import annotations

import numpy as np


class ServeMetrics:
    def __init__(self):
        self.latencies_s: list = []          # one per completed request
        self.waves: list = []                # dicts: bucket/n_real/exec_s
        self.queue_depths: list = []         # depth sampled at each submit
        self.t_first_submit: float | None = None
        self.t_last_done: float | None = None

    # ------------------------------------------------------------------
    # recording (called by the engine)
    # ------------------------------------------------------------------
    def record_submit(self, t: float, queue_depth: int) -> None:
        if self.t_first_submit is None:
            self.t_first_submit = t
        self.queue_depths.append(queue_depth)

    def record_wave(self, *, bucket: int, n_real: int, exec_s: float,
                    t_done: float, latencies_s) -> None:
        self.waves.append(
            {"bucket": bucket, "n_real": n_real, "exec_s": exec_s})
        self.latencies_s.extend(latencies_s)
        self.t_last_done = t_done

    # ------------------------------------------------------------------
    # derived figures
    # ------------------------------------------------------------------
    @property
    def images_done(self) -> int:
        return len(self.latencies_s)

    @property
    def waves_run(self) -> int:
        return len(self.waves)

    def latency_percentile(self, p: float) -> float:
        """p-th percentile request latency in seconds (nan when empty)."""
        if not self.latencies_s:
            return float("nan")
        return float(np.percentile(np.asarray(self.latencies_s), p))

    def occupancy(self) -> float:
        """Mean fraction of wave rows that carried a real request."""
        if not self.waves:
            return float("nan")
        return float(np.mean([w["n_real"] / w["bucket"] for w in self.waves]))

    def images_per_s(self) -> float:
        """Aggregate throughput over the serving window (wall clock from
        first submit to last completion; falls back to summed exec time
        for a zero-width window, e.g. under a frozen fake clock)."""
        if not self.images_done:
            return float("nan")
        wall = 0.0
        if self.t_first_submit is not None and self.t_last_done is not None:
            wall = self.t_last_done - self.t_first_submit
        if wall <= 0.0:
            wall = sum(w["exec_s"] for w in self.waves)
        return self.images_done / wall if wall > 0 else float("nan")

    def max_queue_depth(self) -> int:
        return max(self.queue_depths, default=0)

    def summary(self) -> dict:
        return {
            "images": self.images_done,
            "waves": self.waves_run,
            "p50_ms": self.latency_percentile(50) * 1e3,
            "p95_ms": self.latency_percentile(95) * 1e3,
            "p99_ms": self.latency_percentile(99) * 1e3,
            "occupancy": self.occupancy(),
            "images_per_s": self.images_per_s(),
            "max_queue_depth": self.max_queue_depth(),
        }

    def report(self) -> str:
        s = self.summary()
        return ("serve: {images} imgs in {waves} waves | "
                "latency p50 {p50_ms:.1f} / p95 {p95_ms:.1f} / "
                "p99 {p99_ms:.1f} ms | occupancy {occupancy:.2f} | "
                "{images_per_s:.1f} img/s | "
                "max queue {max_queue_depth}").format(**s)
