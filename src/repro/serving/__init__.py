"""Batched int8 CapsNet serving engine (see README.md in this package)."""
from repro.serving.engine import (CapsServeEngine, Completion,  # noqa: F401
                                  DEFAULT_BUCKETS, Request, serve_window)
from repro.serving.metrics import ServeMetrics  # noqa: F401
from repro.serving.registry import (EDGE_TINY, ModelRegistry,  # noqa: F401
                                    ModelSpec, default_specs)
from repro.serving.sharded import (CompiledWave, compile_wave,  # noqa: F401
                                   wave_fn)
