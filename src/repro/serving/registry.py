"""Multi-model registry: model ids -> quantized CapsNets + compiled waves.

Two caches with different lifetimes:

  * model cache — `model(id)` builds a `QuantCapsNet` lazily on first
    request (init -> calibrate -> PTQ, paper Alg. 6/7); trained or
    externally-quantized models are `install()`ed under an id and skip
    the lazy path entirely.
  * executable cache — `executable(id, bucket)` AOT-compiles the wave
    (sharded.compile_wave, under the registry's mesh if any) once per
    (model, backend, bucket) and reuses it for every later wave.  The
    backend is part of the model id's spec, so the tuple key is
    (model_id, bucket).

`quantize_count` / `compile_count` / `exec_hits` make both caches
observable — tests pin reuse instead of trusting it.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.data.synthetic import make_image_dataset
from repro.nn.config import (CAPSNET_CONFIGS, CIFAR10, MNIST, SMALLNORB,
                             CapsNetConfig)
from repro.nn.pipeline import CapsPipeline, QuantCapsNet
from repro.nn.variants import DEFAULT_SOFTMAX, DEFAULT_SQUASH, VariantSet
from repro.serving import sharded


# Deep-edge micro geometry: the paper's target class of model (MCU-sized
# CapsNets) shrunk to where per-request dispatch overhead, not compute,
# dominates a batch-1 loop — the regime the wave scheduler exists for.
# 16x16 gray -> conv8 k5 s2 -> 6x6; pcap k3 s2 -> 2x2x(4x4) -> 16 caps
# -> caps layer 4x16x4x4, 2 routing iterations.
EDGE_TINY = CapsNetConfig("capsnet_edge_tiny", (16, 16, 1), (8,), (5,),
                          (2,), pcap_caps=4, pcap_dim=4, pcap_kernel=3,
                          pcap_stride=2, num_classes=4, caps_dim=4,
                          routings=2)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Everything needed to materialize a servable quantized CapsNet."""
    model_id: str
    config: CapsNetConfig
    backend: str = "jnp"             # "jnp" oracle | "pallas" kernels
    rounding: str = "floor"
    dataset: str = "mnist"           # calibration kind, or "uniform"
    calib_n: int = 32
    seed: int = 0
    softmax_impl: str = DEFAULT_SOFTMAX   # operator-variant references
    squash_impl: str = DEFAULT_SQUASH     # (repro.nn.variants registry)
    per_channel: bool = False        # per-output-channel conv PTQ

    @property
    def variants(self) -> VariantSet:
        """The spec's operator-variant selection (registry-validated)."""
        return VariantSet(softmax=self.softmax_impl,
                          squash=self.squash_impl)

    def images(self, n: int, seed: int) -> np.ndarray:
        """n request/calibration images matching the config's geometry
        ("uniform" serves ad-hoc geometries with no dataset analogue)."""
        if self.dataset == "uniform":
            rng = np.random.default_rng(seed)
            shape = (n,) + tuple(self.config.input_shape)
            return rng.uniform(0, 1, shape).astype(np.float32)
        return make_image_dataset(self.dataset, n, seed=seed)[0]

    def build(self) -> QuantCapsNet:
        pipe = CapsPipeline.from_config(self.config,
                                        variants=self.variants,
                                        per_channel=self.per_channel)
        params = pipe.init(jax.random.key(self.seed))
        calib = jnp.asarray(self.images(self.calib_n, self.seed + 1))
        return pipe.quantize(params, calib, rounding=self.rounding,
                             backend=self.backend)


def default_specs() -> dict:
    """The paper's three configs plus the edge-tiny geometry, x both op
    backends: "mnist@jnp", "cifar10@pallas", ... (ids are dataset@backend)."""
    out = {}
    for ds, cfg, kind in (("mnist", MNIST, "mnist"),
                          ("smallnorb", SMALLNORB, "smallnorb"),
                          ("cifar10", CIFAR10, "cifar10"),
                          ("edge_tiny", EDGE_TINY, "uniform")):
        for be in ("jnp", "pallas"):
            mid = f"{ds}@{be}"
            out[mid] = ModelSpec(mid, cfg, backend=be, dataset=kind)
    return out


class ModelRegistry:
    def __init__(self, specs: dict | None = None, mesh=None,
                 metrics: obs.MetricsRegistry | None = None):
        self.specs = dict(specs) if specs is not None else default_specs()
        self.mesh = mesh
        self._models: dict = {}
        self._execs: dict = {}
        # cache observability lives in a metrics registry (per-model_id
        # labeled series); a fresh ModelRegistry defaults to its own so
        # counts stay per-instance like the old loose ints, and
        # quantize_count / compile_count / exec_hits remain as views
        self.metrics = obs.MetricsRegistry("serving") if metrics is None \
            else metrics
        self._c_quantize = self.metrics.counter(
            "serving.quantize_builds", help="lazy PTQ builds by model")
        self._c_compile = self.metrics.counter(
            "serving.wave_compiles", help="AOT wave compiles by "
            "(model, bucket)")
        self._c_hits = self.metrics.counter(
            "serving.wave_cache_hits", help="wave-executable cache hits")
        self._c_fallback = self.metrics.counter(
            "serving.variant_fallbacks", help="models served through the "
            "pallas->oracle variant fallback")
        # model_id -> variant tag for models whose pallas backend falls
        # back to the jnp oracle on non-default operator variants (the
        # engine-side view of PallasBackend.fallbacks; warned once each)
        self.variant_fallbacks: dict = {}
        self._warned_fallbacks: set = set()

    # compatibility views over the metrics registry (the pre-obs ints)
    @property
    def quantize_count(self) -> int:
        return int(self._c_quantize.total())

    @property
    def compile_count(self) -> int:
        return int(self._c_compile.total())

    @property
    def exec_hits(self) -> int:
        return int(self._c_hits.total())

    # ------------------------------------------------------------------
    # models
    # ------------------------------------------------------------------
    def register(self, spec: ModelSpec) -> None:
        """(Re-)register a spec under its id.  Drops any lazily-built
        model and wave executables cached for that id — a re-register
        with, say, a different operator variant must never keep serving
        the previously built model from the cache."""
        self.specs[spec.model_id] = spec
        self._models.pop(spec.model_id, None)
        self.variant_fallbacks.pop(spec.model_id, None)
        for key in [k for k in self._execs if k[0] == spec.model_id]:
            del self._execs[key]

    def install(self, model_id: str, qnet: QuantCapsNet) -> None:
        """Serve an already-built model (trained weights, custom plan)
        under `model_id`, bypassing the lazy PTQ path.  Drops any wave
        executables compiled for a previous model under this id — they
        hold the old weights as baked-in constants."""
        self._models[model_id] = qnet
        for key in [k for k in self._execs if k[0] == model_id]:
            del self._execs[key]
        self._note_variant_fallback(model_id, qnet)

    def _note_variant_fallback(self, model_id: str,
                               qnet: QuantCapsNet) -> None:
        """Non-default operator variants on the pallas backend run the
        jnp oracle loop (bit-identical, slower).  Make that observable
        per model — a counter entry plus one warning per (model,
        variant) — instead of a silent degradation."""
        vs = qnet.variants
        if qnet.backend != "pallas" or vs.is_default():
            self.variant_fallbacks.pop(model_id, None)   # no longer stale
            return
        self.variant_fallbacks[model_id] = vs.tag
        self._c_fallback.inc(model=model_id, variant=vs.tag)
        if (model_id, vs.tag) not in self._warned_fallbacks:
            self._warned_fallbacks.add((model_id, vs.tag))
            warnings.warn(
                f"model {model_id!r}: pallas backend falls back to the "
                f"jnp oracle for operator variants {vs.tag!r} (no fused "
                "kernel; bit-identical, slower)", RuntimeWarning,
                stacklevel=3)

    def install_artifact(self, capsbin_path, *, model_id: str | None = None,
                         check: bool = True) -> QuantCapsNet:
        """Serve exactly the artifact `export_caps` shipped: load the
        `.capsbin`, rebuild a QuantCapsNet from its ops (repro.edge
        importer — bit-identical to the EdgeVM), and install it under
        `model_id` (default: the program's own name).  The static
        verifier vets the program first unless check=False (a tampered
        artifact is rejected, not served)."""
        from repro.edge import load_qnet
        qnet = load_qnet(capsbin_path, check=check)
        self.install(model_id or qnet.pipeline.cfg.name, qnet)
        return qnet

    def model_ids(self) -> tuple:
        return tuple(sorted(set(self.specs) | set(self._models)))

    def has(self, model_id: str) -> bool:
        return model_id in self._models or model_id in self.specs

    def model(self, model_id: str) -> QuantCapsNet:
        if model_id not in self._models:
            try:
                spec = self.specs[model_id]
            except KeyError:
                raise KeyError(
                    f"unknown model {model_id!r}; have {self.model_ids()}")
            with obs.span("serving.ptq_build", model=model_id):
                self._models[model_id] = spec.build()
            self._c_quantize.inc(model=model_id)
            self._note_variant_fallback(model_id, self._models[model_id])
        return self._models[model_id]

    def input_shape(self, model_id: str) -> tuple:
        """Static geometry only — must not trigger the lazy PTQ build
        (submit() validates shapes with it before any wave runs)."""
        if model_id in self._models:
            return tuple(self._models[model_id].pipeline.cfg.input_shape)
        return tuple(self.specs[model_id].config.input_shape)

    # ------------------------------------------------------------------
    # compiled wave executables
    # ------------------------------------------------------------------
    def export(self, model_id: str, out_dir, *, stem: str | None = None,
               verify_n: int = 4, check: bool = True) -> dict:
        """Dump a served model as an MCU artifact (repro.edge): lower the
        QuantCapsNet to an EdgeProgram, statically check it
        (repro.analysis, unless check=False), write `.capsbin` +
        manifest + CMSIS-NN-style `.c/.h`, and re-verify the reloaded
        binary in the NumPy VM against the live model on `verify_n`
        images."""
        from repro.edge import export_artifacts
        qnet = self.model(model_id)
        images = None
        if verify_n > 0:
            spec = self.specs.get(model_id)
            if spec is not None:
                images = spec.images(verify_n, seed=99)
            else:                    # install()ed model: synthetic probes
                rng = np.random.default_rng(99)
                shape = (verify_n,) + self.input_shape(model_id)
                images = rng.uniform(0, 1, shape).astype(np.float32)
        stem = stem or model_id.replace("@", "_")
        return export_artifacts(qnet, out_dir, stem=stem,
                                verify_images=images, check=check)

    def executable(self, model_id: str, bucket: int) -> sharded.CompiledWave:
        key = (model_id, bucket)
        if key in self._execs:
            self._c_hits.inc(model=model_id, bucket=str(bucket))
            return self._execs[key]
        with obs.span("serving.compile_wave", model=model_id, bucket=bucket):
            exe = sharded.compile_wave(self.model(model_id), bucket,
                                       mesh=self.mesh)
        self._execs[key] = exe
        self._c_compile.inc(model=model_id, bucket=str(bucket))
        return exe


def config_for_dataset(dataset: str) -> CapsNetConfig:
    return CAPSNET_CONFIGS[f"capsnet_{dataset}"]
