"""CapsServeEngine: request queue + bucketed micro-batch scheduler.

The serving problem the paper leaves open: int8 CapsNet inference is
cheap per image, but XLA executables are shape-specialized — serving
arbitrary request counts naively either recompiles per batch size or
runs everything at batch 1.  The engine holds a FIFO request queue and
drains it in WAVES: each wave takes the longest run of queued requests
that share the head request's model, caps it at the largest bucket, and
pads the batch up to the smallest bucket that fits (default 1/4/16/64).
XLA therefore compiles once per (model, backend, bucket) — the registry
caches the executables — and every later wave of any size reuses one of
those few shapes.

Padding is semantically free: conv, squash and routing act per-row, so
pad rows cannot perturb real rows, and the engine's outputs are
bit-identical to calling `QuantCapsNet.forward` directly (pinned by
tests/test_serving.py).

Scheduling is deterministic: same submission order -> same waves, same
buckets, same bits.  The clock is injectable so tests can pin latency
accounting exactly.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from repro import obs
from repro.serving.metrics import ServeMetrics
from repro.serving.registry import ModelRegistry

DEFAULT_BUCKETS = (1, 4, 16, 64)


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    model_id: str
    image: np.ndarray                # [H,W,C] float32
    t_enq: float


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    model_id: str
    v_q: np.ndarray                  # int8 class capsules [J, O]
    lengths: np.ndarray              # float32 [J]
    pred: int
    wave: int                        # index of the wave that served it
    bucket: int                      # padded wave size
    latency_s: float                 # enqueue -> completion


class CapsServeEngine:
    def __init__(self, registry: ModelRegistry,
                 buckets=DEFAULT_BUCKETS,
                 metrics: ServeMetrics | None = None,
                 clock=time.perf_counter,
                 tracer: obs.Tracer | None = None):
        buckets = tuple(sorted({int(b) for b in buckets}))
        if not buckets or buckets[0] < 1:
            raise ValueError(f"need positive bucket sizes, got {buckets}")
        self.registry = registry
        self.buckets = buckets
        self.metrics = ServeMetrics() if metrics is None else metrics
        self.clock = clock
        # explicit tracer wins; otherwise the ambient obs tracer (if
        # installed) picks the spans up — NULL_SPAN no-ops when neither
        self.tracer = tracer
        self._queue: collections.deque = collections.deque()
        self._next_rid = 0
        self._next_wave = 0

    def _span(self, name: str, **args):
        if self.tracer is not None:
            return self.tracer.span(name, **args)
        return obs.span(name, **args)

    # ------------------------------------------------------------------
    # queue side
    # ------------------------------------------------------------------
    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def queue_depth(self) -> int:
        return len(self._queue)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket that fits n rows (n is pre-capped by the
        scheduler, so the largest bucket always fits)."""
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"wave of {n} exceeds max bucket {self.max_bucket}")

    def submit(self, image, model_id: str) -> int:
        if not self.registry.has(model_id):
            raise KeyError(f"unknown model {model_id!r}; have "
                           f"{self.registry.model_ids()}")
        image = np.asarray(image, np.float32)
        shape = self.registry.input_shape(model_id)
        if image.shape != shape:
            raise ValueError(
                f"{model_id} expects image shape {shape}, got {image.shape}")
        rid = self._next_rid
        self._next_rid += 1
        with self._span("serve.enqueue", model=model_id, req_id=rid):
            t = self.clock()
            self._queue.append(Request(rid, model_id, image, t))
            self.metrics.record_submit(t, len(self._queue))
        return rid

    def submit_many(self, images, model_id: str) -> list:
        return [self.submit(img, model_id) for img in images]

    # ------------------------------------------------------------------
    # scheduler side
    # ------------------------------------------------------------------
    def step(self) -> list:
        """Drain ONE wave: the longest same-model run at the queue head,
        capped at the largest bucket.  Returns its completions in
        submission order ([] when idle)."""
        if not self._queue:
            return []
        model_id = self._queue[0].model_id
        with self._span("serve.wave", model=model_id,
                        wave=self._next_wave) as wave_span:
            with self._span("serve.bucket"):
                wave: list = []
                for r in self._queue:            # peek, don't pop yet
                    if (r.model_id != model_id
                            or len(wave) == self.max_bucket):
                        break
                    wave.append(r)
                bucket = self.bucket_for(len(wave))
                x = np.zeros(
                    (bucket,) + self.registry.input_shape(model_id),
                    np.float32)
                for i, r in enumerate(wave):
                    x[i] = r.image
            # the analyzer reconstructs per-request timelines by joining
            # enqueue req_id against this membership (comma-joined: span
            # args are scalar-or-string in the Chrome export)
            req_ids = ",".join(str(r.rid) for r in wave)
            wave_span.note(bucket=bucket, n_real=len(wave),
                           req_ids=req_ids)

            # registry adds serving.compile_wave / serving.ptq_build
            # child spans on a cache miss; a hit is just the lookup
            with self._span("serve.compile", bucket=bucket):
                exe = self.registry.executable(model_id, bucket)
            with self._span("serve.execute", bucket=bucket,
                            n_real=len(wave)):
                t0 = self.clock()
                v_q, lengths, pred = exe(x)
                # host conversion doubles as block_until_ready
                v_q, lengths, pred = (np.asarray(v_q), np.asarray(lengths),
                                      np.asarray(pred))
                t_done = self.clock()
            with self._span("serve.complete", req_ids=req_ids):
                # only now is the wave irrevocably served: a raising
                # executable leaves the queue intact so the requests can
                # be retried
                for _ in wave:
                    self._queue.popleft()

                wave_idx = self._next_wave
                self._next_wave += 1
                done = [Completion(rid=r.rid, model_id=model_id,
                                   v_q=v_q[i], lengths=lengths[i],
                                   pred=int(pred[i]), wave=wave_idx,
                                   bucket=bucket,
                                   latency_s=t_done - r.t_enq)
                        for i, r in enumerate(wave)]
                self.metrics.record_wave(
                    bucket=bucket, n_real=len(wave), exec_s=t_done - t0,
                    t_done=t_done,
                    latencies_s=[c.latency_s for c in done])
        return done

    def drain(self) -> list:
        """Run waves until the queue is empty; completions in submission
        order per model run."""
        out: list = []
        while self._queue:
            out.extend(self.step())
        return out

    def warmup(self, model_id: str, buckets=None) -> None:
        """Pre-build the model and its wave executables so first-request
        latency excludes PTQ + XLA compile."""
        for b in (self.buckets if buckets is None else buckets):
            self.registry.executable(model_id, b)


def serve_window(registry, buckets, images, model_id, *,
                 metrics_registry=None) -> tuple:
    """The measurement harness serve_caps and bench_serving share: serve
    every image through a fresh warmed engine, timing submit -> drained.
    Returns (engine, wall_s).  `metrics_registry` mirrors the window's
    ServeMetrics into an obs.MetricsRegistry (serve_caps --metrics-out
    snapshots it next to the registry/process counters)."""
    metrics = None if metrics_registry is None \
        else ServeMetrics(registry=metrics_registry)
    engine = CapsServeEngine(registry, buckets=buckets, metrics=metrics)
    engine.warmup(model_id)
    t0 = time.perf_counter()
    engine.submit_many(images, model_id)
    done = engine.drain()
    wall = time.perf_counter() - t0
    assert len(done) == len(images)
    return engine, wall
