"""Wave execution: one compiled function per (model, bucket), optionally
split across a device mesh.

`wave_fn` is the single definition of what a serving wave computes —
quantize the float images, run the int8 pipeline (`CapsPipeline
.forward_q7` via `QuantCapsNet.forward`), score class lengths, argmax —
with `dist.api.shard` constraints on the logical BATCH axis at the wave
boundary.  Under a mesh, GSPMD splits the wave's rows across the BATCH
(pod x data) axes; with no mesh (or a 1-device mesh) `api.shard` degrades
to the identity and the very same function runs locally.  Because every
int8 op is exact and rows are independent, the sharded wave is
bit-identical to the unsharded one.

`compile_wave` AOT-compiles (jit -> lower -> compile) so the registry's
executable cache holds real XLA executables keyed on (model, backend,
bucket): a wave never pays a trace, and a cache hit is observable (the
registry counts compiles).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist import api


def wave_fn(qnet):
    """float images [B,H,W,C] -> (v_q int8 [B,J,O], lengths [B,J],
    pred int32 [B]) with logical-BATCH sharding constraints."""
    def fn(x):
        x = api.shard(x, api.BATCH)
        x_q = qnet.quantize_input(x)
        v_q = qnet.forward(x_q)
        v_q = api.shard(v_q, api.BATCH)
        lengths = qnet.class_lengths(v_q)
        pred = jnp.argmax(lengths, axis=-1).astype(jnp.int32)
        return v_q, lengths, pred
    return fn


@dataclasses.dataclass(frozen=True)
class CompiledWave:
    """An AOT-compiled wave executable pinned to one input shape."""
    compiled: object                 # jax.stages.Compiled
    in_sharding: object | None       # None off-mesh
    bucket: int
    input_shape: tuple               # (bucket, H, W, C)

    def __call__(self, x):
        x = jnp.asarray(x, jnp.float32)
        if x.shape != self.input_shape:
            raise ValueError(
                f"wave executable compiled for {self.input_shape}, "
                f"got {x.shape}")
        if self.in_sharding is not None:
            x = jax.device_put(x, self.in_sharding)
        return self.compiled(x)


def compile_wave(qnet, bucket: int, mesh=None) -> CompiledWave:
    """Compile `wave_fn(qnet)` for a fixed bucket, under `mesh` if given.

    The mesh only needs to be active while tracing: `api.shard` resolves
    the logical spec against it and the constraint is baked into the
    executable, so callers invoke the result without a mesh context.
    """
    shape = (bucket,) + tuple(qnet.pipeline.cfg.input_shape)
    spec = jax.ShapeDtypeStruct(shape, jnp.float32)
    if mesh is None:
        compiled = jax.jit(wave_fn(qnet)).lower(spec).compile()
        in_sh = None
    else:
        with mesh:
            compiled = jax.jit(wave_fn(qnet)).lower(spec).compile()
        in_sh = compiled.input_shardings[0][0]
    return CompiledWave(compiled=compiled, in_sharding=in_sh,
                        bucket=bucket, input_shape=shape)
