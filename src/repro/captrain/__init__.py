"""Quantization-aware capsule training subsystem (see README.md here).

CapsTrainer (margin + reconstruction loss, AdamW, ckpt/resume) over the
typed `repro.nn` pipeline; fake-quant QAT on the exact plans PTQ
derives; deterministic tree-reduced data-parallel steps; the Table-2
float-vs-int8 accuracy harness.
"""
from repro.captrain.decoder import ReconDecoder  # noqa: F401
from repro.captrain.evalq import (Table2Row, eval_float,  # noqa: F401
                                  eval_q7, format_rows, table2_rows)
from repro.captrain.losses import (accuracy, accuracy_count,  # noqa: F401
                                   class_lengths, margin_loss,
                                   predictions)
from repro.captrain.steps import (make_train_step,  # noqa: F401
                                  pairwise_reduce, tree_pairwise_mean)
from repro.captrain.trainer import CapsTrainer, TrainConfig  # noqa: F401
