"""Reconstruction-decoder regularizer (paper §3.1 / Sabour et al. §4.1).

The class capsules are masked to the true class and decoded back to the
input image through a small fully-connected stack; the summed-squared
reconstruction error, scaled way down (0.0005 per pixel in the paper's
setup), regularizes the capsule lengths without dominating the margin
loss.  The decoder trains alongside the pipeline but is NOT part of the
deployed model: `CapsTrainer` keeps its params in a separate branch of
the train state, so `CapsPipeline.quantize` / `repro.edge.lower` never
see it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ReconDecoder:
    """FC(h0) relu -> FC(h1) relu -> FC(H*W*C) sigmoid over the masked
    class capsules.  The paper uses (512, 1024) for the 28x28 nets;
    configs here default smaller and scale with the image."""
    num_classes: int
    caps_dim: int
    image_shape: tuple                   # (H, W, C)
    hidden: tuple = (64, 128)

    @property
    def in_dim(self) -> int:
        return self.num_classes * self.caps_dim

    @property
    def out_dim(self) -> int:
        h, w, c = self.image_shape
        return h * w * c

    def init(self, key) -> dict:
        dims = (self.in_dim,) + tuple(self.hidden) + (self.out_dim,)
        params = {}
        for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
            key, sub = jax.random.split(key)
            params[f"fc{i}"] = {
                "w": jax.random.normal(sub, (din, dout), jnp.float32)
                * (2.0 / din) ** 0.5,
                "b": jnp.zeros((dout,), jnp.float32),
            }
        return params

    def apply(self, params, v, labels):
        """v [B,J,O] class capsules + labels [B] -> reconstruction
        [B,H,W,C] in [0,1]."""
        mask = jax.nn.one_hot(labels, self.num_classes, dtype=v.dtype)
        h = (v * mask[:, :, None]).reshape(v.shape[0], -1)
        n_fc = len(self.hidden) + 1
        for i in range(n_fc):
            p = params[f"fc{i}"]
            h = h @ p["w"] + p["b"]
            if i < n_fc - 1:
                h = jax.nn.relu(h)
        return jax.nn.sigmoid(h).reshape((v.shape[0],) + self.image_shape)

    def loss(self, params, v, labels, x):
        """Mean (over batch) summed-squared reconstruction error."""
        recon = self.apply(params, v, labels)
        return jnp.mean(jnp.sum(jnp.square(recon - x), axis=(1, 2, 3)))
