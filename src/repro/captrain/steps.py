"""Data-parallel capsule train steps with bit-reproducible gradients.

The ROADMAP gap this closes: serving waves shard (PR 2) but training
never did.  The obstacle to *pinned* parity is floating-point reduction
order — a plain `jnp.mean` over a sharded batch lets XLA pick how the
per-device partial sums combine, so an 8-way step and a 1-way step agree
only approximately.  Here the reduction order is part of the step's
definition instead:

  1. the batch is reshaped into S fixed microbatches [S, B/S, ...] and
     sharding-constrained on the logical BATCH axis over S
     (`dist.api.shard`), so each device owns whole microbatches;
  2. `vmap(value_and_grad)` computes one loss/grad per microbatch with
     NO cross-microbatch arithmetic (each microbatch's internal
     reductions run identically whether its slice lives on device 0 or
     device k);
  3. the S partials combine through an explicit pairwise halving tree
     (`pairwise_reduce`) — elementwise adds in a fixed association
     order, which XLA executes exactly as written on any mesh;
  4. the reduced gradients are constrained back to replicated before the
     optimizer, so the AdamW update (and its global-norm reduction) runs
     on full, bit-identical arrays on every device.

Net effect: the same jitted step function is bit-identical with no
mesh, a 1-device mesh, and an 8-device mesh (pinned in
tests/test_captrain.py), and `S` — not the device count — defines the
numerics, so *growing the mesh never changes the loss curve*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.captrain.losses import accuracy_count, margin_loss
from repro.dist import api


def pairwise_reduce(a):
    """Sum over a power-of-two leading axis in a fixed halving tree:
    ((a0+a1)+(a2+a3))+... — the association order is explicit in the
    graph, so sharded and unsharded execution add in the same order."""
    n = a.shape[0]
    if n & (n - 1):
        raise ValueError(f"leading axis must be a power of two, got {n}")
    while a.shape[0] > 1:
        a = a[0::2] + a[1::2]
    return a[0]


def tree_pairwise_mean(tree, n: int):
    return jax.tree.map(lambda g: pairwise_reduce(g) / n, tree)


def make_train_step(pipeline, decoder, opt, *, num_classes: int,
                    microbatches: int = 8, recon_weight: float = 0.0,
                    plan=None, rounding: str = "floor"):
    """Build one jitted step: (state, x, y) -> (state, metrics).

    plan=None trains the float pipeline; a PipelinePlan switches the
    forward to `CapsPipeline.forward_fq` (fake-quant QAT) on that plan's
    grids.  The plan is baked into the graph (its shifts are Python
    ints), so a recalibrated plan compiles a fresh step — the trainer
    caches per plan.  Trace the returned function under `with mesh:` to
    bake in the BATCH sharding constraints.
    """
    S = microbatches
    if S < 1 or (S & (S - 1)):
        raise ValueError(f"microbatches must be a power of two, got {S}")

    def micro_loss(tparams, x, y):
        """Loss of ONE microbatch (mean over its rows only)."""
        if plan is None:
            v = pipeline.forward(tparams["caps"], x)
        else:
            v = pipeline.forward_fq(tparams["caps"], x, plan,
                                    rounding=rounding)
        loss = margin_loss(v, y, num_classes)
        if decoder is not None and recon_weight > 0:
            loss = loss + recon_weight * decoder.loss(tparams["dec"], v, y,
                                                      x)
        return loss, accuracy_count(v, y)

    def step(state, x, y):
        if x.shape[0] % S:
            raise ValueError(f"batch {x.shape[0]} not divisible by "
                             f"microbatches={S}")
        xs = api.shard(x.reshape((S, x.shape[0] // S) + x.shape[1:]),
                       api.BATCH)
        ys = api.shard(y.reshape(S, -1), api.BATCH)
        grad_fn = jax.value_and_grad(micro_loss, has_aux=True)
        (losses, counts), grads = jax.vmap(
            grad_fn, in_axes=(None, 0, 0))(state["params"], xs, ys)
        loss = pairwise_reduce(losses) / S
        acc = jnp.sum(counts) / x.shape[0]          # int sum: order-free
        grads = jax.tree.map(
            lambda g: api.shard(pairwise_reduce(g) / S), grads)
        params, opt_state, info = opt.update(grads, state["opt"],
                                             state["params"])
        metrics = {"loss": loss, "accuracy": acc,
                   "grad_norm": info["grad_norm"], "lr": info["lr"],
                   "step": opt_state["step"]}
        return {"params": params, "opt": opt_state}, metrics

    return jax.jit(step)
