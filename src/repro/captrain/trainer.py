"""CapsTrainer: float + fake-quant (QAT) training of `CapsPipeline`s.

One trainer object owns the pieces the legacy example script wired up ad
hoc: the typed pipeline, the reconstruction-decoder regularizer, an
`repro.optim.AdamW`, the deterministic data-parallel step builder
(`captrain.steps`), and checkpoint/resume through `repro.ckpt`.

QAT deliberately adds no second quantization path.  The plan a QAT step
trains against comes from `CapsPipeline.calibrate` + `.plan` — the
EXACT machinery PTQ uses (Alg. 6/7) — re-derived every
`recalib_every` steps from the current weights; the finished model goes
through the ordinary `pipeline.quantize`, so it lowers with
`repro.edge.lower` and serves through `serving.ModelRegistry` with zero
new conversion code.

Determinism contract (pinned in tests/test_captrain.py):
  * batches are pure functions of the optimizer step index
    (`data.synthetic.ImageTask`), so restoring a checkpoint resumes the
    exact sample stream — same step counter => same loss, bit for bit;
  * the QAT plan is part of the checkpoint (a JSON side-car via
    `nn.plans.plan_to_json`), so a resume between recalibrations trains
    against the same grids the original run did;
  * steps are bit-identical across meshes (see steps.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt, obs
from repro.captrain.decoder import ReconDecoder
from repro.captrain.steps import make_train_step
from repro.data.synthetic import ImageTask
from repro.nn.config import CapsNetConfig
from repro.nn.pipeline import CapsPipeline, QuantCapsNet
from repro.nn.plans import PipelinePlan, plan_from_json, plan_to_json
from repro.optim.adam import AdamW


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Everything about HOW to train (the CapsNetConfig says WHAT)."""
    dataset: str = "mnist"          # data.synthetic kind
    batch: int = 64
    microbatches: int = 8           # gradient-tree leaves (power of two)
    lr: float = 1e-3
    weight_decay: float = 0.0
    clip_norm: float = 0.0
    recon_weight: float = 0.0005    # paper's decoder regularizer scale
    decoder_hidden: tuple = (64, 128)
    rounding: str = "floor"         # QAT trains against this rounding
    recalib_every: int = 50         # re-derive the QAT plan every N steps
    calib_n: int = 64
    calib_seed: int = 555_555
    per_channel: bool = False
    softmax_impl: str | None = None  # operator-variant references
    squash_impl: str | None = None   # (None -> registry defaults)
    seed: int = 0
    ckpt_every: int = 0             # 0 = checkpointing off
    ckpt_dir: str | None = None
    ckpt_keep: int = 3


class CapsTrainer:
    def __init__(self, cfg: CapsNetConfig, tcfg: TrainConfig = TrainConfig(),
                 mesh=None, metrics=None, rng=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        # optional EXPLICIT calibration rng (np.random.Generator).  When
        # set, every calibration (QAT recalibrations and the final
        # quantize) subsamples its calib_n images from a 4x pool through
        # THIS generator — so a caller that seeds it owns the complete
        # random state and repeated runs are bit-reproducible (the
        # repro.search contract).  None (default) keeps the legacy fixed
        # calibration set exactly.
        self.rng = rng
        # the run's metrics registry: QAT clipping-rate series land here
        # (pass the serving/run registry to fold them into its snapshot)
        self.metrics = metrics if metrics is not None \
            else obs.MetricsRegistry("captrain")
        self.pipeline = CapsPipeline.from_config(
            cfg, softmax_impl=tcfg.softmax_impl,
            squash_impl=tcfg.squash_impl,
            per_channel=tcfg.per_channel)
        self.decoder = ReconDecoder(
            cfg.num_classes, cfg.caps_dim, tuple(cfg.input_shape),
            hidden=tuple(tcfg.decoder_hidden)) \
            if tcfg.recon_weight > 0 else None
        self.opt = AdamW(lr=tcfg.lr, weight_decay=tcfg.weight_decay,
                         clip_norm=tcfg.clip_norm)
        self.task = ImageTask(tcfg.dataset, seed=tcfg.seed)
        self._steps: dict = {}      # plan key -> jitted step

    # ------------------------------------------------------------------
    # state
    # ------------------------------------------------------------------
    def init_state(self, key=None) -> dict:
        key = jax.random.key(self.tcfg.seed) if key is None else key
        kc, kd = jax.random.split(key)
        params = {"caps": self.pipeline.init(kc),
                  "dec": self.decoder.init(kd) if self.decoder else {}}
        return {"params": params, "opt": self.opt.init(params)}

    @staticmethod
    def step_index(state) -> int:
        return int(state["opt"]["step"])

    # ------------------------------------------------------------------
    # one step
    # ------------------------------------------------------------------
    def _step_fn(self, plan: PipelinePlan | None):
        key = "float" if plan is None else repr(plan)
        if key not in self._steps:
            # recalibration never returns to an old plan: keep the float
            # step plus the CURRENT QAT step, drop superseded executables
            for stale in [k for k in self._steps if k != "float"]:
                del self._steps[stale]
            self._steps[key] = make_train_step(
                self.pipeline, self.decoder, self.opt,
                num_classes=self.cfg.num_classes,
                microbatches=self.tcfg.microbatches,
                recon_weight=self.tcfg.recon_weight,
                plan=plan, rounding=self.tcfg.rounding)
        return self._steps[key]

    def train_step(self, state, x, y, plan: PipelinePlan | None = None):
        """One (sharded, if the trainer has a mesh) optimizer step."""
        fn = self._step_fn(plan)
        x = jnp.asarray(x, jnp.float32)
        y = jnp.asarray(y, jnp.int32)
        if self.mesh is not None:
            with self.mesh:
                return fn(state, x, y)
        return fn(state, x, y)

    # ------------------------------------------------------------------
    # QAT plan derivation — the PTQ machinery, reused verbatim
    # ------------------------------------------------------------------
    def calib_images(self):
        """Fixed calibration set, disjoint from the train stream (its own
        seed) — QAT plans and the final PTQ see the same references.
        With an explicit trainer rng, each call draws calib_n images
        from a 4x pool through it instead (deterministic given the
        caller's seed; order-stable via sorted indices)."""
        tc = self.tcfg
        n = tc.calib_n if self.rng is None else 4 * tc.calib_n
        imgs, _ = ImageTask(tc.dataset, seed=tc.calib_seed).batch(0, n)
        if self.rng is not None:
            idx = self.rng.choice(n, size=tc.calib_n, replace=False)
            imgs = np.asarray(imgs)[np.sort(idx)]
        return jnp.asarray(imgs)

    def derive_plan(self, state) -> PipelinePlan:
        """calibrate + plan on the CURRENT weights — identical to what
        `pipeline.quantize` would derive for them (pinned by tests)."""
        params = state["params"]["caps"]
        stats = self.pipeline.calibrate(params, self.calib_images())
        return self.pipeline.plan(params, stats)

    def qat_clip_rates(self, state, plan: PipelinePlan,
                       batch: int = 16) -> dict:
        """Per-layer STE-clipped fraction of one eager fake-quant pass
        over the calibration set: how often the plan's Qm.n grids clamp
        what training actually produces (repro.obs.numerics probes the
        `fake_quant` faces; high rates mean the format allocation is
        throwing away signal)."""
        from repro.obs import numerics as health
        n = max(1, min(batch, self.tcfg.calib_n))
        probe = health.NumericsProbe()
        with health.probing(probe):
            self.pipeline.forward_fq(state["params"]["caps"],
                                     self.calib_images()[:n], plan,
                                     rounding=self.tcfg.rounding)
        return probe.fq_clip_rates()

    def _record_clip_rates(self, state, plan: PipelinePlan,
                           step: int) -> None:
        """One `qat.clip_rate` gauge point per layer into the run's
        metrics registry — the per-recalibration clipping-rate series."""
        gauge = self.metrics.gauge(
            "qat.clip_rate",
            help="STE-clipped activation fraction per layer at each "
            "QAT plan recalibration")
        for layer, rate in sorted(self.qat_clip_rates(state, plan).items()):
            gauge.set(rate, layer=layer, step=str(step))

    def quantize(self, state, *, rounding: str | None = None,
                 backend: str = "jnp") -> QuantCapsNet:
        """Trained params -> int8 model via the ordinary PTQ entry point
        (same calibration set the QAT plans were derived from)."""
        return self.pipeline.quantize(
            state["params"]["caps"], self.calib_images(),
            rounding=rounding or self.tcfg.rounding, backend=backend)

    # ------------------------------------------------------------------
    # checkpoint / resume
    # ------------------------------------------------------------------
    def save(self, state, plan: PipelinePlan | None = None) -> str:
        if not self.tcfg.ckpt_dir:
            raise ValueError("TrainConfig.ckpt_dir is not set")
        step = self.step_index(state)
        d = pathlib.Path(self.tcfg.ckpt_dir)
        d.mkdir(parents=True, exist_ok=True)
        # the plan side-car lands (atomically) BEFORE ckpt.save publishes
        # LATEST: a crash in between leaves an unreferenced side-car, never
        # a resumable QAT snapshot without its grids
        side = d / f"plan_{step:08d}.json"
        if plan is not None:
            tmp = side.with_suffix(".json.tmp")
            tmp.write_text(json.dumps(plan_to_json(plan), sort_keys=True))
            os.replace(tmp, side)
        elif side.exists():
            side.unlink()
        path = ckpt.save(self.tcfg.ckpt_dir, step, state)
        ckpt.gc_keep_n(self.tcfg.ckpt_dir, keep=self.tcfg.ckpt_keep)
        for orphan in d.glob("plan_*.json"):     # side-cars of GC'd snaps
            if not (d / f"step_{orphan.stem[5:]}.npz").exists():
                orphan.unlink(missing_ok=True)
        return path

    def resume_or_init(self, key=None):
        """(state, plan) from the newest checkpoint, or a fresh init."""
        example = self.init_state(key)
        if not self.tcfg.ckpt_dir:
            return example, None
        step, restored = ckpt.restore_latest(self.tcfg.ckpt_dir, example)
        if step is None:
            return example, None
        side = pathlib.Path(self.tcfg.ckpt_dir) / f"plan_{step:08d}.json"
        plan = plan_from_json(json.loads(side.read_text())) \
            if side.exists() else None
        return restored, plan

    # ------------------------------------------------------------------
    # training loop
    # ------------------------------------------------------------------
    def fit(self, state, num_steps: int, *, qat: bool = False,
            plan: PipelinePlan | None = None, log_every: int = 0,
            log=print):
        """Run `num_steps` optimizer steps from wherever `state` is.

        qat=False trains the float pipeline (plan ignored).  qat=True
        trains fake-quant: the plan is (re)derived from the live weights
        whenever the step counter crosses a `recalib_every` boundary —
        and on entry when no plan was carried in (fresh QAT start or a
        resume whose checkpoint predates QAT).
        Returns (state, plan, history) with history rows
        {"step", "loss", "accuracy", "grad_norm"}.
        """
        tc = self.tcfg
        history = []
        for _ in range(num_steps):
            i = self.step_index(state)           # batch index == step
            if qat and (plan is None or
                        (tc.recalib_every > 0 and i > 0
                         and i % tc.recalib_every == 0)):
                with obs.span("train.recalibrate", step=i):
                    plan = self.derive_plan(state)
                    self._record_clip_rates(state, plan, i)
            x, y = self.task.batch(i, tc.batch)
            with obs.span("train.step", step=i, qat=qat):
                state, metrics = self.train_step(state, x, y,
                                                 plan if qat else None)
            row = {"step": int(metrics["step"]),
                   "loss": float(metrics["loss"]),
                   "accuracy": float(metrics["accuracy"]),
                   "grad_norm": float(metrics["grad_norm"])}
            history.append(row)
            done = self.step_index(state)
            if log_every and (done % log_every == 0 or done == 1):
                log(f"  step {row['step']:5d}: loss={row['loss']:.4f} "
                    f"acc={row['accuracy']:.3f}"
                    + (" [qat]" if qat else ""))
            if tc.ckpt_every and tc.ckpt_dir and done % tc.ckpt_every == 0:
                with obs.span("train.ckpt", step=done):
                    self.save(state, plan if qat else None)
        return state, plan, history
