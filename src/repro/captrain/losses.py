"""Training losses on the typed pipeline's class capsules.

Standalone (not imported from the `repro.core` shims) so the training
subsystem depends only on `repro.nn`: margin loss (Sabour et al. eq. 4,
the paper's training objective) and the accuracy metrics.  The
reconstruction regularizer lives in `captrain.decoder`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def class_lengths(v):
    """||v_j|| per class capsule; eps keeps the sqrt differentiable."""
    return jnp.sqrt(jnp.sum(jnp.square(v), axis=-1) + 1e-9)


def margin_loss(v, labels, num_classes: int,
                m_pos: float = 0.9, m_neg: float = 0.1, lam: float = 0.5):
    L = class_lengths(v)                              # [B, J]
    T = jax.nn.one_hot(labels, num_classes)
    pos = T * jnp.square(jnp.maximum(0.0, m_pos - L))
    neg = lam * (1 - T) * jnp.square(jnp.maximum(0.0, L - m_neg))
    return jnp.mean(jnp.sum(pos + neg, axis=-1))


def predictions(v):
    return jnp.argmax(class_lengths(v), axis=-1)


def accuracy_count(v, labels):
    """Number of correct rows as int32 — an integer, so summing counts
    across microbatches/devices is exact in any association order
    (steps.py relies on this for bit-reproducible metrics)."""
    return jnp.sum((predictions(v) == labels).astype(jnp.int32))


def accuracy(v, labels):
    return accuracy_count(v, labels) / labels.shape[0]
