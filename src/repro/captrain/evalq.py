"""Table-2 accuracy harness: train -> PTQ -> QAT -> float-vs-int8 delta.

The paper's headline claim is that Qm.n power-of-two quantization costs
only 0.07-0.18 % accuracy next to its 75 % memory cut (Table 2).  This
module is the repo's first end-to-end measurement of that delta — and of
what fake-quant training recovers when plain PTQ isn't enough:

    rows = table2_rows(EDGE_TINY, TrainConfig(dataset="edge_tiny"),
                       float_steps=300, qat_steps=60)
    print(format_rows(rows))

For each rounding mode it reports float accuracy, int8 accuracy after
plain PTQ, int8 accuracy after QAT fine-tuning (same seed, same
calibration set), the two deltas, and the Table-2 footprint saving.
`benchmarks/bench_train_caps.py` drives this as a benchmark section;
tests pin `delta_qat <= delta_ptq` for the edge_tiny seed.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.captrain.losses import accuracy_count
from repro.captrain.trainer import CapsTrainer, TrainConfig
from repro.data.synthetic import make_image_dataset
from repro.nn.config import CapsNetConfig
from repro.nn.pipeline import CapsPipeline, QuantCapsNet
from repro.nn.variants import VariantSet


def eval_float(pipeline: CapsPipeline, params, images, labels,
               batch: int = 256) -> float:
    """Float-pipeline top-1 accuracy (exact integer counting)."""
    correct, n = 0, images.shape[0]
    for i in range(0, n, batch):
        v = pipeline.forward(params, jnp.asarray(images[i:i + batch]))
        correct += int(accuracy_count(v, jnp.asarray(labels[i:i + batch])))
    return correct / n


def eval_q7(qnet: QuantCapsNet, images, labels, batch: int = 256) -> float:
    """int8 top-1 accuracy (scored by the plan's class_lengths)."""
    correct, n = 0, images.shape[0]
    for i in range(0, n, batch):
        xq = qnet.quantize_input(jnp.asarray(images[i:i + batch]))
        lengths = np.asarray(qnet.class_lengths(qnet.forward(xq)))
        correct += int((lengths.argmax(-1) ==
                        np.asarray(labels[i:i + batch])).sum())
    return correct / n


@dataclasses.dataclass(frozen=True)
class Table2Row:
    """One (config, variants, rounding) line of the accuracy
    reproduction; `variant` is the operator-variant tag the int8 model
    ran (softmax+squash, see repro.nn.variants).  `est_ms_m7` /
    `est_ms_gap8` are the static MCU latency estimates of the PTQ'd
    program (repro.edge.costmodel, calibrated to the paper's tables) —
    the latency axis the Q-CapsNets-style Pareto search consumes.
    `sat_pct` / `snr_db` are the PTQ model's numeric health from a
    probed pass (repro.obs.numerics): worst per-site saturation rate
    and worst per-layer q7-vs-f32 SNR — the quality axis of the same
    search.  `flash_bytes` / `ram_bytes` are the machine-readable
    footprint of the lowered program (repro.edge.arena memory_report),
    and `source` tags where the row came from — "ptq"/"qat" for the
    Table-2 harness, "search" for Pareto-frontier rows — so bench docs
    can tell baseline and searched rows apart."""
    name: str
    rounding: str
    acc_f32: float
    acc_ptq: float
    acc_qat: float
    saving_pct: float
    variant: str = VariantSet().tag
    est_ms_m7: float = float("nan")
    est_ms_gap8: float = float("nan")
    sat_pct: float = float("nan")
    snr_db: float = float("nan")
    flash_bytes: int = 0
    ram_bytes: int = 0
    source: str = "ptq"

    @property
    def delta_ptq(self) -> float:
        return self.acc_f32 - self.acc_ptq

    @property
    def delta_qat(self) -> float:
        return self.acc_f32 - self.acc_qat


def table2_rows(cfg: CapsNetConfig, tcfg: TrainConfig, *,
                float_steps: int, qat_steps: int,
                roundings=("floor", "nearest"), eval_n: int = 512,
                eval_seed: int = 999_999, mesh=None, log=None,
                variants: VariantSet | None = None) -> list:
    """Train once in float, then branch per rounding mode: PTQ the float
    weights directly, and QAT-fine-tune a copy before quantizing it —
    same seed, same calibration images, so the two deltas are
    comparable.  Returns [Table2Row, ...].

    `variants` selects the int8 operator variants (repro.nn.variants):
    PTQ/QAT plans carry them, QAT's fake-quant faces train against
    them, and the row is tagged with the variant so approximate-op
    deltas (ISLPED'22) read next to the baseline."""
    if variants is not None:
        tcfg = dataclasses.replace(tcfg, softmax_impl=variants.softmax,
                                   squash_impl=variants.squash)
    trainer = CapsTrainer(cfg, tcfg, mesh=mesh)
    caps = trainer.pipeline.layers[-1]
    vtag = VariantSet(softmax=caps.softmax_impl,
                      squash=caps.squash_impl).tag
    state, _ = trainer.resume_or_init()          # ckpt_dir -> resume
    remaining = max(0, float_steps - trainer.step_index(state))
    state, _, _ = trainer.fit(state, remaining,
                              log_every=50 if log else 0,
                              log=log or print)

    images, labels = make_image_dataset(tcfg.dataset, eval_n,
                                        seed=eval_seed)
    acc_f = eval_float(trainer.pipeline, state["params"]["caps"],
                       images, labels)

    rows = []
    for rounding in roundings:
        # QAT branches fork from the float weights; no checkpointing here
        # (they would clobber the float run's snapshots)
        rtc = dataclasses.replace(tcfg, rounding=rounding, ckpt_every=0)
        q_ptq = trainer.quantize(state, rounding=rounding)
        acc_ptq = eval_q7(q_ptq, images, labels)

        qtrainer = CapsTrainer(cfg, rtc, mesh=mesh)
        qstate, _, _ = qtrainer.fit(state, qat_steps, qat=True,
                                    log_every=25 if log else 0,
                                    log=log or print)
        q_qat = qtrainer.quantize(qstate, rounding=rounding)
        acc_qat = eval_q7(q_qat, images, labels)

        fp32 = trainer.pipeline.param_bytes(state["params"]["caps"])
        # the static MCU latency axis: lower the PTQ'd model once and
        # price it on both calibrated profiles (QAT shares the exact
        # geometry, so one estimate covers the row)
        from repro.edge import lower, total_latency_ms
        from repro.edge.arena import memory_report
        program = lower(q_ptq)
        mem = memory_report(program)
        # the numeric-health axis: one probed VM pass of the PTQ model
        # with the trained float weights as the SNR oracle
        from repro.obs.numerics import run_numerics
        health = run_numerics(q_ptq, images[:min(64, eval_n)],
                              params=state["params"]["caps"],
                              program=program)
        rows.append(Table2Row(
            name=cfg.name, rounding=rounding, acc_f32=acc_f,
            acc_ptq=acc_ptq, acc_qat=acc_qat,
            saving_pct=100.0 * (1 - q_ptq.memory_bytes() / fp32),
            variant=vtag,
            est_ms_m7=total_latency_ms(program, "cortex-m7"),
            est_ms_gap8=total_latency_ms(program, "gap8"),
            sat_pct=100.0 * health.worst_saturation_rate(),
            snr_db=health.min_snr_db(),
            flash_bytes=int(mem["flash_bytes"]),
            ram_bytes=int(mem["ram_bytes"])))
    return rows


def format_rows(rows) -> str:
    """The Table-2 analogue printout (paper band: 0.07-0.18 % loss,
    74.99 % memory saving)."""
    head = (f"  {'config':<18}{'variant':<16}{'rounding':<10}{'src':<7}"
            f"{'fp32':>8}"
            f"{'ptq':>8}{'qat':>8}{'d_ptq':>8}{'d_qat':>8}{'saving':>9}"
            f"{'m7_ms':>9}{'gap8_ms':>9}{'sat%':>7}{'snr_db':>8}"
            f"{'flash':>9}{'ram':>8}")
    lines = [head]
    for r in rows:
        lines.append(
            f"  {r.name:<18}{r.variant:<16}{r.rounding:<10}{r.source:<7}"
            f"{r.acc_f32:8.4f}"
            f"{r.acc_ptq:8.4f}{r.acc_qat:8.4f}{r.delta_ptq:8.4f}"
            f"{r.delta_qat:8.4f}{r.saving_pct:8.2f}%"
            f"{r.est_ms_m7:9.2f}{r.est_ms_gap8:9.2f}"
            f"{r.sat_pct:7.2f}{r.snr_db:8.1f}"
            f"{r.flash_bytes:>9,}{r.ram_bytes:>8,}")
    lines.append("  paper Table 2: accuracy loss 0.07-0.18 %, "
                 "saving 74.99 % (latency est: repro.edge.costmodel; "
                 "sat/snr: repro.obs.numerics)")
    return "\n".join(lines)
