"""Post-training quantization framework (paper §4, Algorithms 6 & 7).

Input:  a trained float CapsNet + a reference (calibration) dataset.
Output: int8 weights/bias + the complete shift table for the int8
inference pass (repro.core.capsnet_q7) — output shift and bias shift per
matmul/conv, per-routing-iteration shifts for the capsule layer (Alg. 6:
calc_caps_output and calc_agreement take one scaling factor per iteration).

The activation Qm.n formats are *static*: calibrated once from the maximum
absolute values observed on the reference dataset, exactly as the paper
prescribes for CMSIS-NN/PULP-NN compatibility.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capsnet as C
from repro.core.capsnet_q7 import QCapsNet
from repro.quant import qformat as qf


@dataclasses.dataclass
class CalibStats:
    max_abs: dict           # trace point -> float


def calibrate(params, cfg, calib_images, batch: int = 64) -> CalibStats:
    """Run the float model over the reference dataset recording max|x| at
    every quantization point (Alg. 6 line 8)."""
    fwd = jax.jit(lambda x: C.capsnet_forward(params, x, cfg,
                                              with_trace=True)[1])
    maxes: dict[str, float] = {}
    n = calib_images.shape[0]
    for i in range(0, n, batch):
        trace = fwd(calib_images[i:i + batch])
        for k, t in trace.items():
            m = float(jnp.max(jnp.abs(t)))
            maxes[k] = max(maxes.get(k, 0.0), m)
    return CalibStats(maxes)


def quantize_capsnet(params, cfg, calib_images, *,
                     rounding: str = "floor",
                     per_channel: bool = False) -> QCapsNet:
    """Alg. 6: quantize weights & bias (Alg. 7), derive all shifts."""
    stats = calibrate(params, cfg, calib_images)
    fb = qf.frac_bits
    weights: dict = {}
    shifts: dict = {}

    f_act = fb(stats.max_abs["input"])         # input image format
    shifts["input_frac"] = f_act

    # --- convolutional stack -------------------------------------------
    for i in range(len(cfg.conv_filters)):
        p = params[f"conv{i}"]
        f_w = fb(float(jnp.max(jnp.abs(p["w"]))))
        f_b = fb(float(jnp.max(jnp.abs(p["b"])))) if p["b"].size else f_w
        f_out = fb(stats.max_abs[f"conv{i}_out"])
        weights[f"conv{i}"] = {"w": qf.quantize(p["w"], f_w),
                               "b": qf.quantize(p["b"], f_b)}
        shifts[f"conv{i}_w_frac"] = f_w
        shifts[f"conv{i}_out_frac"] = f_out
        shifts[f"conv{i}_out_shift"] = qf.out_shift(f_act, f_w, f_out)
        shifts[f"conv{i}_bias_shift"] = qf.bias_shift(f_act, f_w, f_b)
        f_act = f_out                           # relu preserves the format

    # --- primary capsule layer ------------------------------------------
    p = params["pcap"]
    f_w = fb(float(jnp.max(jnp.abs(p["w"]))))
    f_b = fb(float(jnp.max(jnp.abs(p["b"]))))
    f_out = fb(stats.max_abs["pcap_out"])
    weights["pcap"] = {"w": qf.quantize(p["w"], f_w),
                       "b": qf.quantize(p["b"], f_b)}
    shifts["pcap_w_frac"] = f_w
    shifts["pcap_out_frac"] = f_out
    shifts["pcap_out_shift"] = qf.out_shift(f_act, f_w, f_out)
    shifts["pcap_bias_shift"] = qf.bias_shift(f_act, f_w, f_b)
    # squash output is Q0.7 by construction (paper §3.2)

    # --- capsule layer ----------------------------------------------------
    W = params["caps"]["W"]
    f_W = fb(float(jnp.max(jnp.abs(W))))
    f_uhat = fb(stats.max_abs["u_hat"])
    weights["caps"] = {"W": qf.quantize(W, f_W)}
    shifts["caps_W_frac"] = f_W
    shifts["uhat_frac"] = f_uhat
    shifts["uhat_shift"] = qf.out_shift(7, f_W, f_uhat)   # u is Q0.7

    # logits format: shared across iterations (b accumulates agreements)
    max_logit = max([stats.max_abs.get(f"logits_iter{r}", 0.0)
                     for r in range(cfg.routings)] + [1e-6])
    f_logit = min(fb(max_logit), 7)
    shifts["logit_frac"] = f_logit

    for r in range(cfg.routings):
        f_s = fb(stats.max_abs[f"s_iter{r}"])
        shifts[f"caps_out_frac_{r}"] = f_s
        # c is Q0.7
        shifts[f"caps_out_shift_{r}"] = qf.out_shift(f_uhat, 7, f_s)
        if r < cfg.routings - 1:
            # agreement <u_hat, v>: u_hat f_uhat, v Q0.7 -> logits format
            shifts[f"agree_shift_{r}"] = qf.out_shift(f_uhat, 7, f_logit)

    return QCapsNet(cfg=cfg, weights=weights, shifts=shifts,
                    rounding=rounding)


def quantize_input(x, frac: int = 7):
    """Images in [0,1] -> Q0.7 int8."""
    return qf.quantize(x, frac)


# ---------------------------------------------------------------------------
# evaluation helpers (Table 2 analogue)
# ---------------------------------------------------------------------------
def footprint_report(params, qmodel: QCapsNet) -> dict:
    fp32 = C.param_bytes_fp32(params)
    int8 = qmodel.memory_bytes()
    return {
        "fp32_kb": fp32 / 1024.0,
        "int8_kb": int8 / 1024.0,
        "saving_pct": 100.0 * (1 - int8 / fp32),
    }


def eval_float(params, cfg, images, labels, batch: int = 256) -> float:
    fwd = jax.jit(lambda x: C.capsnet_forward(params, x, cfg))
    correct = 0
    for i in range(0, images.shape[0], batch):
        v = fwd(images[i:i + batch])
        pred = jnp.argmax(C.class_lengths(v), -1)
        correct += int(jnp.sum(pred == labels[i:i + batch]))
    return correct / images.shape[0]


def eval_q7(qmodel: QCapsNet, images, labels, batch: int = 256) -> float:
    from repro.core.capsnet_q7 import qcapsnet_forward, qclass_lengths
    fwd = jax.jit(lambda x: qcapsnet_forward(qmodel, x))
    correct = 0
    for i in range(0, images.shape[0], batch):
        xq = quantize_input(images[i:i + batch], qmodel.shifts["input_frac"])
        v = fwd(xq)
        pred = jnp.argmax(qclass_lengths(qmodel, v), -1)
        correct += int(jnp.sum(pred == labels[i:i + batch]))
    return correct / images.shape[0]
