"""Post-training quantization (paper §4, Algorithms 6 & 7) — compatibility
shim over the typed repro.nn pipeline.

The per-layer format/shift derivation that used to be hand-rolled here
(one block per layer, ~25 string keys) now belongs to the layers
themselves: `CapsPipeline.quantize` asks each layer for its own
`LayerQuantPlan`.  This module keeps the original entry points and the
legacy `QCapsNet` (string-keyed shift table) output for existing callers;
the keys are produced by `repro.nn.compat.plan_to_shifts` — a pure
renaming of the typed plans.

New code should use the pipeline directly:

    pipe = CapsPipeline.from_config(cfg)
    qnet = pipe.quantize(params, calib_images, rounding="nearest")
    v = qnet.forward(qnet.quantize_input(images))
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import capsnet as C
from repro.core.capsnet_q7 import QCapsNet
from repro.nn import compat
from repro.nn.pipeline import CapsPipeline, QuantCapsNet
from repro.quant import qformat as qf


@dataclasses.dataclass
class CalibStats:
    max_abs: dict           # trace point -> float


def calibrate(params, cfg, calib_images, batch: int = 64) -> CalibStats:
    """Run the float model over the reference dataset recording max|x| at
    every quantization point (Alg. 6 line 8).  Legacy trace-key names."""
    stats = C.pipeline(cfg).calibrate(params, calib_images, batch=batch)
    return CalibStats({compat.tap_to_trace_key(k): v
                       for k, v in stats.max_abs.items()})


def quantize_capsnet(params, cfg, calib_images, *,
                     rounding: str = "floor",
                     per_channel: bool = False) -> QCapsNet:
    """Alg. 6: quantize weights & bias (Alg. 7), derive all shifts.

    Returns the legacy string-keyed QCapsNet; `quantize_pipeline` returns
    the typed equivalent."""
    if per_channel:
        raise ValueError(
            "per-channel shift tables are tuples and have no legacy "
            "string-keyed representation; use quantize_pipeline(..., "
            "per_channel=True) for the typed ConvPlan.w_frac_per_channel "
            "path")
    qnet = quantize_pipeline(params, cfg, calib_images, rounding=rounding)
    # the legacy container's softmax reference comes off the typed plan
    # (registry-validated), never from a literal repeated here
    return QCapsNet(cfg=cfg, weights=qnet.qweights,
                    shifts=compat.plan_to_shifts(qnet.plan),
                    rounding=rounding,
                    softmax_impl=qnet.plan.variants.softmax)


def quantize_pipeline(params, cfg, calib_images, *,
                      rounding: str = "floor",
                      backend: str = "jnp",
                      per_channel: bool = False) -> QuantCapsNet:
    """The typed path: per-layer plans, no string keys.

    per_channel=True re-derives the pipeline with per-output-channel conv
    weight formats (ConvPlan.w_frac_per_channel); params initialized for
    the per-tensor pipeline are layout-compatible."""
    pipe = CapsPipeline.from_config(cfg, per_channel=True) if per_channel \
        else C.pipeline(cfg)
    return pipe.quantize(params, calib_images,
                         rounding=rounding, backend=backend)


def quantize_input(x, frac: int = 7):
    """Images in [0,1] -> Q0.7 int8."""
    return qf.quantize(x, frac)


# ---------------------------------------------------------------------------
# evaluation helpers (Table 2 analogue)
# ---------------------------------------------------------------------------
def footprint_report(params, qmodel) -> dict:
    fp32 = C.param_bytes_fp32(params)
    int8 = qmodel.memory_bytes()
    return {
        "fp32_kb": fp32 / 1024.0,
        "int8_kb": int8 / 1024.0,
        "saving_pct": 100.0 * (1 - int8 / fp32),
    }


def eval_float(params, cfg, images, labels, batch: int = 256) -> float:
    fwd = jax.jit(lambda x: C.capsnet_forward(params, x, cfg))
    correct = 0
    for i in range(0, images.shape[0], batch):
        v = fwd(images[i:i + batch])
        pred = jnp.argmax(C.class_lengths(v), -1)
        correct += int(jnp.sum(pred == labels[i:i + batch]))
    return correct / images.shape[0]


def eval_q7(qmodel: QCapsNet, images, labels, batch: int = 256) -> float:
    from repro.core.capsnet_q7 import qcapsnet_forward, qclass_lengths
    fwd = jax.jit(lambda x: qcapsnet_forward(qmodel, x))
    correct = 0
    for i in range(0, images.shape[0], batch):
        xq = quantize_input(images[i:i + batch], qmodel.shifts["input_frac"])
        v = fwd(xq)
        pred = jnp.argmax(qclass_lengths(qmodel, v), -1)
        correct += int(jnp.sum(pred == labels[i:i + batch]))
    return correct / images.shape[0]
