"""Exact int8 operation semantics (the pure-jnp oracle layer).

These functions define the integer arithmetic the Pallas kernels must
reproduce bit-exactly (kernels/ref.py re-exports them): int8 operands,
int32 accumulation, power-of-two rescale (arithmetic shift), saturation to
[-128, 127] — the TPU analogue of the paper's CMSIS-NN / PULP-NN kernels.

`rounding="floor"` matches the paper/CMSIS `__SSAT(sum >> shift, 8)`
truncation; `rounding="nearest"` adds the half-LSB before shifting
(beyond-paper accuracy option, still shift-only).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.obs import numerics as _health

INT8_MIN, INT8_MAX = -128, 127


def rshift_sat8(acc, shift: int, rounding: str = "floor"):
    """int32 accumulator -> int8 via arithmetic shift + saturate."""
    if _health._PROBE is not None:     # observer only; skips jit tracers
        _health.observe_requant(acc, shift, rounding)
    acc = acc.astype(jnp.int32)
    if shift > 0:
        if rounding == "nearest":
            acc = acc + (1 << (shift - 1))
        acc = jnp.right_shift(acc, shift)
    elif shift < 0:
        acc = jnp.left_shift(acc, -shift)
    return jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8)


def sat8(x):
    return jnp.clip(x.astype(jnp.int32), INT8_MIN, INT8_MAX).astype(jnp.int8)


def matmul_q7(a, b, shift: int, rounding: str = "floor"):
    """[..., M, K] int8 x [..., K, N] int8 -> int8, int32 accumulation.

    The `mat_mult_q7` family: the transposed-B / SIMD variants of the paper
    are memory layouts of the same arithmetic; on TPU the MXU consumes
    int8 pairs natively (preferred_element_type=int32)."""
    acc = jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (b.ndim - 2,)), ((), ())),
        preferred_element_type=jnp.int32)
    return rshift_sat8(acc, shift, rounding)


def matmul_q7_acc(a, b):
    """Raw int32 accumulator (for fused pipelines)."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (b.ndim - 2,)), ((), ())),
        preferred_element_type=jnp.int32)


def add_q7(a, b, shift_a: int = 0, shift_b: int = 0):
    """Saturating int8 addition with per-operand alignment shifts."""
    aa = jnp.left_shift(a.astype(jnp.int32), max(-shift_a, 0)) \
        if shift_a <= 0 else jnp.right_shift(a.astype(jnp.int32), shift_a)
    bb = jnp.left_shift(b.astype(jnp.int32), max(-shift_b, 0)) \
        if shift_b <= 0 else jnp.right_shift(b.astype(jnp.int32), shift_b)
    return sat8(aa + bb)


def conv2d_q7(x, w, bias, out_shift: int, bias_shift: int,
              stride: int = 1, padding: str = "VALID",
              rounding: str = "floor"):
    """NHWC int8 conv, int32 accumulation, shifted bias, shift+sat output.

    x [B,H,W,Cin] int8; w [KH,KW,Cin,Cout] int8; bias [Cout] int8.
    bias is left-shifted by `bias_shift` into the accumulator's Qm.n
    (paper Alg. 6 line 10)."""
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)
    if bias is not None:
        b = bias.astype(jnp.int32)
        b = jnp.left_shift(b, bias_shift) if bias_shift >= 0 \
            else jnp.right_shift(b, -bias_shift)
        acc = acc + b
    return rshift_sat8(acc, out_shift, rounding)


def rshift_sat8_vec(acc, shifts, rounding: str = "floor"):
    """rshift_sat8 with a per-lane shift array broadcast against the
    accumulator's trailing axes (the per-channel requantization step).

    Semantics per lane match the scalar path exactly: positive shifts
    arithmetic-right-shift (nearest adds the half-LSB first), negative
    shifts left-shift, then saturate to int8."""
    if _health._PROBE is not None:     # observer only; skips jit tracers
        _health.observe_requant(acc, shifts, rounding)
    acc = acc.astype(jnp.int32)
    shifts = jnp.asarray(shifts, jnp.int32)
    if rounding == "nearest":
        half = jnp.left_shift(jnp.int32(1), jnp.maximum(shifts - 1, 0))
        acc = acc + jnp.where(shifts > 0, half, 0)
    acc = jnp.right_shift(acc, jnp.maximum(shifts, 0))
    acc = jnp.left_shift(acc, jnp.maximum(-shifts, 0))
    return jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8)


def conv2d_q7_per_channel(x, w, bias, out_shifts, bias_shifts,
                          stride: int = 1, padding: str = "VALID",
                          rounding: str = "floor"):
    """conv2d_q7 with per-output-channel weight formats: the accumulator
    for channel c carries in_frac + w_frac[c] fractional bits, so both
    the bias alignment and the output requantization are per-channel
    shift tables (still power-of-two — MCU cost is one extra q7 table).
    """
    acc = jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)
    if bias is not None:
        b = bias.astype(jnp.int32)
        bs = jnp.asarray(bias_shifts, jnp.int32)
        b = jnp.left_shift(b, jnp.maximum(bs, 0))
        b = jnp.right_shift(b, jnp.maximum(-bs, 0))
        acc = acc + b
    return rshift_sat8_vec(acc, out_shifts, rounding)


def relu_q7(x):
    return jnp.maximum(x, 0).astype(jnp.int8)


# ---------------------------------------------------------------------------
# integer square root (Newton-Raphson, paper Alg. 4) and squash (Eq. 8)
# ---------------------------------------------------------------------------
def isqrt_newton(n):
    """Integer sqrt of int32 n (elementwise, vectorized Newton-Raphson).

    Follows Alg. 4: x0 = n/2, x_{k+1} = (x_k + n/x_k)/2, stop when the next
    iterate stops decreasing.  A fixed 32-iteration loop (Newton from n/2
    halves the exponent gap per step; 32 covers any int32) with the
    monotonicity guard makes it bit-exact with the sequential algorithm."""
    n = n.astype(jnp.int32)
    x0 = jnp.maximum(n // 2, 1)

    def body(_, x):
        nxt = (x + n // jnp.maximum(x, 1)) // 2
        return jnp.where(nxt < x, nxt, x)

    x = jax.lax.fori_loop(0, 32, body, x0)
    # n in {0,1}: x0 heuristics
    x = jnp.where(n <= 1, n, x)
    return x


SQUASH_GUARD_BITS = 10


def squash_q7(s, in_frac: int, out_frac: int = 7):
    """Integer squash (paper Eq. 8) over the last axis.

    s int8 [..., D] with `in_frac` (i) fractional bits; returns int8 with
    `out_frac` (o) fractional bits.  Derivation: with Q = sum(s^2) (2i frac
    bits) and S = isqrt(Q) (i frac bits),
        v_f  = (||s|| / (1 + ||s||^2)) * s_f
        v_q  = v_f * 2^o = [S * 2^o / (2^{2i} + Q)] * s_q
    The bracket is Eq. 8's  (||s|| << (o-i)) / ((1<<i) + (Q>>i))  up to the
    integer-division order; we carry SQUASH_GUARD_BITS (P) extra bits
    through the division so the factor keeps ~3 decimal digits:
        ratio = (S << (o - i + P)) // ((2^{2i} + Q) >> i)
        v     = sat8((ratio * s) >> P)
    Values: S <= 127*sqrt(D) < 2^9 for D <= 16, so int32 never overflows.
    """
    s32 = s.astype(jnp.int32)
    Q = jnp.sum(s32 * s32, axis=-1, keepdims=True)
    S = isqrt_newton(Q)
    P = SQUASH_GUARD_BITS
    shift = out_frac - in_frac + P
    num = jnp.left_shift(S, max(shift, 0)) if shift >= 0 \
        else jnp.right_shift(S, -shift)
    den = (1 << in_frac) + jnp.right_shift(Q, in_frac)
    ratio = num // jnp.maximum(den, 1)
    v = jnp.right_shift(ratio * s32, P)
    return jnp.clip(v, INT8_MIN, INT8_MAX).astype(jnp.int8)


def softmax_q7(x, in_frac: int):
    """Shift-based integer softmax over the last axis -> Q0.7 output.

    Faithful to the arm_softmax_q7 approach: probabilities are powers of two
    of the integer part of (x - max), normalized to 128 = 1.0, saturated to
    127.  Coarse but branch/LUT-free."""
    x32 = x.astype(jnp.int32)
    m = jnp.max(x32, axis=-1, keepdims=True)
    # integer exponent of 2^(x-m) in value units
    e = jnp.right_shift(x32 - m, in_frac)          # <= 0
    e = jnp.maximum(e, -20)
    p = jnp.left_shift(jnp.ones_like(e), 20 + e)   # 2^(20+e)
    tot = jnp.sum(p, axis=-1, keepdims=True)   # <= n_cls * 2^20, fits int32
    c = jnp.left_shift(p, 7) // jnp.maximum(tot, 1)
    return jnp.clip(c, 0, INT8_MAX).astype(jnp.int8)


def softmax_q7_precise(x, in_frac: int):
    """Beyond-paper variant: dequantize -> fp32 softmax -> requant Q0.7.
    (What you would do on a TPU where the VPU has fast exp; kept for the
    accuracy/throughput trade-off study.)"""
    xf = x.astype(jnp.float32) * (2.0 ** -in_frac)
    p = jax.nn.softmax(xf, axis=-1)
    return jnp.clip(jnp.round(p * 128.0), 0, INT8_MAX).astype(jnp.int8)


def ceil_log2_int(tot):
    """ceil(log2(tot)) for positive int32 arrays: the bit length of
    tot - 1, counted with shifts so the semantics are integer-exact (and
    identical to the NumPy mirror in repro.nn.variants)."""
    t1 = tot.astype(jnp.int32) - 1
    k = jnp.zeros_like(t1)
    for j in range(31):
        k = k + (jnp.right_shift(t1, j) > 0)
    return k


def softmax_q7_approx(x, in_frac: int):
    """ISLPED'22 approximate softmax: shift-based exp with power-of-two
    normalization -> Q0.7 output.

    Probabilities are the same powers of two of floor(x - max) as
    `softmax_q7`, but the normalizer sum is rounded UP to a power of two
    (2^ceil(log2(sum))), so the per-element integer division becomes one
    arithmetic right shift — the cheapest softmax an MCU can run."""
    x32 = x.astype(jnp.int32)
    m = jnp.max(x32, axis=-1, keepdims=True)
    e = jnp.maximum(jnp.right_shift(x32 - m, in_frac), -20)
    p = jnp.left_shift(jnp.ones_like(e), 20 + e)
    tot = jnp.sum(p, axis=-1, keepdims=True)
    k = ceil_log2_int(tot)          # >= 20: the max element contributes 2^20
    c = jnp.right_shift(p, k - 7)
    return jnp.clip(c, 0, INT8_MAX).astype(jnp.int8)


def squash_q7_approx(s, in_frac: int, out_frac: int = 7):
    """ISLPED'22 approximate squash: Eq. 8 with the L2 norm replaced by
    the L-inf norm M = max|s_i| — the 32-iteration Newton-Raphson
    integer sqrt (Alg. 4, the routing loop's hot spot) disappears:

        ratio = (M << (o - i + P)) // ((1 << i) + (M*M >> i))
        v     = sat8((ratio * s) >> P)

    M <= ||s||_2 <= sqrt(D) * M, so capsule probabilities keep their
    ordering; the factor error is bounded by the capsule dimension."""
    s32 = s.astype(jnp.int32)
    M = jnp.max(jnp.abs(s32), axis=-1, keepdims=True)
    Q = M * M
    P = SQUASH_GUARD_BITS
    shift = out_frac - in_frac + P
    num = jnp.left_shift(M, max(shift, 0)) if shift >= 0 \
        else jnp.right_shift(M, -shift)
    den = (1 << in_frac) + jnp.right_shift(Q, in_frac)
    ratio = num // jnp.maximum(den, 1)
    v = jnp.right_shift(ratio * s32, P)
    return jnp.clip(v, INT8_MIN, INT8_MAX).astype(jnp.int8)
