"""W8A8 quantization of transformer parameters — the paper's Qm.n
framework applied to LM serving (beyond-paper, DESIGN §7).

Weights: int8 with per-output-channel power-of-two exponents (Alg. 7 run
per channel — granularity the paper marks as future work; still shift-only
so the MCU-compatible contract holds).  Activations: dynamic per-tensor
power-of-two quantization at matmul entry (on TPU the dequant multiply is
a cheap VPU op; the paper's static calibration remains available through
repro.quant.ptq for the CapsNet path — deviation noted in DESIGN.md).

A quantized weight leaf is a dict {"q": int8 [..., out], "n": int32 [out]}.
`layers.dense` and the MoE einsums dispatch on that structure, so the same
model code runs both float and W8A8 (serve.py --quant w8a8, dryrun --quant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

QUANT_LEAF_NAMES = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
    "up_proj", "down_proj", "in_proj", "out_proj", "wx",
    "ffn_up", "ffn_down",
}
HEAD_LEAF_NAMES = {"w"}        # lm_head / frontend dense


def _leaf_name(path) -> str:
    k = path[-1]
    return str(getattr(k, "key", getattr(k, "idx", k)))


def _quantize_weight(w):
    """[..., K, N] -> {"q" int8, "n" int32 [..., N]}: per-output-channel
    power-of-two exponents, reduced over the contraction dim (axis -2)
    only, so stacked-cycle / expert leading dims are preserved (the layer
    scan slices q and n together)."""
    wf = w.astype(jnp.float32)
    max_abs = jnp.max(jnp.abs(wf), axis=-2)
    n = jnp.clip(jnp.floor(jnp.log2(127.0 / jnp.maximum(max_abs, 1e-30))),
                 -24, 24).astype(jnp.int32)
    q = jnp.clip(jnp.round(wf * jnp.exp2(n.astype(jnp.float32))[..., None, :]),
                 -128, 127).astype(jnp.int8)
    return {"q": q, "n": n}


def quantize_lm_params(params, quantize_head: bool = True):
    """Transform a float param tree into the W8A8 tree (norms, embeddings,
    biases and small vectors stay float)."""
    def visit(path, leaf):
        name = _leaf_name(path)
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if name in QUANT_LEAF_NAMES and leaf.ndim >= 2:
            return _quantize_weight(leaf)
        if quantize_head and name == "w" and "lm_head" in names:
            return _quantize_weight(leaf)
        return leaf
    return jax.tree_util.tree_map_with_path(visit, params)


def is_qweight(w) -> bool:
    return isinstance(w, dict) and set(w) >= {"q", "n"}


def quantize_activation(x):
    """Dynamic per-tensor pow2 activation quantization -> (int8, exponent)."""
    xf = x.astype(jnp.float32)
    e = jnp.clip(jnp.floor(jnp.log2(127.0 /
                                    jnp.maximum(jnp.max(jnp.abs(xf)),
                                                1e-30))), -24, 24)
    q = jnp.clip(jnp.round(xf * jnp.exp2(e)), -128, 127).astype(jnp.int8)
    return q, e


def q_dense(x, w: dict, out_dtype=jnp.bfloat16):
    """W8A8 dense: x [..., K] float, w {"q" [K,N], "n" [N]}."""
    xq, xe = quantize_activation(x)
    acc = jax.lax.dot_general(
        xq, w["q"], (((x.ndim - 1,), (w["q"].ndim - 2,)), ((), ())),
        preferred_element_type=jnp.int32)
    scale = jnp.exp2(-(xe + w["n"].astype(jnp.float32)))
    return (acc.astype(jnp.float32) * scale).astype(out_dtype)


def q_einsum(spec: str, x, w: dict, out_dtype=jnp.bfloat16):
    """Quantized einsum for the MoE expert matmuls ('gecd,edf->gecf',
    'gecf,efd->gecd'): w["q"] [E,K,N], w["n"] [E,N] -> scale [1,E,1,N]."""
    xq, xe = quantize_activation(x)
    acc = jnp.einsum(spec, xq.astype(jnp.int8), w["q"],
                     preferred_element_type=jnp.int32)
    n = w["n"].astype(jnp.float32)[None, :, None, :]
    scale = jnp.exp2(-(xe + n))
    return (acc.astype(jnp.float32) * scale).astype(out_dtype)


def quantized_bytes(qparams) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(qparams):
        total += leaf.size * leaf.dtype.itemsize
    return int(total)
