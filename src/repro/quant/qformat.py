"""Qm.n power-of-two quantization format calculus (paper §4, Alg. 7).

Symmetric, uniform, static, power-of-two scaling: a float A is stored as
round(A * 2^n) in int8, where n is the number of (possibly *virtual*)
fractional bits.  "Virtual" (paper's term): when max|x| < 1/127 the
framework keeps increasing n past 7 — physically the value still fits in
8 bits, but the format exponent exceeds the Q0.7 barrier.

Because scaling is a power of two, every rescale in the int8 inference pass
is a bit shift:
    out_shift  = f_ia + f_ib - f_o      (right shift of the int32 accum)
    bias_shift = f_ia + f_ib - f_b      (left shift aligning the bias)
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import numerics as _health

INT8_MIN, INT8_MAX = -128, 127
MAX_FRAC_BITS = 24


def frac_bits(max_abs: float) -> int:
    """Number of fractional bits n for the Qm.n format covering
    [-max_abs, max_abs] (Alg. 7: maximal n with round(max_abs*2^n) <= 127,
    capped at MAX_FRAC_BITS for degenerate ranges)."""
    max_abs = float(max_abs)
    if max_abs <= 0 or math.isnan(max_abs):
        return MAX_FRAC_BITS
    n = int(math.floor(math.log2(INT8_MAX / max_abs)))
    # floating point edge: ensure round(max_abs * 2^n) <= 127 < round(*2^(n+1))
    while round(max_abs * 2.0 ** (n + 1)) <= INT8_MAX and n < MAX_FRAC_BITS:
        n += 1
    while round(max_abs * 2.0 ** n) > INT8_MAX and n > -MAX_FRAC_BITS:
        n -= 1
    return n


def quantize(x, n: int):
    """float -> int8 in Qm.n (round-to-nearest, clip to [-128, 127])."""
    q = jnp.round(jnp.asarray(x, jnp.float32) * (2.0 ** n))
    return jnp.clip(q, INT8_MIN, INT8_MAX).astype(jnp.int8)


def dequantize(q, n: int):
    return jnp.asarray(q, jnp.float32) * (2.0 ** -n)


def quantize_with_fracs(x, ns, axis: int):
    """float -> int8 with a per-slice fractional-bit table along `axis`
    (the quantization step of the per-channel scheme, for fracs that
    were already derived — e.g. carried by a ConvPlan)."""
    x = np.asarray(x, np.float32)
    ns = np.asarray(ns, np.int32)
    moved = np.moveaxis(x, axis, 0)
    scale = (2.0 ** ns).reshape((-1,) + (1,) * (moved.ndim - 1))
    q = np.clip(np.round(moved * scale), INT8_MIN, INT8_MAX).astype(np.int8)
    return jnp.asarray(np.moveaxis(q, 0, axis))


def quantize_per_channel(x, axis: int):
    """Beyond-paper: per-output-channel power-of-two scales (still
    shift-only in hardware).  Returns (int8 array, n per channel [int32])."""
    moved = np.moveaxis(np.asarray(x, np.float32), axis, 0)
    ns = np.array([frac_bits(np.abs(c).max()) for c in moved], np.int32)
    return quantize_with_fracs(x, ns, axis), jnp.asarray(ns)


@dataclasses.dataclass(frozen=True)
class QTensor:
    """An int8 tensor + its Qm.n fractional-bit count."""
    q: jax.Array          # int8
    n: int                # fractional bits

    @property
    def float(self):
        return dequantize(self.q, self.n)

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.q.shape))


def qtensor(x, n: int | None = None) -> QTensor:
    if n is None:
        n = frac_bits(float(jnp.max(jnp.abs(x))))
    return QTensor(quantize(x, n), n)


def out_shift(f_ia: int, f_ib: int, f_o: int) -> int:
    return f_ia + f_ib - f_o


def bias_shift(f_ia: int, f_ib: int, f_b: int) -> int:
    return f_ia + f_ib - f_b


# ---------------------------------------------------------------------------
# fake quantization (QAT): the same Qm.n clamp, straight-through gradient
# ---------------------------------------------------------------------------
def _ste(x, q):
    """Straight-through estimator: forward `q`, gradient of identity."""
    return x + jax.lax.stop_gradient(q - x)


def fake_quant(x, n: int, rounding: str = "nearest"):
    """quantize(x, n) -> dequantize, differentiably (STE).

    Forward lands exactly on the Qm.n grid `quantize` would produce —
    the same round/floor and the same [-128, 127] saturation.  "nearest"
    matches the weight/input quantizer (`quantize`); "floor" matches the
    truncating accumulator shift (`int8_ops.rshift_sat8`), so fake-quant
    activations see the same truncation bias the int8 graph has.
    """
    x = jnp.asarray(x, jnp.float32)
    scaled = x * (2.0 ** n)
    r = jnp.round(scaled) if rounding == "nearest" else jnp.floor(scaled)
    if _health._PROBE is not None:     # count STE-clipped grid values
        _health.observe_fq(r)
    q = jnp.clip(r, INT8_MIN, INT8_MAX) * (2.0 ** -n)
    return _ste(x, q)


def fake_quant_with_fracs(x, ns, axis: int, rounding: str = "nearest"):
    """Per-slice fake quantization along `axis` (the QAT face of
    `quantize_with_fracs`; `ns` comes from a plan, e.g.
    `ConvPlan.w_frac_per_channel`)."""
    x = jnp.asarray(x, jnp.float32)
    shape = [1] * x.ndim
    shape[axis] = -1
    scale = jnp.asarray(2.0, jnp.float32) ** \
        jnp.asarray(ns, jnp.float32).reshape(shape)
    scaled = x * scale
    r = jnp.round(scaled) if rounding == "nearest" else jnp.floor(scaled)
    if _health._PROBE is not None:     # count STE-clipped grid values
        _health.observe_fq(r)
    q = jnp.clip(r, INT8_MIN, INT8_MAX) / scale
    return _ste(x, q)
