"""Hierarchical span tracer with an injectable clock.

One `Tracer` records a forest of `Span`s: a span is opened as a context
manager, nests under whichever span is currently open on the tracer's
stack, and captures enter/exit timestamps from the tracer's clock (a
plain callable, so tests drive a fake clock and pin exact trees).

Instrumented code never talks to a `Tracer` directly — it calls the
module-level `span(name, **args)`, which resolves the AMBIENT tracer
(installed with `set_tracer` / scoped with `tracing`).  When no tracer
is installed, `span()` returns one shared no-op object without reading
the clock or allocating — tracing is free when it is off, which is what
lets the serving/VM hot paths stay instrumented permanently (traced and
untraced runs are pinned bit-identical in tests/test_obs.py).

Export is the Chrome trace-event JSON format ("complete" `ph:"X"`
events, microsecond timestamps), loadable in chrome://tracing or
Perfetto:

    tracer = Tracer()
    with tracing(tracer):
        serve_window(...)
    tracer.write_chrome_trace("serve_trace.json")
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path


class Span:
    """One timed region: name + args + [t0, t1) + child spans.

    Created by `Tracer.span`; entering attaches it to the current top of
    the tracer's stack (or the root list) and stamps t0, exiting stamps
    t1.  `dur_s` is None while the span is still open.
    """

    __slots__ = ("name", "args", "t0", "t1", "children", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, args: dict):
        self.name = name
        self.args = args
        self.t0: float | None = None
        self.t1: float | None = None
        self.children: list = []
        self._tracer = tracer

    @property
    def dur_s(self) -> float | None:
        if self.t0 is None or self.t1 is None:
            return None
        return self.t1 - self.t0

    def note(self, **args) -> None:
        """Attach args discovered after the span opened (e.g. the wave
        membership the scheduler only knows once the bucket is built)."""
        self.args.update(args)

    def __enter__(self) -> "Span":
        t = self._tracer
        (t._stack[-1].children if t._stack else t.roots).append(self)
        t._stack.append(self)
        self.t0 = t.clock()
        return self

    def __exit__(self, *exc) -> bool:
        self.t1 = self._tracer.clock()
        # tolerate exception-driven unwinds that skipped inner __exit__s
        stack = self._tracer._stack
        while stack and stack.pop() is not self:
            pass
        return False

    def find(self, name: str) -> list:
        """All descendant spans (including self) with this name."""
        out = [self] if self.name == name else []
        for c in self.children:
            out.extend(c.find(name))
        return out

    def __repr__(self):
        return (f"Span({self.name!r}, t0={self.t0}, t1={self.t1}, "
                f"children={len(self.children)})")


class _NullSpan:
    """The shared do-nothing span `span()` hands out when tracing is
    off: no clock read, no allocation, reentrant."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def note(self, **args):
        pass

    def find(self, name):
        return []


NULL_SPAN = _NullSpan()


class Tracer:
    """Records spans into a forest; not thread-safe by design (the
    serving engine and trainer are single-threaded drivers)."""

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.roots: list = []
        self._stack: list = []

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def span(self, name: str, **args) -> Span:
        return Span(self, name, args)

    def reset(self) -> None:
        self.roots = []
        self._stack = []

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------
    def find(self, name: str) -> list:
        out = []
        for r in self.roots:
            out.extend(r.find(name))
        return out

    def span_count(self) -> int:
        def walk(s):
            return 1 + sum(walk(c) for c in s.children)
        return sum(walk(r) for r in self.roots)

    # ------------------------------------------------------------------
    # Chrome trace-event export
    # ------------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """The trace as a Chrome trace-event JSON object ("X" complete
        events; ts/dur in microseconds, shifted so the earliest span
        starts at 0).  Open spans are exported with zero duration."""
        events: list = []

        def t0s(s):
            yield s.t0
            for c in s.children:
                yield from t0s(c)

        starts = [t for r in self.roots for t in t0s(r) if t is not None]
        epoch = min(starts) if starts else 0.0

        def emit(s: Span):
            if s.t0 is not None:
                end = s.t1 if s.t1 is not None else s.t0
                events.append({
                    "name": s.name, "ph": "X", "pid": 0, "tid": 0,
                    "cat": s.name.split(".", 1)[0],
                    "ts": (s.t0 - epoch) * 1e6,
                    "dur": (end - s.t0) * 1e6,
                    "args": {k: _json_safe(v) for k, v in s.args.items()},
                })
            for c in s.children:
                emit(c)

        for r in self.roots:
            emit(r)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.chrome_trace(), sort_keys=True))
        return path


def _json_safe(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    return str(v)


# ---------------------------------------------------------------------------
# ambient tracer: what instrumented code talks to
# ---------------------------------------------------------------------------
_AMBIENT: Tracer | None = None


def get_tracer() -> Tracer | None:
    return _AMBIENT


def set_tracer(tracer: Tracer | None) -> Tracer | None:
    """Install `tracer` as the process-ambient tracer; returns the
    previous one (so callers can restore it)."""
    global _AMBIENT
    prev = _AMBIENT
    _AMBIENT = tracer
    return prev


@contextlib.contextmanager
def tracing(tracer: Tracer):
    """Scoped `set_tracer`: ambient within the with-block, restored
    after (exception-safe)."""
    prev = set_tracer(tracer)
    try:
        yield tracer
    finally:
        set_tracer(prev)


def span(name: str, *, tracer: Tracer | None = None, **args):
    """Open a span on `tracer`, or on the ambient tracer when none is
    given; the shared NULL_SPAN when tracing is off."""
    t = _AMBIENT if tracer is None else tracer
    if t is None:
        return NULL_SPAN
    return t.span(name, **args)
