"""Numeric-health probes for the quantized stack (saturation, range
utilization, bound tightness, q7-vs-f32 SNR).

`repro.analysis.ranges` PROVES static int32 bounds; this module OBSERVES
what the quantized dataflow actually does at runtime, so the two can
cross-validate each other and the Q-CapsNets-style format search
(ROADMAP item 3) gets a per-layer quality signal to rank allocations.

The probe is ambient, exactly like the span tracer (`obs.trace`):
instrumented sites — the EdgeVM runners, `quant.int8_ops.rshift_sat8`,
the QAT fake-quant faces — guard on `numerics._PROBE is not None` and
otherwise touch nothing, so probes-off execution stays the untouched
hot path (no object allocated, no call made; the EdgeVM keeps its plain
loop).  Probes are pure observers: every statistic is recomputed in
int64 NEXT TO the real int32 computation, never inside it, so probed
and unprobed runs are bit-identical (pinned in tests/test_numerics.py
for all shipped configs x both roundings).

Per requantization point the probe records, in exact integer arithmetic:

  * saturation — elements whose shifted value falls outside [-128, 127]
    before the int8 clamp (`sat_lo` / `sat_hi`);
  * int32 clipping — elements whose int32-domain intermediate (the
    half-LSB add on right shifts, the shifted value on left shifts)
    exceeds int32 when recomputed in int64.  On a verifier-clean
    program this is provably zero — CI gates on it;
  * `acc_peak`, the raw pre-shift |accumulator| peak, and its ratio to
    the statically proven `acc_bound` (bound tightness: how much of the
    proof's headroom reality uses).

Per op output it records the int8 range and its utilization of the Qm.n
grid (optionally into a `MetricsRegistry` histogram); QAT fake-quant
sites count STE-clipped activations.  `snr_rows` runs `fwd_q7` against
the `fwd_f32` oracle layer by layer and reports signal-to-quantization-
noise per layer.  Everything rolls up into a `NumericsReport`
(`repro.numerics/v1`), consumed by `export_caps --numerics`,
`serve_caps --numerics-out`, `python -m repro.obs.analyze`, the
Table-2 harness, and `benchmarks/bench_numerics.py`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math

import numpy as np

NUMERICS_SCHEMA = "repro.numerics/v1"
INT8_MIN, INT8_MAX = -128, 127
INT32_MAX = 2 ** 31 - 1

# range-utilization histogram buckets: fractions of the int8 grid
_UTIL_BUCKETS = (0.125, 0.25, 0.5, 0.75, 0.9, 1.0, float("inf"))


def _is_tracer(x) -> bool:
    """True for abstract jax values (inside jit/vmap tracing) — probes
    only observe concrete eager execution; jitted serving waves skip."""
    try:
        import jax
        return isinstance(x, jax.core.Tracer)
    except Exception:               # jax-free numpy paths
        return False


class NumericsProbe:
    """Accumulates numeric-health observations keyed by (op, site).

    Instrumented code attributes observations to the CURRENT op context
    (`begin_op` / the `scope` context manager); the EdgeVM sets it per
    schedule entry, the jnp pipeline per layer.  Pass a
    `MetricsRegistry` to also stream range-utilization histograms and
    saturation/clip counters into labeled metric series.
    """

    def __init__(self, metrics=None):
        self.metrics = metrics
        self._recs: dict = {}           # (family, op, site) -> record
        self._op = (None, "<unscoped>", None)
        self._seq = 0
        if metrics is not None:
            self._h_util = metrics.histogram(
                "numerics.range_utilization",
                help="per-call peak |out| / 127 per op output",
                buckets=_UTIL_BUCKETS)
            self._c_sat = metrics.counter(
                "numerics.saturations",
                help="values clamped at the int8 rails per requant site")
            self._c_clip = metrics.counter(
                "numerics.int32_clips",
                help="int32-domain overflows recomputed in int64 "
                "(zero on verifier-clean programs)")

    # ------------------------------------------------------------------
    # op context
    # ------------------------------------------------------------------
    def begin_op(self, index, name: str, kind: str | None = None) -> None:
        self._op = (index, name, kind)
        self._seq = 0

    def _rec(self, family: str, site: str) -> dict:
        idx, op, kind = self._op
        key = (family, op, site)
        r = self._recs.get(key)
        if r is None:
            r = self._recs[key] = {
                "family": family, "op": op, "site": site,
                "op_index": idx, "kind": kind, "calls": 0, "n": 0}
        return r

    # ------------------------------------------------------------------
    # observation points (pure int64 recomputation; never mutates input)
    # ------------------------------------------------------------------
    def observe_requant(self, acc, shift, rounding: str, *,
                        site: str | None = None, bound=None) -> None:
        """One `rshift_sat8[_vec]` call: int32 accumulator `acc` about
        to be shifted by `shift` (scalar or per-lane array)."""
        a = np.asarray(acc)
        if a.size == 0:
            return
        if site is None:
            site = f"requant[{self._seq}]"
            self._seq += 1
        a64 = a.astype(np.int64)
        peak = int(np.abs(a64).max())
        sh = np.asarray(shift, np.int64)
        if rounding == "nearest":
            half = np.where(sh > 0,
                            np.left_shift(np.int64(1),
                                          np.maximum(sh - 1, 0)),
                            np.int64(0))
            pre = a64 + half
        else:
            pre = a64
        # the int32-domain intermediates, recomputed wide: the half-add
        # sum (right shifts) and the left-shifted value (negative sh)
        over = np.abs(pre) > INT32_MAX
        shifted = np.right_shift(pre, np.maximum(sh, 0))
        shifted = np.left_shift(shifted, np.maximum(-sh, 0))
        over |= np.abs(shifted) > INT32_MAX
        sat_hi = int((shifted > INT8_MAX).sum())
        sat_lo = int((shifted < INT8_MIN).sum())
        clips = int(over.sum())

        r = self._rec("requant", site)
        r["calls"] += 1
        r["n"] += int(a.size)
        r["sat_lo"] = r.get("sat_lo", 0) + sat_lo
        r["sat_hi"] = r.get("sat_hi", 0) + sat_hi
        r["int32_clip"] = r.get("int32_clip", 0) + clips
        r["acc_peak"] = max(r.get("acc_peak", 0), peak)
        if bound is not None:
            r["acc_bound"] = int(bound)
        if self.metrics is not None:
            if sat_lo or sat_hi:
                self._c_sat.inc(sat_lo + sat_hi, op=r["op"], site=site)
            if clips:
                self._c_clip.inc(clips, op=r["op"], site=site)

    def observe_output(self, y, *, frac=None, site: str = "out") -> None:
        """An op's int8 output tensor: range + grid utilization."""
        a = np.asarray(y)
        if a.size == 0:
            return
        lo = int(a.min())
        hi = int(a.max())
        util = max(abs(lo), abs(hi)) / float(INT8_MAX)
        r = self._rec("output", site)
        r["calls"] += 1
        r["n"] += int(a.size)
        r["out_min"] = min(r.get("out_min", lo), lo)
        r["out_max"] = max(r.get("out_max", hi), hi)
        r["util_sum"] = r.get("util_sum", 0.0) + util
        if frac is not None:
            r["frac"] = int(frac)
        if self.metrics is not None:
            self._h_util.observe(util, op=r["op"])

    def observe_fq(self, r_scaled) -> None:
        """One fake-quant call: `r_scaled` is the rounded pre-clip grid
        value; elements outside [-128, 127] are STE-clipped."""
        a = np.asarray(r_scaled)
        if a.size == 0:
            return
        clipped = int(((a < INT8_MIN) | (a > INT8_MAX)).sum())
        r = self._rec("fq", "fq")
        r["calls"] += 1
        r["n"] += int(a.size)
        r["clipped"] = r.get("clipped", 0) + clipped

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------
    def rows(self) -> list:
        """JSON-safe per-(op, site) rows with derived health metrics,
        deterministically ordered (schedule position, then name)."""
        out = []
        for r in self._recs.values():
            row = {"family": r["family"], "op": r["op"],
                   "site": r["site"], "op_index": r["op_index"],
                   "kind": r["kind"], "calls": r["calls"], "n": r["n"]}
            if r["family"] == "requant":
                sat = r["sat_lo"] + r["sat_hi"]
                row.update(
                    sat_lo=r["sat_lo"], sat_hi=r["sat_hi"],
                    saturation_rate=sat / r["n"] if r["n"] else 0.0,
                    int32_clip=r["int32_clip"],
                    acc_peak=r["acc_peak"],
                    acc_bits=int(r["acc_peak"]).bit_length())
                bound = r.get("acc_bound")
                row["acc_bound"] = bound
                if bound:
                    row["bound_bits"] = int(bound).bit_length()
                    row["bound_tightness"] = r["acc_peak"] / bound
            elif r["family"] == "output":
                row.update(
                    out_min=r["out_min"], out_max=r["out_max"],
                    frac=r.get("frac"),
                    range_util=max(abs(r["out_min"]),
                                   abs(r["out_max"])) / float(INT8_MAX),
                    util_mean=r["util_sum"] / r["calls"])
            else:                       # fq
                row.update(
                    clipped=r["clipped"],
                    clip_rate=r["clipped"] / r["n"] if r["n"] else 0.0)
            out.append(row)
        big = 1 << 30
        out.sort(key=lambda r: (r["op_index"] if r["op_index"] is not None
                                else big, r["op"], r["family"], r["site"]))
        return out

    def fq_clip_rates(self) -> dict:
        """op (layer scope) -> STE-clipped activation fraction."""
        return {r["op"]: (r["clipped"] / r["n"] if r["n"] else 0.0)
                for r in self._recs.values() if r["family"] == "fq"}


# ---------------------------------------------------------------------------
# ambient probe: what instrumented code guards on
# ---------------------------------------------------------------------------
_PROBE: NumericsProbe | None = None


def get_probe() -> NumericsProbe | None:
    return _PROBE


def set_probe(probe: NumericsProbe | None) -> NumericsProbe | None:
    """Install `probe` as the process-ambient probe; returns the
    previous one (so callers can restore it)."""
    global _PROBE
    prev = _PROBE
    _PROBE = probe
    return prev


@contextlib.contextmanager
def probing(probe: NumericsProbe):
    """Scoped `set_probe`: ambient within the with-block, restored
    after (exception-safe)."""
    prev = set_probe(probe)
    try:
        yield probe
    finally:
        set_probe(prev)


@contextlib.contextmanager
def scope(name: str, *, index=None, kind: str | None = None):
    """Attribute observations inside the block to op `name` (the jnp
    pipeline wraps each layer in one; no-op when probing is off)."""
    p = _PROBE
    if p is None:
        yield
        return
    prev = (p._op, p._seq)
    p.begin_op(index, name, kind)
    try:
        yield
    finally:
        p._op, p._seq = prev


def observe_requant(acc, shift, rounding: str, *,
                    site: str | None = None, bound=None) -> None:
    """Module-level hook for the jnp q7 ops: records on the ambient
    probe, skipping abstract (jit-traced) values."""
    p = _PROBE
    if p is None or _is_tracer(acc):
        return
    p.observe_requant(acc, shift, rounding, site=site, bound=bound)


def observe_fq(r_scaled) -> None:
    """Module-level hook for the fake-quant faces (Tracer-safe)."""
    p = _PROBE
    if p is None or _is_tracer(r_scaled):
        return
    p.observe_fq(r_scaled)


# ---------------------------------------------------------------------------
# SNR probe mode: fwd_q7 against the fwd_f32 oracle, layer by layer
# ---------------------------------------------------------------------------
def snr_rows(pipeline, params, qnet, images) -> list:
    """Per-layer signal-to-quantization-noise of the int8 pipeline
    against the float oracle, both walked layer by layer from the same
    input.  `params` are the float weights the model was quantized from
    (the oracle); the q7 output is dequantized with each layer plan's
    `out_frac`.  snr_db is None when the error is exactly zero."""
    import jax.numpy as jnp

    h_f = jnp.asarray(images, jnp.float32)
    h_q = qnet.quantize_input(h_f)
    rows = []
    for layer in pipeline.layers:
        h_f, _ = layer.fwd_f32(params[layer.name], h_f)
        h_q = layer.fwd_q7(qnet.qweights[layer.name], qnet.plan[layer.name],
                           h_q, backend=qnet.backend,
                           rounding=qnet.rounding)
        out_frac = qnet.plan[layer.name].out_frac
        ref = np.asarray(h_f, np.float64)
        deq = np.asarray(h_q, np.float64) * (2.0 ** -out_frac)
        sig = float(np.sum(ref * ref))
        err = float(np.sum((ref - deq) ** 2))
        snr_db = 10.0 * math.log10(sig / err) if err > 0 and sig > 0 \
            else None
        rows.append({"layer": layer.name, "out_frac": int(out_frac),
                     "signal_power": sig, "noise_power": err,
                     "snr_db": snr_db})
    return rows


# ---------------------------------------------------------------------------
# report
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NumericsReport:
    """Per-op numeric-health rows + per-layer SNR, serializable as a
    `repro.numerics/v1` document that reproduces the rows exactly."""
    program: str
    rounding: str
    batch: int
    rows: list
    snr: list = dataclasses.field(default_factory=list)

    # -- aggregates ----------------------------------------------------
    def total_int32_clip(self) -> int:
        return sum(r.get("int32_clip", 0) for r in self.rows)

    def worst_saturation_rate(self) -> float:
        rates = [r["saturation_rate"] for r in self.rows
                 if r["family"] == "requant"]
        return max(rates) if rates else 0.0

    def max_bound_tightness(self) -> float:
        vals = [r["bound_tightness"] for r in self.rows
                if r.get("bound_tightness") is not None]
        return max(vals) if vals else float("nan")

    def min_snr_db(self) -> float:
        vals = [r["snr_db"] for r in self.snr if r["snr_db"] is not None]
        return min(vals) if vals else float("nan")

    def summary(self) -> dict:
        """Worst offenders, one line per health axis."""
        def _argmax(fam, key):
            rows = [r for r in self.rows
                    if r["family"] == fam and r.get(key) is not None]
            return max(rows, key=lambda r: r[key]) if rows else None

        sat = _argmax("requant", "saturation_rate")
        tight = _argmax("requant", "bound_tightness")
        snr = min((r for r in self.snr if r["snr_db"] is not None),
                  key=lambda r: r["snr_db"], default=None)
        return {
            "int32_clip_total": self.total_int32_clip(),
            "worst_saturation": None if sat is None else
            {"op": sat["op"], "site": sat["site"],
             "rate": sat["saturation_rate"]},
            "worst_tightness": None if tight is None else
            {"op": tight["op"], "site": tight["site"],
             "tightness": tight["bound_tightness"]},
            "min_snr": None if snr is None else
            {"layer": snr["layer"], "snr_db": snr["snr_db"]},
        }

    # -- serialization (repro.numerics/v1) -----------------------------
    def to_doc(self) -> dict:
        return {"schema": NUMERICS_SCHEMA, "program": self.program,
                "rounding": self.rounding, "batch": self.batch,
                "rows": self.rows, "snr": self.snr,
                "summary": self.summary()}

    @classmethod
    def from_doc(cls, doc: dict) -> "NumericsReport":
        if doc.get("schema") != NUMERICS_SCHEMA:
            raise ValueError(f"not a {NUMERICS_SCHEMA} document: "
                             f"schema={doc.get('schema')!r}")
        return cls(program=doc["program"], rounding=doc["rounding"],
                   batch=doc["batch"], rows=doc["rows"],
                   snr=doc.get("snr", []))

    # -- text ----------------------------------------------------------
    def format(self) -> str:
        lines = [f"[{self.program}] numerics report "
                 f"(rounding={self.rounding}, batch {self.batch})"]
        req = [r for r in self.rows if r["family"] == "requant"]
        if req:
            lines.append(f"  {'op':<8}{'site':<12}{'n':>9}{'sat%':>8}"
                         f"{'clip32':>8}{'acc_peak':>12}{'bound':>12}"
                         f"{'tight%':>8}{'bits':>6}")
            for r in req:
                bound = r.get("acc_bound")
                tight = r.get("bound_tightness")
                lines.append(
                    f"  {r['op']:<8}{r['site']:<12}{r['n']:>9}"
                    f"{r['saturation_rate'] * 100:>7.2f}%"
                    f"{r['int32_clip']:>8}{r['acc_peak']:>12}"
                    f"{bound if bound is not None else '-':>12}"
                    + (f"{tight * 100:>7.1f}%" if tight is not None
                       else f"{'-':>8}")
                    + f"{r['acc_bits']:>6}")
        outs = [r for r in self.rows if r["family"] == "output"]
        if outs:
            lines.append(f"  {'op':<8}{'output range':<16}{'frac':>6}"
                         f"{'util%':>8}")
            for r in outs:
                rng = "[{}, {}]".format(r["out_min"], r["out_max"])
                frac = r["frac"] if r["frac"] is not None else "-"
                lines.append(f"  {r['op']:<8}{rng:<16}{frac:>6}"
                             f"{r['range_util'] * 100:>7.1f}%")
        if self.snr:
            lines.append(f"  {'layer':<8}{'out_frac':>9}{'snr_db':>9}")
            for r in self.snr:
                snr = "inf" if r["snr_db"] is None else f"{r['snr_db']:.1f}"
                lines.append(f"  {r['layer']:<8}{r['out_frac']:>9}"
                             f"{snr:>9}")
        s = self.summary()
        worst = []
        if s["worst_saturation"]:
            w = s["worst_saturation"]
            worst.append(f"saturation {w['op']}/{w['site']} "
                         f"{w['rate'] * 100:.2f}%")
        if s["worst_tightness"]:
            w = s["worst_tightness"]
            worst.append(f"tightness {w['op']}/{w['site']} "
                         f"{w['tightness'] * 100:.1f}%")
        if s["min_snr"]:
            worst.append(f"min snr {s['min_snr']['layer']} "
                         f"{s['min_snr']['snr_db']:.1f} dB")
        lines.append(f"  int32 clips: {s['int32_clip_total']}"
                     + ("; worst: " + "; ".join(worst) if worst else ""))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------
def run_numerics(qnet, images, *, params=None, metrics=None,
                 program=None) -> NumericsReport:
    """Probe one EdgeVM pass of `qnet` over `images` (floats) and
    return the report; with `params` (the float weights the model was
    quantized from) the per-layer SNR rows are included."""
    from repro.edge import EdgeVM, lower

    if program is None:
        program = lower(qnet)
    vm = EdgeVM(program)
    x = np.asarray(images, np.float32)
    x_q = np.asarray(qnet.quantize_input(x))
    probe = NumericsProbe(metrics=metrics)
    with probing(probe):
        vm.run(x_q)
    snr = snr_rows(qnet.pipeline, params, qnet, x) \
        if params is not None else []
    return NumericsReport(program=program.name, rounding=program.rounding,
                          batch=int(x_q.shape[0]), rows=probe.rows(),
                          snr=snr)


def run_program_numerics(program, x_q, *, metrics=None):
    """(output, NumericsReport) for one probed EdgeVM pass over an
    already-quantized batch — the artifact-only surface (no float
    oracle, so no SNR rows)."""
    from repro.edge import EdgeVM

    probe = NumericsProbe(metrics=metrics)
    with probing(probe):
        out = EdgeVM(program).run(x_q)
    batch = int(np.asarray(x_q).shape[0]) \
        if np.asarray(x_q).ndim > len(program.input_tensor.shape) else 1
    return out, NumericsReport(program=program.name,
                               rounding=program.rounding, batch=batch,
                               rows=probe.rows())


def check_containment(program, report: NumericsReport) -> list:
    """`observed range ⊆ static interval bound`, op/tensor-precise.

    Joins the report's requant rows against
    `repro.analysis.ranges.requant_bounds` (every requantization point's
    statically proven |int32| bound) and the output rows against the
    static int8 intervals.  Empty list = the verifier's proofs hold in
    practice; any finding means probe and proof disagree."""
    from repro.analysis.ranges import requant_bounds

    sites, out_ivs = requant_bounds(program)
    findings = []
    for row in report.rows:
        idx = row.get("op_index")
        if idx is None:
            continue
        if row["family"] == "requant":
            bound = sites.get((idx, row["site"]))
            if bound is not None and row["acc_peak"] > bound:
                findings.append(
                    f"op[{idx}] {row['op']}/{row['site']}: observed "
                    f"|acc| {row['acc_peak']} exceeds the static bound "
                    f"{bound}")
        elif row["family"] == "output":
            lo, hi = out_ivs.get(idx, (INT8_MIN, INT8_MAX))
            if row["out_min"] < lo or row["out_max"] > hi:
                findings.append(
                    f"op[{idx}] {row['op']} output: observed range "
                    f"[{row['out_min']}, {row['out_max']}] outside the "
                    f"static interval [{lo}, {hi}]")
    return findings
