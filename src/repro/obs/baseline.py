"""Perf baselines: committed BENCH_*.json snapshots + a direction-aware
regression gate — the "regress" leg of the observe -> analyze ->
regress loop.

`benchmarks.run --out DIR` emits one `repro.bench/v1` document per
section; this module compares such a run against the committed
snapshots in `benchmarks/baselines/` and fails loudly (exit 1, the
offending section/row/metric named) when a gated metric moved the wrong
way:

    PYTHONPATH=src python -m repro.obs.baseline compare artifacts/bench
    PYTHONPATH=src python -m repro.obs.baseline record  artifacts/bench \
        --sections serving,edge_vm,variants,observability

Tolerance policy (METRIC_POLICY): every gated metric declares a
DIRECTION — "higher" means only a decrease is a regression (img/s,
speedup, occupancy), "lower" means only growth is (latency, us/call),
"exact" means any change is (deterministic counters: waves scheduled,
variant fallbacks) — and a relative tolerance in the bad direction.
Timing tolerances are deliberately generous (smoke runs on shared CI
machines are noisy; the committed trajectory is about catching 2-3x
cliffs, not 10% wobble) and scale with `--slack`; exact metrics never
do.  Metrics without a policy entry are ignored: a section is free to
grow figures without tripping the gate, and gets gated the day its
metric is added to the policy.

Only sections with a committed baseline are compared; extra sections in
the run are reported as notes, so the gate keeps passing while new
bench sections incubate, and `record` is the deliberate act that starts
gating one.  An improved number never fails the gate — re-record when
you want the trajectory to remember it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import shutil

BENCH_SCHEMA = "repro.bench/v1"
DEFAULT_BASELINE_DIR = "benchmarks/baselines"


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """Direction-aware relative tolerance for one metric.

    direction: "higher" (better; gate on decrease), "lower" (better;
    gate on increase), or "exact" (deterministic; gate on any change).
    rel: allowed relative change in the bad direction (0.60 on a
    "higher" metric = may regress up to 60%; 1.5 on a "lower" metric =
    may grow up to 150%, i.e. 2.5x).  `timing` marks wall-clock-derived
    metrics whose rel scales with the CLI --slack factor.
    """
    direction: str
    rel: float
    timing: bool = False

    def bound(self, base: float, slack: float) -> float | None:
        """The worst acceptable new value, or None for exact metrics.
        Relative to |base| so negative-valued metrics (snr_db) gate in
        the same direction as positive ones."""
        if self.direction == "exact":
            return None
        rel = self.rel * (slack if self.timing else 1.0)
        if self.direction == "higher":
            return base - abs(base) * min(rel, 1.0)
        return base + abs(base) * rel


# The gated metrics.  Row `us_per_call` is implicitly "lower"/timing
# (US_PER_CALL below); everything else must appear here to be gated.
METRIC_POLICY = {
    # throughput figures: may only regress
    "images_per_s": Tolerance("higher", 0.60, timing=True),
    "speedup": Tolerance("higher", 0.60, timing=True),
    # latency figures: may only grow
    "p95_ms": Tolerance("lower", 1.5, timing=True),
    # accuracy: may only drop, and not by much (seeded eval; the small
    # rel absorbs cross-platform float wobble, not real regressions)
    "acc": Tolerance("higher", 0.05),
    # deterministic scheduling/counter figures: must not move at all
    "occupancy": Tolerance("exact", 0.0),
    "waves": Tolerance("exact", 0.0),
    "total_fallback_decisions": Tolerance("exact", 0.0),
    "default_variant_fallbacks": Tolerance("exact", 0.0),
    "total": Tolerance("exact", 0.0),
    "default": Tolerance("exact", 0.0),
    # deterministic memory-plan figures (edge_vm arena rows)
    "arena_bytes": Tolerance("exact", 0.0),
    "naive_bytes": Tolerance("exact", 0.0),
    "flash_bytes": Tolerance("exact", 0.0),
    "ram_bytes": Tolerance("exact", 0.0),
    # numeric health (numerics rows): saturation may only shrink, SNR
    # may only improve (small rel absorbs float wobble in the f32
    # oracle), int32 clips are proven-impossible and must stay 0
    "saturation_rate": Tolerance("lower", 1.0),
    "snr_db": Tolerance("higher", 0.25),
    "int32_clip": Tolerance("exact", 0.0),
}

US_PER_CALL = Tolerance("lower", 1.5, timing=True)

_EXACT_EPS = 1e-9


def _check_metric(where: str, metric: str, tol: Tolerance,
                  base, new, slack: float) -> list:
    if not isinstance(base, (int, float)) or isinstance(base, bool):
        return []                                # non-numeric: not gated
    if not isinstance(new, (int, float)) or isinstance(new, bool):
        return [f"{where}: {metric} was {base!r}, is now "
                f"non-numeric {new!r}"]
    if tol.direction == "exact":
        if abs(new - base) > _EXACT_EPS + _EXACT_EPS * abs(base):
            return [f"{where}: {metric} changed {base!r} -> {new!r} "
                    "(deterministic metric; any change is a finding — "
                    "re-record the baseline if deliberate)"]
        return []
    bound = tol.bound(base, slack)
    if tol.direction == "higher" and new < bound:
        return [f"{where}: {metric} regressed {base:g} -> {new:g} "
                f"(allowed >= {bound:g}; may regress "
                f"{tol.rel * (slack if tol.timing else 1) * 100:.0f}%)"]
    if tol.direction == "lower" and new > bound:
        return [f"{where}: {metric} grew {base:g} -> {new:g} "
                f"(allowed <= {bound:g}; may grow "
                f"{tol.rel * (slack if tol.timing else 1) * 100:.0f}%)"]
    return []


def compare_docs(base: dict, new: dict, slack: float = 1.0) -> list:
    """Findings from comparing one section's run doc against its
    committed baseline (empty list = within tolerance)."""
    section = base.get("section", "?")
    where = f"BENCH_{section}"
    findings = []
    if new.get("section") != section:
        return [f"{where}: run doc is for section "
                f"{new.get('section')!r}, baseline for {section!r}"]
    if bool(new.get("smoke")) != bool(base.get("smoke")):
        findings.append(
            f"{where}: smoke={new.get('smoke')!r} run compared against "
            f"smoke={base.get('smoke')!r} baseline — record a matching "
            "baseline instead")
    # section-level figures
    base_figs = base.get("figures", {})
    new_figs = new.get("figures", {})
    for metric, tol in METRIC_POLICY.items():
        if metric in base_figs:
            if metric not in new_figs:
                findings.append(f"{where}: figure {metric!r} "
                                "disappeared from the run")
            else:
                findings += _check_metric(where, metric, tol,
                                          base_figs[metric],
                                          new_figs[metric], slack)
    # rows, joined by name
    new_rows = {r.get("name"): r for r in new.get("rows", [])}
    for brow in base.get("rows", []):
        name = brow.get("name")
        nrow = new_rows.get(name)
        rwhere = f"{where}.{name}"
        if nrow is None:
            findings.append(f"{rwhere}: row disappeared from the run")
            continue
        b_us = brow.get("us_per_call", 0)
        if isinstance(b_us, (int, float)) and b_us > 0:
            findings += _check_metric(rwhere, "us_per_call",
                                      US_PER_CALL, b_us,
                                      nrow.get("us_per_call"), slack)
        bf, nf = brow.get("figures", {}), nrow.get("figures", {})
        for metric, tol in METRIC_POLICY.items():
            if metric in bf:
                if metric not in nf:
                    findings.append(f"{rwhere}: figure {metric!r} "
                                    "disappeared from the run")
                else:
                    findings += _check_metric(rwhere, metric, tol,
                                              bf[metric], nf[metric],
                                              slack)
    return findings


def _load_dir(d) -> dict:
    """section -> parsed BENCH doc, for every BENCH_*.json in `d`."""
    out = {}
    for path in sorted(pathlib.Path(d).glob("BENCH_*.json")):
        doc = json.loads(path.read_text())
        out[doc.get("section", path.stem)] = doc
    return out


def compare_dirs(out_dir, baseline_dir, slack: float = 1.0) -> tuple:
    """(findings, notes) comparing a bench run against the committed
    baselines.  Sections without a baseline are notes, not findings —
    `record` is what opts a section into the gate."""
    base_docs = _load_dir(baseline_dir)
    new_docs = _load_dir(out_dir)
    findings: list = []
    notes: list = []
    if not base_docs:
        findings.append(f"{baseline_dir}: no committed BENCH_*.json "
                        "baselines (run `record` first)")
    for section, base in sorted(base_docs.items()):
        new = new_docs.get(section)
        if new is None:
            findings.append(f"BENCH_{section}: baselined section "
                            "missing from the run")
            continue
        findings += compare_docs(base, new, slack=slack)
    for section in sorted(set(new_docs) - set(base_docs)):
        notes.append(f"BENCH_{section}: no baseline committed — not "
                     "gated (record it to start the trajectory)")
    return findings, notes


def record(out_dir, baseline_dir, sections=None) -> list:
    """Snapshot BENCH docs from a run into the baselines directory (the
    deliberate re-baseline action).  Validates each doc against the
    bench schema first — a malformed artifact must not become the
    yardstick.  Returns the written paths."""
    from benchmarks import validate as bench_validate

    out_dir = pathlib.Path(out_dir)
    baseline_dir = pathlib.Path(baseline_dir)
    baseline_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for path in sorted(out_dir.glob("BENCH_*.json")):
        doc = json.loads(path.read_text())
        section = doc.get("section")
        if sections is not None and section not in sections:
            continue
        findings = bench_validate.validate_doc(doc, path.name)
        findings += bench_validate.validate_invariants(doc, path.name)
        if findings:
            raise ValueError(
                f"refusing to baseline {path.name}: " + "; ".join(findings))
        dst = baseline_dir / path.name
        shutil.copyfile(path, dst)
        written.append(dst)
    if not written:
        raise ValueError(f"{out_dir}: nothing to record "
                         f"(sections={sections})")
    return written


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Record / compare committed perf baselines "
        "(benchmarks/baselines/*.json, schema repro.bench/v1)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    cmp_p = sub.add_parser("compare", help="gate a bench run against "
                           "the committed baselines (exit 1 on any "
                           "out-of-tolerance metric)")
    cmp_p.add_argument("out_dir", help="directory with the run's "
                       "BENCH_*.json artifacts")
    cmp_p.add_argument("--baselines", default=DEFAULT_BASELINE_DIR)
    cmp_p.add_argument("--slack", type=float, default=1.0,
                       help="multiplier on the timing tolerances "
                       "(exact metrics are unaffected); CI uses > 1 on "
                       "noisy shared runners")
    rec_p = sub.add_parser("record", help="snapshot a bench run as the "
                           "new committed baselines")
    rec_p.add_argument("out_dir")
    rec_p.add_argument("--baselines", default=DEFAULT_BASELINE_DIR)
    rec_p.add_argument("--sections", default=None,
                       help="comma-separated sections to record "
                       "(default: every BENCH_*.json in the run)")
    args = ap.parse_args(argv)

    if args.cmd == "record":
        sections = (None if args.sections is None
                    else set(args.sections.split(",")))
        written = record(args.out_dir, args.baselines, sections)
        for p in written:
            print(f"recorded {p}")
        return 0

    findings, notes = compare_dirs(args.out_dir, args.baselines,
                                   slack=args.slack)
    for n in notes:
        print(f"NOTE: {n}")
    for f in findings:
        print(f"REGRESSION: {f}")
    print(f"obs.baseline: compared {args.out_dir} vs {args.baselines} "
          f"(slack {args.slack:g}) -> {len(findings)} findings "
          f"{'FAIL' if findings else 'ok'}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
