"""Trace analytics: turn a recorded span forest into "where the time
went" — plus the cost-model drift report that keeps the static MCU
estimates honest against what the EdgeVM measures.

PR 7 made every subsystem *emit* spans and metrics; nothing consumed
them.  This module is the consumer:

  * `analyze(source)` ingests a live `Tracer` or a Chrome trace-event
    JSON (dict or path — the exact format `Tracer.write_chrome_trace`
    emits) and produces per-span-name statistics (count / total / mean /
    p50 / p95 / max, self-time vs child-time), the critical path of
    every `serve.wave`, a queue/compile/execute wall-time breakdown per
    (model, bucket), and — from the `req_id`/`req_ids` args the serving
    engine stamps — the reconstructed enqueue -> complete timeline of
    every request, from the trace alone;
  * `costmodel_drift(program, measured_rows)` joins
    `EdgeVM.run(profile=rows)` measured rows against
    `costmodel.estimate_program` estimated rows on their shared
    `op_index`/name/kind join key and reports, per MCU profile, each
    op's estimated-vs-measured share of the program and how far its
    est/meas ratio drifts from the program-wide ratio — the number that
    moves when the cost model stops describing the workload.

Both sources normalize to the same epoch-relative timeline, so
analyzing a tracer and analyzing its own Chrome export produce the same
report bit for bit (pinned in tests/test_obs_analyze.py under a fake
clock).  Percentiles follow the repo-wide tiny-sample policy
(`obs.Histogram.percentile`): nearest rank, n < 3 -> exact max, never
interpolated.

CLI:

    PYTHONPATH=src python -m repro.obs.analyze trace.json \
        [--metrics metrics.json] [--json]

where `trace.json` comes from `serve_caps --trace` and `metrics.json`
from `serve_caps --metrics-out` — one serving run yields trace +
metrics + this summary from the same process.  The positional argument
also accepts a `repro.numerics/v1` numeric-health doc (export_caps /
serve_caps `--numerics-out`); `--gate-clips` then exits 1 on any
recorded int32-clip event.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

# float-noise tolerance for interval containment when rebuilding the
# span forest from Chrome microsecond timestamps (exact under the fake
# clocks tests use; real clocks carry ~ns rounding from the us export)
_EPS_S = 1e-7


@dataclasses.dataclass
class TraceNode:
    """One span, source-independent: times are epoch-relative seconds
    (the earliest span in the forest starts at 0.0)."""
    name: str
    t0: float
    t1: float
    args: dict
    children: list

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0

    @property
    def self_s(self) -> float:
        """Duration minus the time spent inside child spans."""
        return self.dur_s - sum(c.dur_s for c in self.children)


# ---------------------------------------------------------------------------
# ingestion: Tracer forest | Chrome trace JSON | path
# ---------------------------------------------------------------------------
def nodes_from_tracer(tracer) -> list:
    """Copy a Tracer's forest into epoch-relative TraceNodes (open spans
    are closed at their own t0, matching the Chrome export)."""
    def starts(s):
        if s.t0 is not None:
            yield s.t0
        for c in s.children:
            yield from starts(c)

    epoch = min((t for r in tracer.roots for t in starts(r)), default=0.0)

    def copy(s):
        t0 = (s.t0 if s.t0 is not None else epoch) - epoch
        t1 = (s.t1 if s.t1 is not None else s.t0 or epoch) - epoch
        return TraceNode(s.name, t0, t1, dict(s.args),
                         [copy(c) for c in s.children])

    return [copy(r) for r in tracer.roots]


def nodes_from_chrome(doc: dict) -> list:
    """Rebuild the span forest from Chrome "X" events by interval
    containment, in file order (the exporter writes parents depth-first
    before their children)."""
    roots: list = []
    stack: list = []
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        t0 = ev["ts"] / 1e6
        node = TraceNode(ev["name"], t0, t0 + ev.get("dur", 0.0) / 1e6,
                         dict(ev.get("args", {})), [])
        while stack and not (node.t0 >= stack[-1].t0 - _EPS_S
                             and node.t1 <= stack[-1].t1 + _EPS_S):
            stack.pop()
        (stack[-1].children if stack else roots).append(node)
        stack.append(node)
    return roots


def load_trace(source) -> list:
    """TraceNode roots from a Tracer, a Chrome trace dict, or a path to
    a Chrome trace JSON file."""
    if isinstance(source, (str, pathlib.Path)):
        source = json.loads(pathlib.Path(source).read_text())
    if isinstance(source, dict):
        return nodes_from_chrome(source)
    if hasattr(source, "roots"):                 # a Tracer
        return nodes_from_tracer(source)
    raise TypeError(f"cannot load a trace from {type(source).__name__}; "
                    "want a Tracer, a Chrome trace dict, or a path")


def walk(roots) -> list:
    out: list = []
    stack = list(reversed(roots))
    while stack:
        n = stack.pop()
        out.append(n)
        stack.extend(reversed(n.children))
    return out


# ---------------------------------------------------------------------------
# per-span-name statistics
# ---------------------------------------------------------------------------
def _pctl(sorted_vals: list, p: float):
    """Repo-wide pinned percentile: None on empty, exact max below 3
    samples, nearest rank otherwise (no interpolation anywhere)."""
    n = len(sorted_vals)
    if n == 0:
        return None
    if n < 3:
        return sorted_vals[-1]
    rank = max(1, min(n, -(-int(p * n) // 100)))
    return sorted_vals[rank - 1]


def span_stats(roots) -> dict:
    """name -> {count, total_s, mean_s, p50_s, p95_s, max_s, self_s}."""
    durs: dict = {}
    selfs: dict = {}
    for n in walk(roots):
        durs.setdefault(n.name, []).append(n.dur_s)
        selfs[n.name] = selfs.get(n.name, 0.0) + n.self_s
    out = {}
    for name in sorted(durs):
        d = sorted(durs[name])
        total = sum(d)
        out[name] = {"count": len(d), "total_s": total,
                     "mean_s": total / len(d),
                     "p50_s": _pctl(d, 50), "p95_s": _pctl(d, 95),
                     "max_s": d[-1], "self_s": selfs[name]}
    return out


# ---------------------------------------------------------------------------
# serve.wave critical paths + per-(model, bucket) breakdown
# ---------------------------------------------------------------------------
def critical_path(node: TraceNode) -> list:
    """Longest-child chain from `node` down: the serial spans are
    nested, so the heaviest child at every level IS the critical path."""
    path = []
    while True:
        path.append({"name": node.name, "dur_s": node.dur_s,
                     "self_s": node.self_s})
        if not node.children:
            return path
        node = max(node.children, key=lambda c: c.dur_s)


def _req_ids(args: dict) -> list:
    ids = args.get("req_ids")
    if ids is None or ids == "":
        return []
    if isinstance(ids, (list, tuple)):
        return [int(i) for i in ids]
    return [int(i) for i in str(ids).split(",")]


def wave_summaries(roots) -> list:
    """One entry per serve.wave span, in schedule order: identity args +
    duration + critical path."""
    out = []
    for n in walk(roots):
        if n.name != "serve.wave":
            continue
        out.append({"wave": n.args.get("wave"),
                    "model": n.args.get("model"),
                    "bucket": n.args.get("bucket"),
                    "n_real": n.args.get("n_real"),
                    "req_ids": _req_ids(n.args),
                    "dur_s": n.dur_s,
                    "critical_path": critical_path(n)})
    return out


def request_timelines(roots) -> list:
    """Reconstruct every request's end-to-end timeline from the trace
    alone: `serve.enqueue` (req_id arg) gives t_enq, the serve.wave
    whose req_ids membership names the request gives the wave identity,
    and its serve.complete child's exit gives t_done."""
    enq = {}
    for n in walk(roots):
        if n.name == "serve.enqueue" and "req_id" in n.args:
            enq[int(n.args["req_id"])] = n
    out = []
    for n in walk(roots):
        if n.name != "serve.wave":
            continue
        complete = [c for c in n.children if c.name == "serve.complete"]
        t_done = complete[-1].t1 if complete else n.t1
        for rid in _req_ids(n.args):
            e = enq.get(rid)
            row = {"req_id": rid, "model": n.args.get("model"),
                   "wave": n.args.get("wave"),
                   "bucket": n.args.get("bucket"), "t_done": t_done}
            if e is not None:
                row.update(t_enq=e.t0, e2e_s=t_done - e.t0,
                           queue_s=max(0.0, n.t0 - e.t1))
            out.append(row)
    return sorted(out, key=lambda r: r["req_id"])


_WAVE_PHASES = {"serve.bucket": "bucket_s", "serve.compile": "compile_s",
                "serve.execute": "execute_s", "serve.complete": "complete_s"}


def wave_breakdown(roots) -> list:
    """Queue/bucket/compile/execute/complete wall time per (model,
    bucket): where a serving run's wall clock went, per wave shape."""
    agg: dict = {}
    for w in walk(roots):
        if w.name != "serve.wave":
            continue
        key = (w.args.get("model"), w.args.get("bucket"))
        a = agg.setdefault(key, {"model": key[0], "bucket": key[1],
                                 "waves": 0, "images": 0, "wave_s": 0.0,
                                 "queue_s": 0.0, "bucket_s": 0.0,
                                 "compile_s": 0.0, "execute_s": 0.0,
                                 "complete_s": 0.0})
        a["waves"] += 1
        a["images"] += int(w.args.get("n_real") or 0)
        a["wave_s"] += w.dur_s
        for c in w.children:
            phase = _WAVE_PHASES.get(c.name)
            if phase is not None:
                a[phase] += c.dur_s
    for r in request_timelines(roots):
        key = (r.get("model"), r.get("bucket"))
        if key in agg and "queue_s" in agg[key] and "e2e_s" in r:
            agg[key]["queue_s"] += r["queue_s"]
    return [agg[k] for k in sorted(agg, key=lambda k: (str(k[0]),
                                                       str(k[1])))]


# ---------------------------------------------------------------------------
# the one-call report
# ---------------------------------------------------------------------------
def analyze(source, metrics: dict | None = None) -> dict:
    """The full analysis of one trace (and optionally the metrics
    snapshot recorded by the same run), as one JSON-safe dict."""
    roots = load_trace(source)
    report = {
        "span_count": len(walk(roots)),
        "spans": span_stats(roots),
        "waves": wave_summaries(roots),
        "requests": request_timelines(roots),
        "breakdown": wave_breakdown(roots),
    }
    if metrics is not None:
        report["metrics"] = metrics
    return report


def _ms(x) -> str:
    return "n/a" if x is None else f"{x * 1e3:.3f}"


def format_analysis(report: dict) -> str:
    lines = [f"trace: {report['span_count']} spans, "
             f"{len(report['spans'])} distinct names"]
    lines.append(f"  {'span':<24}{'count':>6}{'total_ms':>10}"
                 f"{'mean_ms':>9}{'p50_ms':>9}{'p95_ms':>9}{'max_ms':>9}"
                 f"{'self_ms':>9}")
    by_total = sorted(report["spans"].items(),
                      key=lambda kv: -kv[1]["total_s"])
    for name, s in by_total:
        lines.append(f"  {name:<24}{s['count']:>6}"
                     f"{_ms(s['total_s']):>10}{_ms(s['mean_s']):>9}"
                     f"{_ms(s['p50_s']):>9}{_ms(s['p95_s']):>9}"
                     f"{_ms(s['max_s']):>9}{_ms(s['self_s']):>9}")
    if report["waves"]:
        lines.append("waves (critical path):")
        for w in report["waves"]:
            path = " > ".join(p["name"] for p in w["critical_path"])
            lines.append(f"  wave {w['wave']} model={w['model']} "
                         f"bucket={w['bucket']} n_real={w['n_real']} "
                         f"{_ms(w['dur_s'])}ms: {path}")
    if report["breakdown"]:
        lines.append("breakdown per (model, bucket), wall ms:")
        lines.append(f"  {'model':<16}{'bucket':>7}{'waves':>6}"
                     f"{'imgs':>5}{'queue':>9}{'compile':>9}"
                     f"{'execute':>9}{'complete':>9}")
        for b in report["breakdown"]:
            lines.append(f"  {str(b['model']):<16}{str(b['bucket']):>7}"
                         f"{b['waves']:>6}{b['images']:>5}"
                         f"{_ms(b['queue_s']):>9}{_ms(b['compile_s']):>9}"
                         f"{_ms(b['execute_s']):>9}"
                         f"{_ms(b['complete_s']):>9}")
    reqs = [r for r in report["requests"] if "e2e_s" in r]
    if reqs:
        e2e = sorted(r["e2e_s"] for r in reqs)
        lines.append(f"requests: {len(reqs)} reconstructed | e2e "
                     f"p50 {_ms(_pctl(e2e, 50))} / "
                     f"p95 {_ms(_pctl(e2e, 95))} / "
                     f"max {_ms(e2e[-1])} ms")
    m = report.get("metrics")
    if m is not None:
        lines.append(_format_metrics(m))
    return "\n".join(lines)


def _format_metrics(doc: dict) -> str:
    """Compact rendering of a metrics snapshot — either a raw
    `MetricsRegistry.snapshot()` or the `repro.metrics/v1` document
    `serve_caps --metrics-out` writes."""
    if doc.get("schema") == "repro.metrics/v1":
        lines = ["metrics (repro.metrics/v1):"]
        for part in ("run", "process"):
            snap = doc.get(part) or {}
            if snap:
                lines.append(f"  [{part}]")
                lines.extend("  " + ln
                             for ln in _snap_lines(snap))
        s = doc.get("serve_summary")
        if s:
            lines.append(f"  serve window: images={s.get('images')} "
                         f"waves={s.get('waves')} "
                         f"p95_ms={s.get('p95_ms')} "
                         f"img/s={s.get('images_per_s')}")
        return "\n".join(lines)
    return "\n".join(["metrics snapshot:"] +
                     ["  " + ln for ln in _snap_lines(doc)])


def _snap_lines(snap: dict) -> list:
    lines = []
    for name, entry in sorted(snap.items()):
        if entry.get("kind") == "histogram":
            tot = sum(s["value"].get("count", 0)
                      for s in entry.get("series", []))
            lines.append(f"{name} (histogram): {tot} observations")
        else:
            tot = sum(s.get("value", 0) or 0
                      for s in entry.get("series", [])
                      if isinstance(s.get("value"), (int, float)))
            lines.append(f"{name} ({entry.get('kind')}): {tot:g}")
    return lines


# ---------------------------------------------------------------------------
# cost-model drift: estimated vs measured, per op and per program
# ---------------------------------------------------------------------------
def costmodel_drift(program, measured_rows, profiles=None,
                    batch: int = 1) -> dict:
    """Join `EdgeVM.run(profile=rows)` measured rows against
    `costmodel.estimate_program(program, ...)` estimated rows on their
    shared (op_index, name, kind) key.

    Absolute est/meas ratios are expected to be large (MCU cycles vs a
    host NumPy interpreter); the drift signal is scale-free: each op's
    `est_share` vs `meas_share` of the program total, and `rel_drift` =
    how far the op's est/meas ratio sits from the program-wide ratio.
    A cost model that ranks ops the way the VM measures them has every
    rel_drift near 0 regardless of the host's speed.

    `batch` is the number of images the measured rows covered (wall
    time is normalized per image; the estimate is per inference).
    Returns coverage over the schedule — the drift gate requires 100%.
    """
    from repro.edge import costmodel

    if profiles is None:
        profiles = sorted(costmodel.MCU_PROFILES)
    measured = {}
    for row in measured_rows:
        key = row.get("op_index")
        if key is None:                          # pre-join-key rows
            key = row["name"]
        measured[key] = row

    out_profiles = {}
    unmatched: list = []
    n_joined = 0
    for pname in profiles:
        est = costmodel.estimate_program(program, pname)
        rows = []
        unmatched = []
        for erow in est["rows"]:
            mrow = measured.get(erow["op_index"],
                                measured.get(erow["name"]))
            if mrow is None or mrow["name"] != erow["name"] \
                    or mrow["kind"] != erow["kind"]:
                unmatched.append({"op_index": erow["op_index"],
                                  "name": erow["name"],
                                  "kind": erow["kind"]})
                continue
            meas_ms = mrow["wall_s"] * 1e3 / max(batch, 1)
            rows.append({"op_index": erow["op_index"],
                         "name": erow["name"], "kind": erow["kind"],
                         "est_ms": erow["ms"], "meas_ms": meas_ms})
        total_est = sum(r["est_ms"] for r in rows)
        total_meas = sum(r["meas_ms"] for r in rows)
        ratio = total_est / total_meas if total_meas > 0 else None
        for r in rows:
            r["est_share"] = r["est_ms"] / total_est if total_est else 0.0
            r["meas_share"] = (r["meas_ms"] / total_meas
                               if total_meas else 0.0)
            if ratio and r["meas_ms"] > 0:
                r["ratio"] = r["est_ms"] / r["meas_ms"]
                r["rel_drift"] = r["ratio"] / ratio - 1.0
            else:
                r["ratio"] = None
                r["rel_drift"] = None
        drifts = [abs(r["rel_drift"]) for r in rows
                  if r["rel_drift"] is not None]
        out_profiles[pname] = {
            "rows": rows, "total_est_ms": total_est,
            "total_meas_ms": total_meas, "ratio": ratio,
            "max_abs_rel_drift": max(drifts) if drifts else None,
        }
        n_joined = len(rows)
    n_ops = len(program.ops)
    return {"program": program.name, "batch": batch,
            "n_ops": n_ops, "n_joined": n_joined,
            "coverage": n_joined / n_ops if n_ops else 1.0,
            "unmatched": unmatched, "profiles": out_profiles}


def format_drift(drift: dict) -> str:
    lines = [f"[{drift['program']}] cost-model drift: estimate vs "
             f"EdgeVM-measured (batch {drift['batch']}, join coverage "
             f"{drift['n_joined']}/{drift['n_ops']} ops = "
             f"{drift['coverage'] * 100:.0f}%)"]
    if drift["unmatched"]:
        lines.append(f"  UNMATCHED schedule ops: {drift['unmatched']}")
    for pname, p in drift["profiles"].items():
        ratio = "n/a" if p["ratio"] is None else f"{p['ratio']:.1f}x"
        mx = ("n/a" if p["max_abs_rel_drift"] is None
              else f"{p['max_abs_rel_drift'] * 100:.1f}%")
        lines.append(f"  profile {pname}: est {p['total_est_ms']:.2f} ms"
                     f" vs meas {p['total_meas_ms']:.3f} ms/img "
                     f"(ratio {ratio}, max |rel drift| {mx})")
        lines.append(f"    {'op':<8}{'kind':<18}{'est_ms':>10}"
                     f"{'meas_ms':>10}{'est%':>7}{'meas%':>7}"
                     f"{'drift':>9}")
        for r in p["rows"]:
            d = ("n/a" if r["rel_drift"] is None
                 else f"{r['rel_drift'] * 100:+.1f}%")
            lines.append(f"    {r['name']:<8}{r['kind']:<18}"
                         f"{r['est_ms']:>10.2f}{r['meas_ms']:>10.3f}"
                         f"{r['est_share'] * 100:>6.1f}%"
                         f"{r['meas_share'] * 100:>6.1f}%{d:>9}")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Analyze an observability artifact: a Chrome trace "
        "recorded by serve_caps --trace (span stats, wave critical "
        "paths, per-request timelines) or a repro.numerics/v1 doc "
        "(export_caps --numerics-out / serve_caps --numerics-out)")
    ap.add_argument("trace", help="Chrome trace-event JSON "
                    "(serve_caps --trace PATH) or a repro.numerics/v1 "
                    "numeric-health doc")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="metrics snapshot JSON to fold into the report "
                    "(serve_caps --metrics-out PATH)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of text")
    ap.add_argument("--gate-clips", action="store_true",
                    help="numerics docs only: exit 1 when the doc "
                    "records any int32-clip event (the CI gate — clips "
                    "are statically proven impossible on shipped "
                    "configs)")
    args = ap.parse_args(argv)
    try:
        doc = json.loads(pathlib.Path(args.trace).read_text())
    except (ValueError, OSError):
        doc = None
    if isinstance(doc, dict) and doc.get("schema") == "repro.numerics/v1":
        from repro.obs.numerics import NumericsReport
        report = NumericsReport.from_doc(doc)
        if args.json:
            print(json.dumps(report.to_doc(), indent=1, sort_keys=True))
        else:
            print(report.format())
        clips = report.total_int32_clip()
        if args.gate_clips and clips:
            print(f"analyze: GATE FAILED — {clips} int32-clip event(s) "
                  "recorded (expected 0)", file=sys.stderr)
            return 1
        return 0
    if args.gate_clips:
        ap.error("--gate-clips needs a repro.numerics/v1 doc")
    metrics = None
    if args.metrics:
        metrics = json.loads(pathlib.Path(args.metrics).read_text())
    report = analyze(args.trace, metrics=metrics)
    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True))
    else:
        print(format_analysis(report))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
