"""Process-wide metrics registry: counters / gauges / histograms with
labeled series behind one `snapshot() -> dict` face.

Before this module every subsystem grew its own ad-hoc counters
(`PallasBackend.fallbacks` was a bare `collections.Counter`,
`ModelRegistry` carried three loose ints, `ServeMetrics` kept raw
lists).  Those attributes still exist — as *views* over instruments
registered here — but the single source of truth is a `MetricsRegistry`,
so one `snapshot()` (JSON-safe) shows everything a process counted.

Instruments are get-or-create by name (re-registering with a different
kind is an error) and hold labeled series: `inc/set/observe` take
keyword labels, and every distinct label combination is its own series.

    reg = MetricsRegistry()
    falls = reg.counter("pallas.fallback_decisions")
    falls.inc(op="squash", variant="approx")
    reg.snapshot()
    # {"pallas.fallback_decisions": {"kind": "counter", "series":
    #    [{"labels": {"op": "squash", "variant": "approx"}, "value": 1}]}}

`METRICS` is the process-default registry (module singletons like the
pallas backend record there); objects that need isolated counts — a
fresh `ModelRegistry`, a `ServeMetrics` window — default to a private
registry instead, exactly matching the per-instance semantics their old
ad-hoc counters had.
"""
from __future__ import annotations

from collections.abc import Mapping

DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0,
                   float("inf"))


def _key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Instrument:
    kind = "?"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._series: dict = {}          # label tuple -> value

    def series(self) -> dict:
        """label tuple (sorted (k, v) pairs) -> current value."""
        return dict(self._series)

    def view(self, *label_names) -> "SeriesView":
        """A read-only Mapping over the series, keyed by the values of
        `label_names` (a single name maps to plain keys, several to
        tuples) — the shape old `collections.Counter` attributes had."""
        return SeriesView(self, label_names)


class Counter(_Instrument):
    kind = "counter"

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment "
                             f"{amount}")
        k = _key(labels)
        self._series[k] = self._series.get(k, 0) + amount

    def value(self, **labels):
        return self._series.get(_key(labels), 0)

    def total(self):
        """Sum over every labeled series."""
        return sum(self._series.values())


class Gauge(_Instrument):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_key(labels)] = value

    def value(self, **labels):
        return self._series.get(_key(labels), 0)


class Histogram(_Instrument):
    """Fixed-bucket histogram (per labeled series: count / sum / min /
    max / cumulative bucket counts).  Percentile-grade data stays with
    the callers that need it (e.g. ServeMetrics keeps raw latencies);
    this is the cheap always-on aggregate."""

    kind = "histogram"

    def __init__(self, name, help="", buckets=DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets))
        if not self.buckets or self.buckets[-1] != float("inf"):
            self.buckets = self.buckets + (float("inf"),)

    def observe(self, value: float, **labels) -> None:
        k = _key(labels)
        s = self._series.get(k)
        if s is None:
            s = self._series[k] = {"count": 0, "sum": 0.0,
                                   "min": float("inf"),
                                   "max": float("-inf"),
                                   "bucket_counts": [0] * len(self.buckets)}
        s["count"] += 1
        s["sum"] += value
        s["min"] = min(s["min"], value)
        s["max"] = max(s["max"], value)
        for i, b in enumerate(self.buckets):
            if value <= b:
                s["bucket_counts"][i] += 1
                break

    def count(self, **labels) -> int:
        s = self._series.get(_key(labels))
        return 0 if s is None else s["count"]

    def sum(self, **labels) -> float:
        s = self._series.get(_key(labels))
        return 0.0 if s is None else s["sum"]

    def percentile(self, p: float, **labels) -> float | None:
        """Bucket-resolution p-th percentile of one labeled series.

        Tiny samples are pinned, never interpolated: 0 observations ->
        None, 1 or 2 observations -> the exact max (any interpolation
        between two points is presentation noise, not signal).  With
        n >= 3 the estimate is the nearest-rank bucket upper bound,
        clamped to the observed max so the +inf bucket (and a sparse top
        bucket) can never report a value no observation reached."""
        s = self._series.get(_key(labels))
        if s is None or s["count"] == 0:
            return None
        if s["count"] < 3:
            return s["max"]
        rank = max(1, min(s["count"],
                          -(-int(p * s["count"]) // 100)))  # ceil, no float
        cum = 0
        for bound, n in zip(self.buckets, s["bucket_counts"]):
            cum += n
            if cum >= rank:
                return min(bound, s["max"])
        return s["max"]

    def summary(self, **labels) -> dict:
        """count/sum/min/max + pinned p50/p95/p99 of one series (the
        shape `snapshot()` embeds per histogram series)."""
        s = self._series.get(_key(labels))
        if s is None:
            return {"count": 0, "sum": 0.0, "min": None, "max": None,
                    "p50": None, "p95": None, "p99": None}
        return {"count": s["count"], "sum": s["sum"],
                "min": s["min"], "max": s["max"],
                "p50": self.percentile(50, **labels),
                "p95": self.percentile(95, **labels),
                "p99": self.percentile(99, **labels)}


class SeriesView(Mapping):
    """Counter-shaped read-only view over one instrument's series.

    Keys are label VALUES: with one label name plain values, with
    several a tuple in the given order — so
    `backend.fallbacks[("squash", "approx")]` keeps working after the
    underlying storage moved into the metrics registry."""

    def __init__(self, instrument: _Instrument, label_names: tuple):
        self._ins = instrument
        self._names = tuple(label_names)

    def _as_dict(self) -> dict:
        out = {}
        for k, v in self._ins.series().items():
            labels = dict(k)
            if len(self._names) == 1:
                out[labels.get(self._names[0])] = v
            else:
                out[tuple(labels.get(n) for n in self._names)] = v
        return out

    def __getitem__(self, key):
        return self._as_dict()[key]

    def __iter__(self):
        return iter(self._as_dict())

    def __len__(self):
        return len(self._as_dict())

    def __repr__(self):
        return f"SeriesView({self._ins.name}: {self._as_dict()!r})"


class MetricsRegistry:
    _KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}

    def __init__(self, name: str = "metrics"):
        self.name = name
        self._instruments: dict = {}

    # ------------------------------------------------------------------
    # registration (get-or-create; kind mismatches are loud)
    # ------------------------------------------------------------------
    def _register(self, cls, name, help, **kw):
        ins = self._instruments.get(name)
        if ins is not None:
            if not isinstance(ins, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {ins.kind}, "
                    f"not {cls.kind}")
            return ins
        ins = cls(name, help, **kw)
        self._instruments[name] = ins
        return ins

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> _Instrument:
        return self._instruments[name]

    def names(self) -> tuple:
        return tuple(sorted(self._instruments))

    # ------------------------------------------------------------------
    # the one face everything is read through
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """Every instrument's every series, as one JSON-safe dict."""
        out = {}
        for name in sorted(self._instruments):
            ins = self._instruments[name]
            series = []
            for k, v in sorted(ins.series().items()):
                value = _json_value(v)
                if isinstance(ins, Histogram):
                    labels = dict(k)
                    for p in (50, 95, 99):
                        q = ins.percentile(p, **labels)
                        value[f"p{p}"] = \
                            q if q is None or abs(q) != float("inf") \
                            else None
                series.append({"labels": dict(k), "value": value})
            entry = {"kind": ins.kind, "help": ins.help, "series": series}
            if isinstance(ins, Histogram):
                entry["buckets"] = [b if b != float("inf") else "inf"
                                    for b in ins.buckets]
            out[name] = entry
        return out

    def reset(self) -> None:
        for ins in self._instruments.values():
            ins._series = {}


def _json_value(v):
    if isinstance(v, dict):       # histogram series
        out = dict(v)
        for k in ("min", "max"):
            if k in out and out[k] in (float("inf"), float("-inf")):
                out[k] = None
        return out
    return v


# The process-default registry: module-level singletons (e.g. the shared
# pallas backend in nn.backend.BACKENDS) record here, so one snapshot at
# the end of a CLI run sees them all.
METRICS = MetricsRegistry("process")
