"""Unified observability layer (see README.md in this package).

Three faces, all optional at every call site and free when unused:

  * spans   — `obs.span("serve.wave", bucket=4)` context managers that
              record a hierarchical trace (Chrome trace-event export)
              through the serving engine, the QAT trainer, and the
              PTQ/export pipelines;
  * metrics — `MetricsRegistry` counters/gauges/histograms with labeled
              series and one `snapshot()` dict (the ad-hoc counters of
              earlier PRs are now views over these);
  * cost    — the static MCU cycle/latency model lives with the edge IR
              in `repro.edge.costmodel` (it reads EdgeProgram geometry),
              calibrated against the paper's Cortex-M7/GAP-8 tables.

A fourth face, `repro.obs.numerics`, probes numeric health of the
quantized stack (saturation, bound tightness, range utilization,
q7-vs-f32 SNR) under the same ambient/zero-cost contract.
"""
from repro.obs.metrics import (DEFAULT_BUCKETS, METRICS,  # noqa: F401
                               Counter, Gauge, Histogram, MetricsRegistry,
                               SeriesView)
from repro.obs.trace import (NULL_SPAN, Span, Tracer,  # noqa: F401
                             get_tracer, set_tracer, span, tracing)
from repro.obs.numerics import (NUMERICS_SCHEMA,  # noqa: F401
                                NumericsProbe, NumericsReport,
                                check_containment, get_probe, probing,
                                run_numerics, run_program_numerics,
                                set_probe, snr_rows)
