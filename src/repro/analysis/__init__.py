"""Static verification for the edge stack (see README.md here).

    from repro.analysis import check_program
    check_program(lower(qnet)).raise_if_failed()

Submodules: `ranges` (interval/overflow proofs), `plancheck` (Qm.n
shift algebra), `arenacheck` (arena aliasing), `repolint` (repo-rule
AST lint), `checker` (the one-call program verifier).  The public
names below resolve lazily so `python -m repro.analysis.repolint`
and `from repro.analysis import Diagnostic` never drag in the
jax-backed model stack.
"""
from repro.analysis.diagnostics import (CheckError,  # noqa: F401
                                        CheckResult, Diagnostic)

_LAZY = {
    "check_program": "repro.analysis.checker",
    "check_structure": "repro.analysis.checker",
    "check_ranges": "repro.analysis.ranges",
    "annotate_acc_bounds": "repro.analysis.ranges",
    "check_pipeline_plan": "repro.analysis.plancheck",
    "check_arena": "repro.analysis.arenacheck",
    "lint_paths": "repro.analysis.repolint",
}

__all__ = ["CheckError", "CheckResult", "Diagnostic", *_LAZY]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
