"""Statically verify exported `.capsbin` artifacts:

    PYTHONPATH=src python -m repro.analysis out/edge_tiny.capsbin [...]

Loads each artifact, runs the full checker (structure, plan algebra,
int32 range proofs, arena aliasing) and prints one result block per
file.  Exit 1 on any finding — CI points this at everything
`export_caps` produced.
"""
from __future__ import annotations

import sys


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: python -m repro.analysis <artifact.capsbin> [...]",
              file=sys.stderr)
        return 2
    from repro.analysis.checker import check_program
    from repro.edge.program import EdgeProgram

    failed = False
    for path in argv:
        result = check_program(EdgeProgram.load(path))
        print(result.format())
        failed = failed or not result.ok
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
