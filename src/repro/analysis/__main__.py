"""Statically verify exported `.capsbin` artifacts:

    PYTHONPATH=src python -m repro.analysis out/edge_tiny.capsbin [...]

Loads each artifact, runs the full checker (structure, plan algebra,
int32 range proofs, arena aliasing) and prints one result block per
file.  Exit 1 on any finding — CI points this at everything
`export_caps` produced.

`--profile` additionally prints the static MCU cycle/latency estimate
of each (passing or failing) artifact on every calibrated profile
(repro.edge.costmodel: cortex-m7 @ 480 MHz, gap8 @ 170 MHz) — the
paper's latency tables, derived from the artifact alone.
"""
from __future__ import annotations

import argparse


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="statically verify exported .capsbin artifacts")
    ap.add_argument("paths", nargs="+", metavar="artifact.capsbin",
                    help="exported artifacts to check")
    ap.add_argument("--profile", action="store_true",
                    help="also print the static per-op cycle/latency "
                    "estimate on every calibrated MCU profile")
    args = ap.parse_args(argv)

    from repro.analysis.checker import check_program
    from repro.edge.program import EdgeProgram

    failed = False
    for path in args.paths:
        program = EdgeProgram.load(path)
        result = check_program(program)
        print(result.format())
        failed = failed or not result.ok
        if args.profile:
            from repro.edge import format_estimates
            print(format_estimates(program))
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
