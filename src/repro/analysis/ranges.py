"""Interval abstract interpretation over the q7 dataflow.

Propagates worst-case int8 value intervals through the EdgeProgram
schedule and proves, per op, that

  * no int32 accumulator can wrap — conv/uhat/s/agreement accumulations
    are bounded by sum(|w|) * max|x| computed from the ACTUAL weight
    blobs (not a generic 127*count bound), plus the shift-aligned bias
    and, for "nearest" rounding, the half-LSB add `1 << (shift-1)`;
  * every power-of-two shift is in-bounds for int32 arithmetic —
    right shifts in [0, 31], left shifts (negative amounts) both small
    enough and proven not to overflow the shifted bound;
  * the shift-only softmax/squash internals stay in int32 — the softmax
    normalizer sum `n * 2^20`, the squash denominator/ratio chain with
    its guard bits, and the logit format feeding `right_shift`.

Everything is exact integer arithmetic on Python ints (no float, no
wrap), so the derived conv accumulator bound doubles as the `acc_bound`
attr `edge.lower` records and the EdgeVM asserts: `analyze()` returns
(bounds, diagnostics) and `annotate_acc_bounds()` stamps the bounds
onto a program.  The module deliberately imports nothing from
`repro.edge` — it walks any program-shaped object — so `lower()` can
call it without an import cycle.

The "precise" softmax variant is float by design (see nn.variants);
its integer-softmax checks are skipped, as for unregistered variant
names (those are flagged by `checker.check_structure`).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.analysis.diagnostics import Diagnostic
from repro.nn.variants import PLAN_FIELDS, REGISTRY

INT32_MAX = 2 ** 31 - 1
_GUARD_BITS = 10                    # quant.int8_ops.SQUASH_GUARD_BITS
_SOFTMAX_UNIT_BITS = 20             # max softmax term is 1 << 20
_INT8 = (-128, 127)


def _xmax(iv) -> int:
    """Worst-case magnitude of an int8 interval AFTER int32 widening
    (-128 contributes 128)."""
    return max(abs(iv[0]), abs(iv[1]))


def _variant(attrs: dict, kind: str):
    """(name, registered?) of an op's variant reference, with the same
    defaulting rule as REGISTRY.from_attrs but no raise — the checker
    reports unregistered names as a diagnostic, not an exception."""
    name = attrs.get(PLAN_FIELDS[kind], REGISTRY.default(kind))
    return name, REGISTRY.is_registered(kind, name)


def _check_requant(diags, bound: int, shift: int, rounding: str, what: str,
                   *, op_index, op_name, tensor, **detail) -> None:
    """One requantization point: an int32 value with |x| <= bound goes
    through `rshift_sat8(x, shift)`.  Emits shift-domain and overflow
    diagnostics (bound is exact Python-int arithmetic, so no wrap here
    either)."""
    where = dict(op_index=op_index, op_name=op_name, tensor=tensor)
    if shift > 31 or shift < -31:
        diags.append(Diagnostic.of(
            "ranges.shift-range",
            f"{what}: shift amount {shift} outside int32 domain [-31, 31]",
            shift=shift, **where, **detail))
        return
    if shift >= 0:
        half = 1 << (shift - 1) if rounding == "nearest" and shift > 0 else 0
        total = bound + half
        if total > INT32_MAX:
            diags.append(Diagnostic.of(
                "ranges.acc-overflow",
                f"{what}: |accumulator| can reach {bound}"
                + (f" (+{half} rounding half-add)" if half else "")
                + f" > int32 max {INT32_MAX}",
                bound=total, shift=shift, **where, **detail))
    elif bound << -shift > INT32_MAX:
        diags.append(Diagnostic.of(
            "ranges.shift-overflow",
            f"{what}: left shift by {-shift} overflows int32 "
            f"(bound {bound} << {-shift} > {INT32_MAX})",
            bound=bound, shift=shift, **where, **detail))


# ---------------------------------------------------------------------------
# CONV_Q7 (also the conv stage of PRIMARY_CAPS_Q7)
# ---------------------------------------------------------------------------
def conv_acc_bounds(op, x_iv) -> list:
    """Per-output-channel worst-case |int32 conv accumulator| including
    the shift-aligned bias, before requantization — valid for ANY
    accumulation order (sum of |w|*max|x|), which is what an MCU kernel
    needs.  Exact Python ints from the actual weight blobs."""
    a = op.attrs
    wsum = np.abs(op.weights["w"].astype(np.int64)).sum(axis=(0, 1, 2))
    bias = op.weights["b"].astype(np.int64)
    xmax = _xmax(x_iv)
    per_ch = a.get("bias_shift_per_channel")
    bounds = []
    for c in range(len(bias)):
        bs = per_ch[c] if per_ch else a["bias_shift"]
        b = int(bias[c])
        b_aligned = b << bs if bs >= 0 else b >> -bs
        bounds.append(int(wsum[c]) * xmax + abs(b_aligned))
    return bounds


def _analyze_conv(op, op_index: int, x_iv, rounding: str, diags):
    """-> (out_interval, acc_bound attr value).  Checks bias alignment,
    accumulator fit and the output requantization shifts."""
    a = op.attrs
    where = dict(op_index=op_index, op_name=op.name, tensor=op.output)
    bias = op.weights["b"].astype(np.int64)
    n_ch = len(bias)
    b_shifts = a.get("bias_shift_per_channel") or [a["bias_shift"]] * n_ch
    out_shifts = a.get("out_shift_per_channel") or [a["out_shift"]] * n_ch

    for c in range(n_ch):
        bs = b_shifts[c]
        if bs > 31 or bs < -31:
            diags.append(Diagnostic.of(
                "ranges.shift-range",
                f"bias alignment: shift amount {bs} outside int32 "
                f"domain [-31, 31]", shift=bs, channel=c, **where))
            break
        if bs > 0 and abs(int(bias[c])) << bs > INT32_MAX:
            diags.append(Diagnostic.of(
                "ranges.shift-overflow",
                f"bias alignment: |b[{c}]|={abs(int(bias[c]))} << {bs} "
                f"overflows int32", shift=bs, channel=c, **where))
            break

    bounds = conv_acc_bounds(op, x_iv)
    for c, (bound, sh) in enumerate(zip(bounds, out_shifts)):
        before = len(diags)
        _check_requant(diags, bound, sh, rounding, "conv accumulator",
                       channel=c, **where)
        if len(diags) > before:     # one finding per op, not per channel
            break

    out_iv = (0, 127) if a.get("relu") else _INT8
    return out_iv, max(bounds)


# ---------------------------------------------------------------------------
# squash / softmax internals (shift-only integer variants)
# ---------------------------------------------------------------------------
def _check_squash(diags, in_frac: int, out_frac: int, dim: int, attrs: dict,
                  what: str, **where) -> None:
    """Integer squash (nn.variants np_q7 semantics): denominator
    `(1 << in_frac) + (Q >> in_frac)`, numerator `S << (out_frac -
    in_frac + GUARD)`, then `ratio * s >> GUARD`.  Bounds every stage.
    Skipped for unregistered squash names (flagged structurally)."""
    name, known = _variant(attrs, "squash")
    if not known:
        return
    if in_frac < 0 or in_frac > 31:
        diags.append(Diagnostic.of(
            "ranges.squash-frac-range",
            f"{what}: squash in_frac {in_frac} outside [0, 31] "
            f"(denominator needs `1 << in_frac` and `Q >> in_frac`)",
            in_frac=in_frac, **where))
        return
    # worst-case (norm, norm^2): exact uses the L2 pair, approx the
    # L-inf pair — the L2 pair dominates both
    q_max = dim * 127 * 127
    if q_max > INT32_MAX:
        diags.append(Diagnostic.of(
            "ranges.squash-overflow",
            f"{what}: squared-norm sum can reach {q_max} > int32 max",
            bound=q_max, dim=dim, **where))
        return
    s_max = math.isqrt(q_max)
    shift = out_frac - in_frac + _GUARD_BITS
    if shift > 31 or shift < -31:
        diags.append(Diagnostic.of(
            "ranges.shift-range",
            f"{what}: squash numerator shift {shift} outside [-31, 31]",
            shift=shift, **where))
        return
    num_max = s_max << shift if shift >= 0 else s_max >> -shift
    if num_max > INT32_MAX:
        diags.append(Diagnostic.of(
            "ranges.shift-overflow",
            f"{what}: squash numerator {s_max} << {shift} overflows int32",
            bound=s_max, shift=shift, **where))
        return
    ratio_max = num_max // (1 << in_frac)       # denominator >= 1 << in_frac
    if ratio_max * 127 > INT32_MAX:
        diags.append(Diagnostic.of(
            "ranges.squash-overflow",
            f"{what}: squash ratio*s product can reach {ratio_max * 127} "
            f"> int32 max", bound=ratio_max * 127, **where))


def _check_softmax(diags, attrs: dict, num_out: int, **where) -> None:
    """Shift-softmax internals (q7 / approx families): the normalizer is
    a sum of up to `num_out` terms of `1 << 20`, and the logits are
    right-shifted by `logit_frac`.  "precise" is float by design and
    unregistered names are flagged structurally — both skipped."""
    name, known = _variant(attrs, "softmax")
    if not known or name == "precise":
        return
    lf = attrs["logit_frac"]
    if lf < 0 or lf > 31:
        diags.append(Diagnostic.of(
            "ranges.logit-frac-range",
            f"softmax: logit_frac {lf} outside [0, 31] (logits are "
            f"right-shifted by it)", logit_frac=lf, **where))
    tot_max = num_out << _SOFTMAX_UNIT_BITS
    if tot_max > INT32_MAX:
        diags.append(Diagnostic.of(
            "ranges.softmax-overflow",
            f"softmax: normalizer sum can reach {num_out} * "
            f"2^{_SOFTMAX_UNIT_BITS} = {tot_max} > int32 max",
            bound=tot_max, num_out=num_out, **where))


# ---------------------------------------------------------------------------
# CAPS_ROUTING_Q7
# ---------------------------------------------------------------------------
def _analyze_routing(op, op_index: int, x_iv, rounding: str, diags):
    a = op.attrs
    where = dict(op_index=op_index, op_name=op.name, tensor=op.output)

    # u_hat = W @ u: per (j, i) capsule pair, sum over in_dim
    wsum = np.abs(op.weights["W"].astype(np.int64)).sum(axis=3)
    per_out = a.get("uhat_shift_per_out")
    if per_out:
        # per-output-capsule shifts: bound each capsule j by ITS rows of
        # W, against its own shift (one finding per op, like conv)
        for j, sh in enumerate(per_out):
            bound_j = int(wsum[j].max()) * _xmax(x_iv)
            before = len(diags)
            _check_requant(diags, bound_j, sh, rounding,
                           "u_hat accumulator", channel=j, **where)
            if len(diags) > before:
                break
    else:
        uhat_bound = int(wsum.max()) * _xmax(x_iv)
        _check_requant(diags, uhat_bound, a["uhat_shift"], rounding,
                       "u_hat accumulator", **where)
    uhat_max = 128                  # |sat8| after the u_hat requantization

    _check_softmax(diags, a, a["num_out"], **where)

    out_frac = a["squash_out_frac"]
    for r in range(a["routings"]):
        # s = sum_i c * u_hat, couplings in [0, 127]
        s_bound = a["num_in"] * 127 * uhat_max
        _check_requant(diags, s_bound, a["caps_out_shifts"][r], rounding,
                       "routing s accumulator", iteration=r, **where)
        _check_squash(diags, a["caps_out_fracs"][r], out_frac,
                      a["out_dim"], a, "routing squash",
                      iteration=r, **where)
        if r < a["routings"] - 1:
            # agreement = sum_o u_hat * v; the VM applies
            # agree_shifts[r] + (squash_out_frac - 7) (can go negative)
            agr_bound = a["out_dim"] * uhat_max * 128
            eff = a["agree_shifts"][r] + out_frac - 7
            _check_requant(diags, agr_bound, eff, rounding,
                           "agreement accumulator", iteration=r, **where)
    return _INT8


# ---------------------------------------------------------------------------
# program walk
# ---------------------------------------------------------------------------
def analyze(program):
    """-> (acc_bounds, diagnostics).

    acc_bounds maps schedule index -> the statically-derived worst-case
    |int32 conv accumulator| (incl. aligned bias) for CONV_Q7 /
    PRIMARY_CAPS_Q7 ops — exactly the `acc_bound` attr value.  Assumes
    a structurally sound program (run checker.check_structure first)."""
    iv = {0: _INT8}
    diags: list = []
    bounds: dict = {}
    for i, op in enumerate(program.ops):
        x_iv = iv[op.inputs[0]]
        if op.kind == "CONV_Q7":
            out_iv, bounds[i] = _analyze_conv(op, i, x_iv,
                                              program.rounding, diags)
        elif op.kind == "PRIMARY_CAPS_Q7":
            out_iv, bounds[i] = _analyze_conv(op, i, x_iv,
                                              program.rounding, diags)
            _check_squash(diags, op.attrs["squash_in_frac"],
                          op.attrs["squash_out_frac"], op.attrs["dim"],
                          op.attrs, "primary-caps squash",
                          op_index=i, op_name=op.name, tensor=op.output)
            out_iv = _INT8          # squash output, not the conv's
        elif op.kind == "CAPS_ROUTING_Q7":
            out_iv = _analyze_routing(op, i, x_iv, program.rounding, diags)
        else:                       # unreachable on a structure-checked
            continue                # program; stay total regardless
        iv[op.output] = out_iv
    return bounds, diags


def requant_bounds(program):
    """-> (sites, out_ivs): the static bound for every requantization
    point the EdgeVM has, keyed exactly like the runtime numerics probe
    labels them (`repro.obs.numerics`), so observed and proven can be
    joined row-for-row.

    `sites` maps (op_index, site) -> worst-case |int32 accumulator|
    entering that requantization (pre-half-add, like the probe's
    `acc_peak`): conv/primary-caps `"out"` is `max(conv_acc_bounds)`
    (== the `acc_bound` attr), routing has `"uhat"`, per-iteration
    `"s[r]"`, and `"agree[r]"` for all but the last iteration.
    `out_ivs` maps op_index -> the op's static int8 output interval.
    Walks the same interval chain as `analyze()`."""
    iv = {0: _INT8}
    sites: dict = {}
    out_ivs: dict = {}
    for i, op in enumerate(program.ops):
        x_iv = iv[op.inputs[0]]
        a = op.attrs
        if op.kind in ("CONV_Q7", "PRIMARY_CAPS_Q7"):
            sites[(i, "out")] = max(conv_acc_bounds(op, x_iv))
            out_iv = (0, 127) if op.kind == "CONV_Q7" and a.get("relu") \
                else _INT8
        elif op.kind == "CAPS_ROUTING_Q7":
            wsum = np.abs(op.weights["W"].astype(np.int64)).sum(axis=3)
            sites[(i, "uhat")] = int(wsum.max()) * _xmax(x_iv)
            uhat_max = 128          # |sat8| after the u_hat requant
            for r in range(a["routings"]):
                sites[(i, f"s[{r}]")] = a["num_in"] * 127 * uhat_max
                if r < a["routings"] - 1:
                    sites[(i, f"agree[{r}]")] = \
                        a["out_dim"] * uhat_max * 128
            out_iv = _INT8
        else:
            continue
        out_ivs[i] = out_iv
        iv[op.output] = out_iv
    return sites, out_ivs


def check_ranges(program) -> list:
    """All interval/overflow/shift diagnostics for a program, plus a
    cross-check that any recorded `acc_bound` attr equals this module's
    own derivation (lower() and the VM must agree with the checker)."""
    bounds, diags = analyze(program)
    for i, op in enumerate(program.ops):
        recorded = op.attrs.get("acc_bound")
        if recorded is not None and i in bounds and recorded != bounds[i]:
            diags.append(Diagnostic.of(
                "ranges.acc-bound-mismatch",
                f"recorded acc_bound {recorded} != statically derived "
                f"{bounds[i]}", op_index=i, op_name=op.name,
                tensor=op.output, recorded=recorded, derived=bounds[i]))
    return diags


def annotate_acc_bounds(program):
    """Return the program with each conv-accumulating op's statically
    derived bound stamped as an `acc_bound` attr (the EdgeVM asserts it
    at run time, so VM and checker can never disagree silently)."""
    bounds, _ = analyze(program)
    ops = []
    for i, op in enumerate(program.ops):
        if i in bounds:
            attrs = dict(op.attrs)
            attrs["acc_bound"] = int(bounds[i])
            op = dataclasses.replace(op, attrs=attrs)
        ops.append(op)
    return dataclasses.replace(program, ops=tuple(ops))
