"""`check_program` — the one-call static verifier for an EdgeProgram.

Three stages, each reusing the single statement of its rules:

  1. structure (this module): tensor table indexed by tid, positive
     shapes, dataflow well-formedness (defined inputs, single writer,
     tid 0 read-only), required attrs per op kind, weight blob dtypes
     and shapes consistent with the attr geometry, activation shapes
     consistent with the conv/caps geometry chain, tensor formats
     matching the op's declared output format;
  2. plan invariants (plancheck, on the flattened attrs) + the
     program-level in_frac threading;
  3. value ranges (ranges) and arena aliasing (arenacheck, against a
     supplied or freshly derived ArenaPlan).

Stages 2-3 assume a sound structure, so a structural finding
short-circuits the pass (the diagnostics already name the defect).
Returns a `CheckResult`; `raise_if_failed()` upgrades findings to a
`CheckError` (caught as AssertionError by the export CLI, as
ValueError by importer callers).
"""
from __future__ import annotations

from repro.analysis import arenacheck, plancheck, ranges
from repro.analysis.diagnostics import CheckResult, Diagnostic

_ROUNDINGS = ("floor", "nearest")

_CONV_ATTRS = ("kernel", "stride", "in_ch", "out_ch", "relu", "in_frac",
               "w_frac", "b_frac", "out_frac", "out_shift", "bias_shift")
_PCAP_ATTRS = _CONV_ATTRS + ("caps", "dim", "squash_in_frac",
                             "squash_out_frac")
_ROUTING_ATTRS = ("num_out", "num_in", "out_dim", "in_dim", "routings",
                  "in_frac", "W_frac", "uhat_frac", "uhat_shift",
                  "logit_frac", "caps_out_shifts", "caps_out_fracs",
                  "agree_shifts", "squash_out_frac")
_REQUIRED = {"CONV_Q7": _CONV_ATTRS, "PRIMARY_CAPS_Q7": _PCAP_ATTRS,
             "CAPS_ROUTING_Q7": _ROUTING_ATTRS}
_WEIGHTS = {"CONV_Q7": ("w", "b"), "PRIMARY_CAPS_Q7": ("w", "b"),
            "CAPS_ROUTING_Q7": ("W",)}


def _blob(diags, op, i, wname, shape, what) -> bool:
    """One weight blob: present, int8, exactly the attr-implied shape.
    Returns False when follow-up checks can't use the blob."""
    w = op.weights.get(wname)
    if w is None:
        diags.append(Diagnostic.of(
            "ir.missing-weight", f"op has no {wname!r} blob ({what})",
            op_index=i, op_name=op.name))
        return False
    if str(w.dtype) != "int8":
        diags.append(Diagnostic.of(
            "ir.weight-dtype",
            f"{wname} blob is {w.dtype}, not int8", op_index=i,
            op_name=op.name, blob=wname))
        return False
    if tuple(w.shape) != shape:
        diags.append(Diagnostic.of(
            "ir.weight-shape-mismatch",
            f"{wname} blob shape {tuple(w.shape)} != {shape} implied by "
            f"the attrs ({what})", op_index=i, op_name=op.name,
            blob=wname))
        return False
    return True


def _conv_geometry(diags, program, op, i) -> None:
    a = op.attrs
    _blob(diags, op, i, "w",
          (a["kernel"], a["kernel"], a["in_ch"], a["out_ch"]),
          "k x k x in_ch x out_ch")
    _blob(diags, op, i, "b", (a["out_ch"],), "out_ch")
    x = program.tensor(op.inputs[0])
    where = dict(op_index=i, op_name=op.name)
    if len(x.shape) != 3 or x.shape[2] != a["in_ch"]:
        diags.append(Diagnostic.of(
            "ir.geometry-mismatch",
            f"input tensor shape {x.shape} is not (H, W, "
            f"in_ch={a['in_ch']})", tensor=x.tid, **where))
        return
    if a["stride"] < 1 or a["kernel"] < 1 \
            or x.shape[0] < a["kernel"] or x.shape[1] < a["kernel"]:
        diags.append(Diagnostic.of(
            "ir.geometry-mismatch",
            f"kernel {a['kernel']} / stride {a['stride']} does not fit "
            f"the {x.shape[0]}x{x.shape[1]} input", tensor=x.tid,
            **where))
        return
    ho = (x.shape[0] - a["kernel"]) // a["stride"] + 1
    wo = (x.shape[1] - a["kernel"]) // a["stride"] + 1
    out = program.tensor(op.output)
    if op.kind == "CONV_Q7":
        want, frac = (ho, wo, a["out_ch"]), a["out_frac"]
    else:
        if a["caps"] * a["dim"] != a["out_ch"]:
            diags.append(Diagnostic.of(
                "ir.geometry-mismatch",
                f"caps {a['caps']} * dim {a['dim']} != out_ch "
                f"{a['out_ch']}", **where))
            return
        want, frac = (ho * wo * a["caps"], a["dim"]), a["squash_out_frac"]
    if tuple(out.shape) != want:
        diags.append(Diagnostic.of(
            "ir.geometry-mismatch",
            f"output tensor shape {out.shape} != {want} implied by the "
            f"schedule geometry", tensor=out.tid, **where))
    elif out.frac != frac:
        diags.append(Diagnostic.of(
            "ir.frac-mismatch",
            f"output tensor frac {out.frac} != the op's declared output "
            f"format {frac}", tensor=out.tid, **where))


def _routing_geometry(diags, program, op, i) -> None:
    a = op.attrs
    where = dict(op_index=i, op_name=op.name)
    _blob(diags, op, i, "W",
          (a["num_out"], a["num_in"], a["out_dim"], a["in_dim"]),
          "num_out x num_in x out_dim x in_dim")
    x = program.tensor(op.inputs[0])
    if tuple(x.shape) != (a["num_in"], a["in_dim"]):
        diags.append(Diagnostic.of(
            "ir.geometry-mismatch",
            f"input tensor shape {x.shape} != (num_in, in_dim) = "
            f"({a['num_in']}, {a['in_dim']})", tensor=x.tid, **where))
    out = program.tensor(op.output)
    if tuple(out.shape) != (a["num_out"], a["out_dim"]):
        diags.append(Diagnostic.of(
            "ir.geometry-mismatch",
            f"output tensor shape {out.shape} != (num_out, out_dim) = "
            f"({a['num_out']}, {a['out_dim']})", tensor=out.tid, **where))
    elif out.frac != a["squash_out_frac"]:
        diags.append(Diagnostic.of(
            "ir.frac-mismatch",
            f"output tensor frac {out.frac} != squash_out_frac "
            f"{a['squash_out_frac']}", tensor=out.tid, **where))
    if a["routings"] < 1:
        diags.append(Diagnostic.of(
            "ir.geometry-mismatch", f"routings {a['routings']} < 1",
            **where))


def check_structure(program) -> list:
    """Stage-1 diagnostics (see module docstring)."""
    diags: list = []
    if program.rounding not in _ROUNDINGS:
        diags.append(Diagnostic.of(
            "ir.bad-rounding",
            f"rounding {program.rounding!r} not in {_ROUNDINGS}"))
    for idx, t in enumerate(program.tensors):
        if t.tid != idx:
            diags.append(Diagnostic.of(
                "ir.tensor-index",
                f"tensor table position {idx} holds tid {t.tid}",
                tensor=t.tid))
        if not t.shape or any(int(s) < 1 for s in t.shape):
            diags.append(Diagnostic.of(
                "ir.bad-shape", f"tensor shape {t.shape} has "
                f"non-positive dims", tensor=t.tid))
    if diags:
        return diags                # tid table broken: nothing below holds
    if program.input_frac != program.tensors[0].frac:
        diags.append(Diagnostic.of(
            "ir.frac-mismatch",
            f"program input_frac {program.input_frac} != input tensor "
            f"frac {program.tensors[0].frac}", tensor=0))
    if not program.ops:
        diags.append(Diagnostic.of("ir.empty-schedule",
                                   "program has no ops"))
        return diags

    written = {0}
    for i, op in enumerate(program.ops):
        where = dict(op_index=i, op_name=op.name)
        if len(op.inputs) != 1:
            diags.append(Diagnostic.of(
                "ir.bad-arity",
                f"{op.kind} takes 1 input tensor, got {len(op.inputs)}",
                **where))
            return diags
        bad_ref = [t for t in (*op.inputs, op.output)
                   if not 0 <= t < len(program.tensors)]
        if bad_ref:
            diags.append(Diagnostic.of(
                "ir.bad-tensor-ref",
                f"op references unknown tensor ids {bad_ref}", **where))
            return diags
        for t in op.inputs:
            if t not in written:
                diags.append(Diagnostic.of(
                    "ir.undefined-input",
                    f"input tensor {t} is not produced by any earlier "
                    f"op (nor the program input)", tensor=t, **where))
        if op.output in written:
            diags.append(Diagnostic.of(
                "ir.output-clobber",
                f"output tensor {op.output} already has a writer "
                f"(the schedule is single-assignment)", tensor=op.output,
                **where))
        written.add(op.output)

        missing = [k for k in _REQUIRED[op.kind] if k not in op.attrs]
        if missing:
            diags.append(Diagnostic.of(
                "ir.missing-attr",
                f"{op.kind} attrs missing {missing}", **where))
            continue                # geometry checks need these attrs
        if op.kind == "CAPS_ROUTING_Q7":
            _routing_geometry(diags, program, op, i)
        else:
            _conv_geometry(diags, program, op, i)
    return diags


def check_program(program, *, arena=None) -> CheckResult:
    """Run every static check on one program; see the module docstring
    for staging.  `arena`: verify a specific ArenaPlan (e.g. the one
    being exported) instead of deriving a fresh one."""
    res = CheckResult(program.name)
    res.extend(check_structure(program))
    if not res.ok:
        return res

    for i, op in enumerate(program.ops):
        a = op.attrs
        where = dict(op_index=i, op_name=op.name)
        if op.kind == "CAPS_ROUTING_Q7":
            res.extend(plancheck.check_routing_fields(a, **where))
        else:
            res.extend(plancheck.check_conv_fields(
                a, out_ch=a["out_ch"], **where))
            if op.kind == "PRIMARY_CAPS_Q7":
                res.extend(plancheck.check_squash_fields(
                    a, conv_out_frac=a["out_frac"], **where))
        x = program.tensor(op.inputs[0])
        if a["in_frac"] != x.frac:
            res.add(Diagnostic.of(
                "plan.frac-thread-mismatch",
                f"op in_frac {a['in_frac']} != its input tensor's "
                f"format {x.frac}", tensor=x.tid, **where))

    res.extend(ranges.check_ranges(program))

    if arena is None:
        from repro.edge.arena import plan_arena
        arena = plan_arena(program)
    res.extend(arenacheck.check_arena(program, arena))
    return res
