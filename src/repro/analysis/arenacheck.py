"""Independent verification of an ArenaPlan against its program.

`edge.arena.plan_arena` is the producer; this module re-derives tensor
liveness straight from the op schedule (its own walk, not
`arena.lifetimes`) and proves the plan's offsets are safe:

  * no two tensors whose live ranges intersect overlap in
    [offset, offset + size);
  * tid 0 (the caller's input buffer) is never given an arena slot,
    and every other tensor has exactly one;
  * every placement fits inside `arena_bytes`;
  * the shared scratch region covers the worst op's transient needs
    (im2col double buffer / resident u_hat — formulas restated here,
    not imported) and its byte count is 2-byte aligned, since the
    emitted C declares it as a q15 array.

A clean result is a proof about the PLAN, independent of the greedy
placement heuristic that produced it — a future planner swap is
covered by construction.
"""
from __future__ import annotations

from repro.analysis.diagnostics import Diagnostic


def derive_lifetimes(program) -> dict:
    """tid -> (first_step, last_step), re-derived from the schedule: a
    tensor is live from the step defining it (step 0 for the program
    input) through its last consumer; the final output outlives the
    schedule (the caller reads it)."""
    life = {0: [0, 0]}
    for i, op in enumerate(program.ops):
        life[op.output] = [i, i]
        for tid in op.inputs:
            life[tid][1] = max(life[tid][1], i)
    life[program.ops[-1].output][1] = len(program.ops)
    return {tid: tuple(v) for tid, v in life.items()}


def _scratch_needed(op) -> int:
    """Worst-case transient bytes of one kernel call — the same model
    the C runtime's shared scratch must satisfy, restated independently
    of edge.arena: conv/primary-caps use a q15 im2col double buffer
    (2 * 2 * k * k * in_ch); routing keeps u_hat resident (J*I*O int8)
    plus logit/coupling planes (2 * J*I) and the pre-squash s (J*O)."""
    a = op.attrs
    if op.kind in ("CONV_Q7", "PRIMARY_CAPS_Q7"):
        return 2 * 2 * a["kernel"] * a["kernel"] * a["in_ch"]
    if op.kind == "CAPS_ROUTING_Q7":
        j, i, o = a["num_out"], a["num_in"], a["out_dim"]
        return j * i * o + 2 * j * i + j * o
    return 0


def check_arena(program, plan) -> list:
    """All aliasing/coverage diagnostics for one (program, ArenaPlan)
    pair.  `plan` needs `offsets`, `lifetimes`, `arena_bytes` and
    `scratch_bytes` — the edge.arena.ArenaPlan shape."""
    diags: list = []
    life = derive_lifetimes(program)
    sizes = {tid: program.tensor(tid).nbytes for tid in life}

    if plan.lifetimes != life:
        diags.append(Diagnostic.of(
            "arena.lifetime-mismatch",
            f"plan lifetimes {plan.lifetimes} != liveness re-derived "
            f"from the schedule {life}"))
    if 0 in plan.offsets:
        diags.append(Diagnostic.of(
            "arena.input-allocated",
            "tid 0 is the caller's input buffer and must never get an "
            "arena offset", tensor=0))
    for tid in sorted(life):
        if tid != 0 and tid not in plan.offsets:
            diags.append(Diagnostic.of(
                "arena.missing-offset",
                "live tensor has no arena placement", tensor=tid))

    placed = sorted((tid, off) for tid, off in plan.offsets.items()
                    if tid in life and tid != 0)
    for tid, off in placed:
        if off < 0 or off + sizes[tid] > plan.arena_bytes:
            diags.append(Diagnostic.of(
                "arena.out-of-bounds",
                f"placement [{off}, {off + sizes[tid]}) outside the "
                f"{plan.arena_bytes}-byte arena", tensor=tid,
                offset=off, size=sizes[tid]))
    for i, (ta, off_a) in enumerate(placed):
        for tb, off_b in placed[i + 1:]:
            (sa, ea), (sb, eb) = life[ta], life[tb]
            if ea < sb or eb < sa:                  # never live together
                continue
            if off_a + sizes[ta] <= off_b or off_b + sizes[tb] <= off_a:
                continue                            # disjoint placements
            diags.append(Diagnostic.of(
                "arena.overlap",
                f"tensors {ta} and {tb} are live together (steps "
                f"{max(sa, sb)}..{min(ea, eb)}) but overlap in the "
                f"arena ([{off_a}, {off_a + sizes[ta]}) vs "
                f"[{off_b}, {off_b + sizes[tb]}))",
                tensor=ta, other=tb))

    need = max((_scratch_needed(op) for op in program.ops), default=0)
    if plan.scratch_bytes < need:
        worst = max(range(len(program.ops)),
                    key=lambda i: _scratch_needed(program.ops[i]))
        diags.append(Diagnostic.of(
            "arena.scratch-undersized",
            f"shared scratch {plan.scratch_bytes}B < the worst op's "
            f"{need}B transient need", op_index=worst,
            op_name=program.ops[worst].name, needed=need,
            scratch=plan.scratch_bytes))
    if plan.scratch_bytes % 2:
        diags.append(Diagnostic.of(
            "arena.scratch-unaligned",
            f"scratch region is {plan.scratch_bytes}B — must be 2-byte "
            f"aligned (the C artifact declares it as a q15 array)",
            scratch=plan.scratch_bytes))
    return diags
