"""Structured findings for the static verifier (repro.analysis).

Every check in this package reports problems as `Diagnostic` records —
machine-readable (check id, op index, op name, tensor id, numeric
detail) so the mutation-corpus tests can pin WHICH defect was found
WHERE, and printable so a human reading `export_caps` output sees one
line per finding instead of a bit-mismatch at verify time.

`CheckResult` aggregates the diagnostics of one subject (a program, a
plan, an arena); `raise_if_failed()` turns a non-clean result into a
`CheckError`.  `CheckError` subclasses BOTH `AssertionError` (so the
CLIs' existing "verification failed -> exit 1" handlers catch it) and
`ValueError` (so importer callers that treat a bad `.capsbin` as a
malformed-artifact error keep working).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: which check fired, where, and the offending values.

    `check` ids are dotted `<module>.<rule>` slugs (e.g.
    "ranges.acc-overflow", "plan.out-shift-mismatch", "arena.overlap") —
    stable strings tests and tooling match on.
    """
    check: str
    message: str
    op_index: int | None = None     # schedule position, when op-scoped
    op_name: str | None = None      # e.g. "conv0", "caps"
    tensor: int | None = None       # offending tensor id, when known
    detail: tuple = ()              # sorted (key, value) pairs

    @classmethod
    def of(cls, check: str, message: str, *, op_index=None, op_name=None,
           tensor=None, **detail) -> "Diagnostic":
        return cls(check=check, message=message, op_index=op_index,
                   op_name=op_name, tensor=tensor,
                   detail=tuple(sorted(detail.items())))

    def __str__(self) -> str:
        where = []
        if self.op_index is not None:
            where.append(f"op[{self.op_index}]")
        if self.op_name:
            where.append(self.op_name)
        if self.tensor is not None:
            where.append(f"tid={self.tensor}")
        loc = " ".join(where)
        extra = "".join(f" {k}={v}" for k, v in self.detail)
        return f"{self.check}: {loc + ': ' if loc else ''}" \
               f"{self.message}{extra}"


@dataclasses.dataclass
class CheckResult:
    """All diagnostics one verification pass produced for `subject`."""
    subject: str
    diagnostics: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.diagnostics

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def extend(self, diags) -> None:
        self.diagnostics.extend(diags)

    def by_check(self, check: str) -> list:
        """The findings of one rule (tests pin op/tensor through this)."""
        return [d for d in self.diagnostics if d.check == check]

    def format(self) -> str:
        if self.ok:
            return f"[{self.subject}] static checks clean"
        lines = [f"[{self.subject}] {len(self.diagnostics)} static "
                 f"finding(s):"]
        lines += [f"  {d}" for d in self.diagnostics]
        return "\n".join(lines)

    def raise_if_failed(self) -> "CheckResult":
        if not self.ok:
            raise CheckError(self)
        return self


class CheckError(AssertionError, ValueError):
    """A static check failed.  Carries the full `CheckResult`."""

    def __init__(self, result: CheckResult):
        self.result = result
        super().__init__(result.format())
