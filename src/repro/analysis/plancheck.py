"""PipelinePlan invariant linter.

The Qm.n algebra every shift in a plan must satisfy (paper Alg. 6,
derived in quant.qformat and nn.layers):

  conv      out_shift     == in_frac + w_frac - out_frac
            bias_shift    == in_frac + w_frac - b_frac
            (and per output channel with the per-channel tables)
  routing   uhat_shift    == in_frac + W_frac - uhat_frac
            caps_out_shifts[r] == uhat_frac + 7 - caps_out_fracs[r]
            agree_shifts[r]    == uhat_frac + 7 - logit_frac
            len(agree_shifts)  == routings - 1
  chaining  each layer's in_frac == previous layer's out_frac

All checks work on plain field dicts, so the SAME functions lint a
typed plan (`check_pipeline_plan`, also reachable as
`PipelinePlan.check()`) and an EdgeOp's flattened attrs (the program
checker reuses them) — there is exactly one statement of each
invariant.  Variant references are resolved through
`nn.variants.REGISTRY`; unknown names are findings, not exceptions.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.diagnostics import Diagnostic
from repro.nn.variants import REGISTRY

_FRAC_FIELDS_CONV = ("in_frac", "w_frac", "b_frac", "out_frac")
_FRAC_FIELDS_ROUTING = ("in_frac", "W_frac", "uhat_frac",
                        "squash_out_frac")


def _max_frac() -> int:
    from repro.quant.qformat import MAX_FRAC_BITS   # jax-backed module;
    return MAX_FRAC_BITS                            # imported on demand


def _frac_range(diags, name: str, value: int, **where) -> None:
    lim = _max_frac()
    if not -lim <= value <= lim:
        diags.append(Diagnostic.of(
            "plan.frac-range",
            f"{name} = {value} outside the Qm.n derivation range "
            f"[{-lim}, {lim}]", field=name, value=value, **where))


def _variant_ref(diags, kind: str, name, **where) -> None:
    if not REGISTRY.is_registered(kind, name):
        diags.append(Diagnostic.of(
            "plan.unregistered-variant",
            f"{kind} variant {name!r} is not in nn.variants.REGISTRY "
            f"(registered: {', '.join(REGISTRY.names(kind))})",
            kind=kind, name=str(name), **where))


def check_conv_fields(d: dict, *, out_ch: int | None = None,
                      **where) -> list:
    """Shift/frac invariants of one conv plan (or CONV_Q7 attr dict).
    `out_ch`, when known, pins the per-channel table lengths."""
    diags: list = []
    for f in _FRAC_FIELDS_CONV:
        _frac_range(diags, f, d[f], **where)
    want = d["in_frac"] + d["w_frac"] - d["out_frac"]
    if d["out_shift"] != want:
        diags.append(Diagnostic.of(
            "plan.out-shift-mismatch",
            f"out_shift {d['out_shift']} != in_frac + w_frac - out_frac "
            f"= {want}", out_shift=d["out_shift"], expected=want, **where))
    want = d["in_frac"] + d["w_frac"] - d["b_frac"]
    if d["bias_shift"] != want:
        diags.append(Diagnostic.of(
            "plan.bias-shift-mismatch",
            f"bias_shift {d['bias_shift']} != in_frac + w_frac - b_frac "
            f"= {want}", bias_shift=d["bias_shift"], expected=want,
            **where))

    tables = {k: tuple(d.get(k) or ())
              for k in ("w_frac_per_channel", "out_shift_per_channel",
                        "bias_shift_per_channel")}
    if any(tables.values()):
        lengths = {k: len(v) for k, v in tables.items()}
        want_len = out_ch if out_ch is not None \
            else max(lengths.values())
        bad = {k: n for k, n in lengths.items() if n != want_len}
        if bad:
            diags.append(Diagnostic.of(
                "plan.per-channel-length",
                f"per-channel tables must all have {want_len} entries "
                f"(one per output channel); got {lengths}",
                expected=want_len, **where))
            return diags            # can't zip truncated tables below
        for c, (wf, osh, bsh) in enumerate(zip(
                tables["w_frac_per_channel"],
                tables["out_shift_per_channel"],
                tables["bias_shift_per_channel"])):
            _frac_range(diags, f"w_frac_per_channel[{c}]", wf, **where)
            if osh != d["in_frac"] + wf - d["out_frac"]:
                diags.append(Diagnostic.of(
                    "plan.out-shift-mismatch",
                    f"out_shift_per_channel[{c}] = {osh} != in_frac + "
                    f"w_frac_per_channel[{c}] - out_frac = "
                    f"{d['in_frac'] + wf - d['out_frac']}",
                    channel=c, **where))
            if bsh != d["in_frac"] + wf - d["b_frac"]:
                diags.append(Diagnostic.of(
                    "plan.bias-shift-mismatch",
                    f"bias_shift_per_channel[{c}] = {bsh} != in_frac + "
                    f"w_frac_per_channel[{c}] - b_frac = "
                    f"{d['in_frac'] + wf - d['b_frac']}",
                    channel=c, **where))
    return diags


def check_squash_fields(d: dict, *, conv_out_frac: int | None = None,
                        **where) -> list:
    """Squash plan fields of a primary-caps stage (typed plan or
    PRIMARY_CAPS_Q7 attrs)."""
    diags: list = []
    _frac_range(diags, "squash_out_frac", d["squash_out_frac"], **where)
    _variant_ref(diags, "squash",
                 d.get("squash_impl", REGISTRY.default("squash")), **where)
    in_frac = d.get("squash_in_frac", conv_out_frac)
    if in_frac is not None and conv_out_frac is not None \
            and in_frac != conv_out_frac:
        diags.append(Diagnostic.of(
            "plan.squash-in-frac-mismatch",
            f"squash_in_frac {in_frac} != the conv stage's out_frac "
            f"{conv_out_frac}", squash_in_frac=in_frac,
            conv_out_frac=conv_out_frac, **where))
    return diags


def check_routing_fields(d: dict, **where) -> list:
    """Shift/frac/table invariants of one routing plan (or
    CAPS_ROUTING_Q7 attr dict)."""
    diags: list = []
    for f in _FRAC_FIELDS_ROUTING:
        _frac_range(diags, f, d[f], **where)
    want = d["in_frac"] + d["W_frac"] - d["uhat_frac"]
    if d["uhat_shift"] != want:
        diags.append(Diagnostic.of(
            "plan.uhat-shift-mismatch",
            f"uhat_shift {d['uhat_shift']} != in_frac + W_frac - "
            f"uhat_frac = {want}", uhat_shift=d["uhat_shift"],
            expected=want, **where))
    per_out = {k: tuple(d.get(k) or ())
               for k in ("W_frac_per_out", "uhat_shift_per_out")}
    if any(per_out.values()):
        lengths = {k: len(v) for k, v in per_out.items()}
        want_len = d.get("num_out") or max(lengths.values())
        bad = {k: n for k, n in lengths.items() if n != want_len}
        if bad:
            diags.append(Diagnostic.of(
                "plan.per-out-length",
                f"per-output-capsule tables must all have {want_len} "
                f"entries (one per output capsule); got {lengths}",
                expected=want_len, **where))
        else:
            for j, (wf, sh) in enumerate(zip(per_out["W_frac_per_out"],
                                             per_out["uhat_shift_per_out"])):
                _frac_range(diags, f"W_frac_per_out[{j}]", wf, **where)
                if sh != d["in_frac"] + wf - d["uhat_frac"]:
                    diags.append(Diagnostic.of(
                        "plan.uhat-shift-mismatch",
                        f"uhat_shift_per_out[{j}] = {sh} != in_frac + "
                        f"W_frac_per_out[{j}] - uhat_frac = "
                        f"{d['in_frac'] + wf - d['uhat_frac']}",
                        channel=j, **where))
    if not 0 <= d["logit_frac"] <= 7:
        diags.append(Diagnostic.of(
            "plan.logit-frac-range",
            f"logit_frac {d['logit_frac']} outside [0, 7] (int8 logits "
            f"cannot carry more than 7 fractional bits)",
            logit_frac=d["logit_frac"], **where))

    shifts = tuple(d["caps_out_shifts"])
    fracs = tuple(d["caps_out_fracs"])
    agree = tuple(d["agree_shifts"])
    routings = d.get("routings", len(shifts))
    if len(shifts) != routings or len(fracs) != routings \
            or len(agree) != routings - 1:
        diags.append(Diagnostic.of(
            "plan.routing-table-length",
            f"per-iteration tables for {routings} routings must have "
            f"{routings}/{routings}/{routings - 1} entries; got "
            f"{len(shifts)}/{len(fracs)}/{len(agree)} "
            f"(caps_out_shifts/caps_out_fracs/agree_shifts)",
            routings=routings, **where))
        return diags                # lengths wrong: cannot zip below
    for r, (sh, f) in enumerate(zip(shifts, fracs)):
        _frac_range(diags, f"caps_out_fracs[{r}]", f, **where)
        if sh != d["uhat_frac"] + 7 - f:
            diags.append(Diagnostic.of(
                "plan.caps-out-shift-mismatch",
                f"caps_out_shifts[{r}] = {sh} != uhat_frac + 7 - "
                f"caps_out_fracs[{r}] = {d['uhat_frac'] + 7 - f}",
                iteration=r, **where))
    for r, sh in enumerate(agree):
        if sh != d["uhat_frac"] + 7 - d["logit_frac"]:
            diags.append(Diagnostic.of(
                "plan.agree-shift-mismatch",
                f"agree_shifts[{r}] = {sh} != uhat_frac + 7 - logit_frac "
                f"= {d['uhat_frac'] + 7 - d['logit_frac']}",
                iteration=r, **where))
    _variant_ref(diags, "softmax",
                 d.get("softmax_impl", REGISTRY.default("softmax")),
                 **where)
    _variant_ref(diags, "squash",
                 d.get("squash_impl", REGISTRY.default("squash")), **where)
    return diags


def check_pipeline_plan(plan) -> list:
    """Lint a typed PipelinePlan: every per-layer invariant above plus
    the out_frac -> in_frac chaining between layers.  Returns the
    diagnostics (empty list == clean); `PipelinePlan.check()` is the
    method spelling of this."""
    from repro.nn.plans import ConvPlan, PrimaryCapsPlan, RoutingPlan

    diags: list = []
    _frac_range(diags, "input_frac", plan.input_frac, op_name="input")
    f_act = plan.input_frac
    for name, p in plan.layers.items():
        where = dict(op_name=name)
        if isinstance(p, (ConvPlan, PrimaryCapsPlan)):
            conv = p.conv if isinstance(p, PrimaryCapsPlan) else p
            d = dataclasses.asdict(conv)
            diags += check_conv_fields(d, **where)
            if isinstance(p, PrimaryCapsPlan):
                diags += check_squash_fields(
                    dataclasses.asdict(p), conv_out_frac=conv.out_frac,
                    **where)
            in_frac = conv.in_frac
        elif isinstance(p, RoutingPlan):
            diags += check_routing_fields(dataclasses.asdict(p), **where)
            in_frac = p.in_frac
        else:
            diags.append(Diagnostic.of(
                "plan.unknown-layer-plan",
                f"no invariants registered for plan type "
                f"{type(p).__name__}", **where))
            continue
        if in_frac != f_act:
            diags.append(Diagnostic.of(
                "plan.frac-thread-mismatch",
                f"in_frac {in_frac} != the upstream activation format "
                f"{f_act} (plans chain out_frac -> in_frac)",
                in_frac=in_frac, upstream=f_act, **where))
        f_act = p.out_frac
    return diags
