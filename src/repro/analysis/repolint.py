"""AST-based repo lint for the rules a generic linter can't know.

    PYTHONPATH=src python -m repro.analysis.repolint src [more paths...]

Rules:

  shim-import
      ROADMAP: "never build against the compat shims".  New code must
      not import `repro.core.capsnet`, `repro.core.capsnet_q7` or
      `repro.quant.ptq` — those modules are frozen translation layers
      over the typed API (repro.nn).  Allowed locations: anything under
      `tests/`, `nn/compat.py`, and the shim modules themselves.

  unregistered-variant-string
      Operator-variant references are validated registry keys
      (nn.variants.REGISTRY), but a string literal passed as
      `softmax_impl=` / `squash_impl=` / `softmax=` / `squash=` (or to
      `REGISTRY.get/validate("softmax", "...")`) only fails at run
      time.  This rule rejects unknown literals at lint time, repo-wide.

Exit status 1 when any finding survives the allow-list, 0 when clean —
CI runs this next to ruff as one lint step.
"""
from __future__ import annotations

import ast
import dataclasses
import sys
from pathlib import Path

SHIM_MODULES = ("repro.core.capsnet", "repro.core.capsnet_q7",
                "repro.quant.ptq")
# (package, submodule) pairs for `from repro.core import capsnet` forms
_SHIM_FROM = {("repro.core", "capsnet"), ("repro.core", "capsnet_q7"),
              ("repro.quant", "ptq")}
_ALLOWED_SUFFIXES = ("nn/compat.py", "core/capsnet.py",
                     "core/capsnet_q7.py", "quant/ptq.py")
_VARIANT_KWARGS = {"softmax_impl": "softmax", "squash_impl": "squash",
                   "softmax": "softmax", "squash": "squash"}


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"


def _shim_allowed(path: str) -> bool:
    p = Path(path).as_posix()
    parts = Path(p).parts
    return "tests" in parts or p.endswith(_ALLOWED_SUFFIXES)


def _registered(kind: str, name: str) -> bool:
    from repro.nn.variants import REGISTRY
    return REGISTRY.is_registered(kind, name)


def _registered_names(kind: str) -> tuple:
    from repro.nn.variants import REGISTRY
    return REGISTRY.names(kind)


def _is_shim(module: str) -> bool:
    return any(module == s or module.startswith(s + ".")
               for s in SHIM_MODULES)


def _iter_shim_imports(tree):
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if _is_shim(alias.name):
                    yield node.lineno, alias.name
        elif isinstance(node, ast.ImportFrom) and node.level == 0 \
                and node.module:
            if _is_shim(node.module):
                yield node.lineno, node.module
            else:
                for alias in node.names:
                    if (node.module, alias.name) in _SHIM_FROM:
                        yield node.lineno, f"{node.module}.{alias.name}"


def _iter_variant_strings(tree):
    """(lineno, kind, name) for every string-literal variant reference."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            kind = _VARIANT_KWARGS.get(kw.arg)
            if kind and isinstance(kw.value, ast.Constant) \
                    and isinstance(kw.value.value, str):
                yield kw.value.lineno, kind, kw.value.value
        # REGISTRY.get("softmax", "name") / .validate / .is_registered
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("get", "validate", "is_registered") \
                and len(node.args) >= 2 \
                and all(isinstance(a, ast.Constant)
                        and isinstance(a.value, str)
                        for a in node.args[:2]) \
                and node.args[0].value in ("softmax", "squash"):
            yield node.args[1].lineno, node.args[0].value, \
                node.args[1].value


def lint_source(source: str, path: str) -> list:
    """All findings in one file's source text."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 0, "syntax-error", str(e.msg))]
    findings = []
    if not _shim_allowed(path):
        for line, module in _iter_shim_imports(tree):
            findings.append(Finding(
                path, line, "shim-import",
                f"import of compat shim {module!r} — build against the "
                f"typed API (repro.nn / repro.quant.qformat) instead; "
                f"only tests/ and nn/compat.py may touch shims"))
    for line, kind, name in _iter_variant_strings(tree):
        if not _registered(kind, name):
            findings.append(Finding(
                path, line, "unregistered-variant-string",
                f"{kind} variant {name!r} is not registered in "
                f"nn.variants.REGISTRY "
                f"(have: {', '.join(_registered_names(kind))})"))
    return findings


def lint_paths(paths) -> list:
    """Lint every .py file under the given files/directories."""
    findings = []
    for p in paths:
        p = Path(p)
        files = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in files:
            findings.extend(lint_source(f.read_text(), str(f)))
    return findings


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    paths = argv or ["src"]
    findings = lint_paths(paths)
    for f in findings:
        print(f)
    scanned = ", ".join(paths)
    if findings:
        print(f"[repolint] {len(findings)} finding(s) in {scanned}")
        return 1
    print(f"[repolint] clean: {scanned}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
