"""Batched serving driver: prefill + decode loop with optional W8A8
quantization (the paper's technique as a first-class serving feature).

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --reduce \
      --requests 8 --prompt-len 64 --gen 32 --quant w8a8

Requests are batched (continuous batching at fixed positions: all rows in
a wave share a decode position — the production scheduler would interleave
waves), the KV cache is allocated once per wave, and --quant w8a8 swaps
the parameter tree for int8 weights with per-channel power-of-two scales
(repro.quant.lm_quant) — on TPU that halves weight HBM traffic and runs
the matmuls on the MXU's 2x-rate int8 path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.data.synthetic import TokenTask
from repro.launch.train import reduced
from repro.models.transformer import build_model, decode_alloc
from repro.quant.lm_quant import quantize_lm_params, quantized_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b")
    ap.add_argument("--reduce", action="store_true", default=True)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--quant", choices=("none", "w8a8"), default="none")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, d_model=args.d_model)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    fp_bytes = sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(params))
    if args.quant == "w8a8":
        params = quantize_lm_params(params)
        print(f"[quant] params {fp_bytes/2**20:.1f} MiB -> "
              f"{quantized_bytes(params)/2**20:.1f} MiB int8")

    task = TokenTask(cfg.vocab_size, args.prompt_len, seed=3)
    prompts = jnp.asarray(task.batch(0, args.requests)["inputs"])
    alloc = decode_alloc(args.prompt_len + args.gen)

    batch = {"inputs": prompts}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.zeros(
            (args.requests, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros(
            (args.requests, args.prompt_len, cfg.d_model), jnp.float32)

    prefill = jax.jit(lambda p, b: model.prefill(p, b, alloc=alloc))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    pos0 = args.prompt_len + (cfg.num_prefix_embeds
                              if cfg.family == "vlm" else 0)
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate(out_tokens, 1)
    tps = args.requests * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {t_prefill*1e3:.1f} ms for "
          f"{args.requests}x{args.prompt_len} tokens")
    print(f"decode : {t_decode*1e3:.1f} ms for {args.gen-1} steps "
          f"({tps:.1f} tok/s aggregate)")
    print(f"sample completions (first 2 rows, first 12 tokens):")
    for r in range(min(2, args.requests)):
        print(f"  req{r}: {gen[r, :12].tolist()}")


if __name__ == "__main__":
    main()
