"""End-to-end training driver.

Runs a real training loop (synthetic token stream, AdamW, checkpointing,
crash-restart) for any assigned architecture — at full scale under a mesh
on real hardware, or at a reduced scale on this CPU container:

  # ~100M-param LM for a few hundred steps (the (b) deliverable):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_14b --reduce \
      --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/ck

  # production posture (dry-run container: compile-only via launch.dryrun)
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_72b --mesh single

Fault tolerance: checkpoints every --ckpt-every steps (atomic, keep-3),
resumes from LATEST, and the whole loop runs under
repro.dist.fault.run_with_restarts.  Optional int8 gradient compression
with error feedback (--grad-compress) applies the paper's Q-format to the
DP gradient reduction.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import ckpt
from repro.configs.base import ARCH_IDS, get_config
from repro.data.synthetic import TokenTask
from repro.dist.fault import StepTimer, run_with_restarts
from repro.launch import steps as steps_mod
from repro.models.transformer import build_model
from repro.optim.grad_compress import EFCompressor


def reduced(cfg, d_model=256, layers=None):
    """Shrink an assigned config to a CPU-trainable scale (same family)."""
    n_blocks = len(cfg.blocks)
    num_layers = layers or n_blocks * max(1, 2 // max(n_blocks // 4, 1))
    num_layers = max(n_blocks, (num_layers // n_blocks) * n_blocks)
    return cfg.scaled(
        num_layers=num_layers, d_model=d_model,
        num_heads=4, num_kv_heads=min(4, cfg.num_kv_heads),
        head_dim=d_model // 4,
        d_ff=d_model * 4 if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 4096),
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        window_size=min(cfg.window_size, 64) if cfg.window_size else 0,
        num_encoder_layers=min(cfg.num_encoder_layers, 2),
        num_prefix_embeds=min(cfg.num_prefix_embeds, 16),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3_14b")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, d_model=args.d_model)
    model = build_model(cfg)
    opt = steps_mod.make_optimizer(total_steps=args.steps)
    task = TokenTask(cfg.vocab_size, args.seq, seed=7)
    comp = EFCompressor() if args.grad_compress else None

    def train_step(state, batch):
        def loss_fn(p):
            return model.train_loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        if comp is not None:
            grads, new_err = comp.apply(grads, state["err"])
        new_params, new_opt, om = opt.update(grads, state["opt"],
                                             state["params"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if comp is not None:
            new_state["err"] = new_err
        return new_state, dict(metrics, **om)

    jstep = jax.jit(train_step, donate_argnums=(0,))

    def make_and_run(attempt: int) -> int:
        key = jax.random.key(0)
        params = model.init(key)
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if comp is not None:
            state["err"] = comp.init(params)
        start = 0
        if args.ckpt_dir:
            got = ckpt.restore_latest(args.ckpt_dir, state)
            if got[0] is not None:
                start, state = got
                print(f"[resume] from step {start}")
        timer = StepTimer()
        for i in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, task.batch(i, args.batch))
            if cfg.family == "vlm":
                Pn = cfg.num_prefix_embeds
                batch["prefix_embeds"] = jnp.zeros(
                    (args.batch, Pn, cfg.d_model), jnp.float32)
            if cfg.is_encoder_decoder:
                batch["frames"] = jnp.zeros(
                    (args.batch, args.seq, cfg.d_model), jnp.float32)
            timer.start()
            state, metrics = jstep(state, batch)
            jax.block_until_ready(metrics["loss"])  # sync for honest timing
            dt = timer.stop()
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms"
                      + (" [straggler]" if timer.is_straggler(dt) else ""))
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, i + 1, state)
                ckpt.gc_keep_n(args.ckpt_dir, keep=3)
        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, args.steps, state)
        return args.steps

    run_with_restarts(make_and_run, max_restarts=2)


if __name__ == "__main__":
    main()
