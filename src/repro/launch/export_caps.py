"""Export a quantized CapsNet as a deployable MCU artifact.

    PYTHONPATH=src python -m repro.launch.export_caps \
        --model edge_tiny --out /tmp/e

builds (or reuses) the model through the serving registry's lazy-PTQ
path, lowers it to an EdgeProgram, and writes

    <out>/<stem>.capsbin        single-file binary (weights + plan)
    <out>/<stem>.manifest.json  human-readable IR manifest
    <out>/<stem>.c / .h         CMSIS-NN-style sources

then reloads the `.capsbin` from disk and re-verifies it in the NumPy
q7 VM against the live model, bit for bit — export and proof in one
command.  `--model` accepts a bare dataset name (mnist, smallnorb,
cifar10, edge_tiny -> the @jnp spec) or a full registry id.
`--softmax`/`--squash` export with an operator variant from the
registry (repro.nn.variants; unknown names fail with the registered
ones listed) — the variant references ride the `.capsbin` attrs and
pick the matching C kernel symbols.  The static verifier
(repro.analysis) vets the lowered program before anything is written;
`--no-check` skips it.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import CheckError
from repro.edge import describe, format_export
from repro.nn.variants import REGISTRY
from repro.serving import ModelRegistry, default_specs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="edge_tiny",
                    help="registry model id (mnist@jnp, ...) or bare "
                    "dataset name (-> @jnp)")
    ap.add_argument("--out", required=True, help="output directory")
    ap.add_argument("--stem", default=None,
                    help="artifact file stem (default: model id)")
    ap.add_argument("--rounding", choices=("floor", "nearest"),
                    default="floor")
    ap.add_argument("--per-channel", action="store_true",
                    help="per-output-channel conv weight formats "
                    "(ConvPlan.w_frac_per_channel)")
    ap.add_argument("--softmax", choices=REGISTRY.names("softmax"),
                    default=None,
                    help="softmax operator variant (repro.nn.variants), "
                    "e.g. the ISLPED'22 'approx'")
    ap.add_argument("--squash", choices=REGISTRY.names("squash"),
                    default=None,
                    help="squash operator variant")
    ap.add_argument("--verify-n", type=int, default=4,
                    help="images for the bit-exact VM re-verification "
                    "(0 disables)")
    ap.add_argument("--check", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="statically verify the lowered program before "
                    "writing artifacts (repro.analysis: int32 range "
                    "proofs, plan shift algebra, arena aliasing)")
    ap.add_argument("--profile", action="store_true",
                    help="print the static per-op cycle/latency estimate "
                    "of the exported program on every calibrated MCU "
                    "profile (repro.edge.costmodel: cortex-m7, gap8)")
    ap.add_argument("--drift", action="store_true",
                    help="run the exported program through the NumPy q7 "
                    "VM with per-op profiling and print the cost-model "
                    "drift report (repro.obs.analyze.costmodel_drift: "
                    "measured wall-time shares vs static cycle shares, "
                    "per calibrated MCU profile)")
    ap.add_argument("--drift-n", type=int, default=8,
                    help="images for the --drift measurement batch")
    ap.add_argument("--numerics", action="store_true",
                    help="run the exported program through the VM with "
                    "numeric-health probes (repro.obs.numerics) and "
                    "print the report: saturation, int32 clips, bound "
                    "tightness vs the static proofs, per-layer q7-vs-"
                    "f32 SNR; exits 1 on any int32-clip event or any "
                    "observed value outside its static bound")
    ap.add_argument("--numerics-out", metavar="PATH", default=None,
                    help="also write the report as a repro.numerics/v1 "
                    "JSON doc (repro.obs.analyze accepts it); implies "
                    "--numerics")
    ap.add_argument("--numerics-n", type=int, default=8,
                    help="images for the --numerics probe batch")
    ap.add_argument("--from-search", metavar="RESULT.json", default=None,
                    help="export a frontier point from a repro.search/v1 "
                    "result doc (repro.launch.search_caps --out): replays "
                    "the doc's seeded setup, rebuilds the point's model, "
                    "asserts its plan matches the doc bit-for-bit, "
                    "re-runs the static checker, then exports.  Ignores "
                    "--model/--rounding/--per-channel/--softmax/--squash "
                    "(the doc's config governs)")
    ap.add_argument("--point", type=int, default=0,
                    help="frontier point index for --from-search")
    args = ap.parse_args(argv)

    if args.from_search:
        return _export_from_search(args)

    model_id = args.model if "@" in args.model else f"{args.model}@jnp"
    registry = ModelRegistry()
    if model_id not in registry.specs:
        print(f"[export_caps] unknown model {args.model!r}; have "
              f"{sorted(default_specs())}", file=sys.stderr)
        return 2
    spec = registry.specs[model_id]
    if args.rounding != "floor" or args.per_channel \
            or args.softmax or args.squash:
        import dataclasses
        overrides = {f"{k}_impl": v
                     for k, v in (("softmax", args.softmax),
                                  ("squash", args.squash)) if v}
        spec = dataclasses.replace(spec, rounding=args.rounding,
                                   per_channel=args.per_channel,
                                   **overrides)
        registry.register(spec)

    print(f"[export_caps] model={model_id} rounding={args.rounding} "
          f"per_channel={args.per_channel} variants={spec.variants.tag} "
          f"-> {args.out}")
    try:
        result = registry.export(model_id, args.out, stem=args.stem,
                                 verify_n=args.verify_n, check=args.check)
    except CheckError as e:          # static findings are exit 1 too
        print(f"[export_caps] STATIC CHECK FAILED:\n{e}", file=sys.stderr)
        return 1
    except AssertionError as e:      # verification failure is exit 1
        print(f"[export_caps] VERIFY FAILED: {e}", file=sys.stderr)
        return 1
    print(describe(result["program"]))
    print(format_export(result))
    if args.profile:
        from repro.edge import format_estimates
        print(format_estimates(result["program"]))
    if args.drift:
        from repro.edge.vm import EdgeVM
        from repro.obs.analyze import costmodel_drift, format_drift
        program = result["program"]
        vm = EdgeVM(program)
        n = max(args.drift_n, 1)
        x_q = vm.quantize_input(spec.images(n, seed=0))
        rows: list = []
        vm.run(x_q, profile=rows)
        print(format_drift(costmodel_drift(program, rows, batch=n)))
    if args.numerics or args.numerics_out:
        import jax

        from repro.obs import numerics as health
        qnet = registry.model(model_id)
        # the float weights the model was quantized from (ModelSpec.build
        # inits from the spec seed) — the SNR oracle
        params = qnet.pipeline.init(jax.random.key(spec.seed))
        n = max(args.numerics_n, 1)
        report = health.run_numerics(qnet, spec.images(n, seed=0),
                                     params=params,
                                     program=result["program"])
        print(report.format())
        if args.numerics_out:
            import json
            import pathlib
            path = pathlib.Path(args.numerics_out)
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(report.to_doc(), indent=1,
                                       sort_keys=True))
            print(f"[export_caps] wrote numerics report to {path}")
        findings = health.check_containment(result["program"], report)
        clips = report.total_int32_clip()
        if clips:
            findings.append(f"{clips} int32-clip event(s) observed — "
                            "statically proven impossible on a "
                            "verifier-clean program")
        if findings:
            for f in findings:
                print(f"[export_caps] NUMERICS: {f}", file=sys.stderr)
            return 1
    return 0


def _export_from_search(args) -> int:
    """The --from-search path: result doc + point index -> artifact."""
    from repro.analysis import check_program
    from repro.edge import lower
    from repro.edge.export import export_artifacts
    from repro.search import load_doc, rebuild_point

    try:
        doc = load_doc(args.from_search)
        qnet, entry, st = rebuild_point(doc, args.point)
    except (OSError, ValueError, RuntimeError) as e:
        print(f"[export_caps] --from-search: {e}", file=sys.stderr)
        return 2
    print(f"[export_caps] search point {args.point} of "
          f"{args.from_search}: spec={entry['spec']} "
          f"acc={entry['metrics'].get('acc'):.4f} -> {args.out}")

    # the satellite contract: re-run the static verifier on the rebuilt
    # program BEFORE anything is written, even though export_artifacts
    # would check again — a drifted checker must block the export here
    result = check_program(lower(qnet))
    if not result.ok:
        print(f"[export_caps] STATIC CHECK FAILED:\n{result.format()}",
              file=sys.stderr)
        return 1
    stem = args.stem or f"{doc['config']['model']}_p{args.point}"
    verify = st.images[:args.verify_n] if args.verify_n > 0 else None
    try:
        out = export_artifacts(qnet, args.out, stem=stem,
                               verify_images=verify, check=args.check)
    except CheckError as e:
        print(f"[export_caps] STATIC CHECK FAILED:\n{e}", file=sys.stderr)
        return 1
    except AssertionError as e:
        print(f"[export_caps] VERIFY FAILED: {e}", file=sys.stderr)
        return 1
    print(describe(out["program"]))
    print(format_export(out))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
