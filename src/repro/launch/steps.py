"""Step functions + ShapeDtypeStruct input specs for every
(architecture x input-shape) cell.

`input_specs(cfg, shape)` returns the stand-in structs for every model input
(the shannon/kernels pattern: weak-type-correct, shardable, no allocation);
`make_cell(cfg, shape, mesh)` additionally returns the step callable and
in/out shardings so the dry-run is a single jit().lower().compile().
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.dist import sharding as shd
from repro.dist.api import BATCH
from repro.models.transformer import build_model, decode_alloc
from repro.optim.adam import AdamW, cosine_schedule


def structs(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


# ---------------------------------------------------------------------------
# batch structs per shape kind
# ---------------------------------------------------------------------------
def batch_structs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    emb = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            Pn = cfg.num_prefix_embeds
            b = {"inputs": tok((B, S - Pn)),
                 "prefix_embeds": emb((B, Pn, cfg.d_model))}
            if shape.kind == "train":
                b["targets"] = tok((B, S - Pn))
            return b
        if cfg.is_encoder_decoder:
            b = {"frames": emb((B, S, cfg.d_model)), "inputs": tok((B, S))}
            if shape.kind == "train":
                b["targets"] = tok((B, S))
            return b
        b = {"inputs": tok((B, S))}
        if shape.kind == "train":
            b["targets"] = tok((B, S))
        return b
    # decode: one new token against a seq_len cache
    return {"token": tok((B, 1))}


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                quant: bool = False) -> dict:
    """All inputs of the lowered step for this cell, as ShapeDtypeStructs.
    quant=True swaps the parameter tree for its W8A8 form (serving only)."""
    model = build_model(cfg)

    def params_struct():
        def mk():
            p = model.init(jax.random.key(0))
            if quant:
                from repro.quant.lm_quant import quantize_lm_params
                p = quantize_lm_params(p)
            return p
        return structs(jax.eval_shape(mk))

    out = {"batch": batch_structs(cfg, shape)}
    if shape.kind == "train":
        out["state"] = train_state_structs(cfg)
    elif shape.kind == "prefill":
        out["params"] = params_struct()
    else:
        out["params"] = params_struct()
        out["cache"] = cache_structs(cfg, shape)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


def cache_is_stacked(cfg: ModelConfig) -> bool:
    return cfg.is_encoder_decoder or not cfg.decode_unroll


def cache_structs(cfg: ModelConfig, shape: ShapeSpec):
    model = build_model(cfg)
    B = shape.global_batch
    alloc = decode_alloc(shape.seq_len)
    if cfg.is_encoder_decoder:
        fn = lambda: model.init_cache(B, alloc, src_len=shape.seq_len)
    else:
        fn = lambda: model.init_cache(B, alloc,
                                      stacked=not cfg.decode_unroll)
    return structs(jax.eval_shape(fn))


# ---------------------------------------------------------------------------
# train state
# ---------------------------------------------------------------------------
def make_optimizer(total_steps: int = 100_000) -> AdamW:
    return AdamW(lr=cosine_schedule(3e-4, 2000, total_steps),
                 weight_decay=0.1, clip_norm=1.0)


def train_state_structs(cfg: ModelConfig) -> dict:
    model = build_model(cfg)
    opt = make_optimizer()

    def init():
        p = model.init(jax.random.key(0))
        return {"params": p, "opt": opt.init(p),
                "step": jnp.zeros((), jnp.int32)}
    return structs(jax.eval_shape(init))


def init_train_state(cfg: ModelConfig, key) -> dict:
    model = build_model(cfg)
    opt = make_optimizer()
    p = model.init(key)
    return {"params": p, "opt": opt.init(p),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig):
    model = build_model(cfg)
    opt = make_optimizer()

    def train_step(state, batch):
        def loss_fn(params):
            return model.train_loss(params, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt, opt_metrics = opt.update(
            grads, state["opt"], state["params"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, **opt_metrics)
        return new_state, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, shape: ShapeSpec):
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    model = build_model(cfg)

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)
    return serve_step


# ---------------------------------------------------------------------------
# full cell assembly: (step fn, input structs, in/out shardings)
# ---------------------------------------------------------------------------
def make_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, quant: bool = False):
    """Returns (fn, args tuple of structs, in_shardings, out_shardings)."""
    from repro.dist.api import dp_size
    B = shape.global_batch
    specs = input_specs(cfg, shape, quant=quant)
    bspec = shd.batch_specs(specs["batch"], B, mesh)
    logits_spec = P(BATCH, None) if B % dp_size(mesh) == 0 else P()

    if shape.kind == "train":
        fn = make_train_step(cfg)
        st = specs["state"]
        st_spec = {
            "params": shd.param_specs(st["params"]),
            "opt": shd.opt_state_specs(st["opt"], st["params"]),
            "step": P(),
        }
        args = (st, specs["batch"])
        in_specs = (st_spec, bspec)
        out_specs = (st_spec, P())          # metrics replicated
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg, shape)
        p_spec = shd.param_specs(specs["params"])
        cache_out = shd.cache_specs(
            _prefill_cache_structs(cfg, shape), B, mesh,
            stacked=cache_is_stacked(cfg))
        args = (specs["params"], specs["batch"])
        in_specs = (p_spec, bspec)
        out_specs = (logits_spec, cache_out)
    else:
        fn = make_decode_step(cfg)
        p_spec = shd.param_specs(specs["params"])
        c_spec = shd.cache_specs(specs["cache"], B, mesh,
                                 stacked=cache_is_stacked(cfg))
        args = (specs["params"], specs["cache"], specs["batch"]["token"],
                specs["pos"])
        tok_spec = shd.batch_specs(specs["batch"], B, mesh)["token"]
        in_specs = (p_spec, c_spec, tok_spec, P())
        out_specs = (logits_spec, c_spec)

    in_shardings = jax.tree.map(
        lambda s: shd.to_shardings(s, mesh),
        in_specs, is_leaf=lambda x: isinstance(x, P))
    out_shardings = jax.tree.map(
        lambda s: shd.to_shardings(s, mesh),
        out_specs, is_leaf=lambda x: isinstance(x, P))
    return fn, args, in_shardings, out_shardings


def _prefill_cache_structs(cfg, shape):
    """Prefill OUTPUT cache layout (unstacked when decode_unroll, since
    prefill hands its cache to the unrolled decode step)."""
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        fn = lambda: model.init_cache(B, S, src_len=S)
    else:
        fn = lambda: model.init_cache(B, S, stacked=not cfg.decode_unroll)
    return structs(jax.eval_shape(fn))
