"""Batched int8 CapsNet serving driver (the capsule-side analogue of
launch/serve.py's LM loop).

  PYTHONPATH=src python -m repro.launch.serve_caps --model mnist@jnp \
      --requests 64 --buckets 1,4,16,64

Builds the model lazily in the registry (init -> PTQ on a synthetic
calibration set), warms the wave executables so compile time stays out of
the latency numbers, submits --requests synthetic images through the
bucketed micro-batch scheduler, and prints the serving metrics.  With
--compare-b1 it replays the same requests through a batch-size-1 loop to
show what micro-batching buys; with --mesh host the waves run sharded
over the logical BATCH axes of a mesh built from the local devices.
With --capsbin PATH the engine serves an exported MCU artifact instead:
the `.capsbin` is imported back into a QuantCapsNet (repro.edge
importer) and installed under its program name — the bits in flight are
exactly the bits that shipped.

Imported artifacts pass through the static verifier (repro.analysis)
before they are served; --no-check skips it.

--softmax/--squash select operator variants from the registry
(repro.nn.variants; e.g. the ISLPED'22 approximate softmax/squash) —
on a spec as a rebuilt ModelSpec, on a --capsbin artifact as a pure
plan edit.  Unknown names fail argparse with the registered ones
listed.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import numpy as np

from repro.analysis import CheckError
from repro.launch.mesh import make_host_mesh
from repro.nn.variants import REGISTRY, VariantSet
from repro.serving import ModelRegistry, default_specs, serve_window


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="mnist@jnp",
                    help=f"registry id ({', '.join(sorted(default_specs()))})"
                    "; ignored when --capsbin is given")
    ap.add_argument("--capsbin", metavar="PATH", default=None,
                    help="serve an exported .capsbin artifact (imported "
                    "via repro.edge, installed under its program name)")
    ap.add_argument("--softmax", choices=REGISTRY.names("softmax"),
                    default=None,
                    help="softmax operator variant (repro.nn.variants); "
                    "default: the spec's / artifact's own")
    ap.add_argument("--squash", choices=REGISTRY.names("squash"),
                    default=None,
                    help="squash operator variant; default: the spec's "
                    "/ artifact's own")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--buckets", default="1,4,16,64",
                    help="comma-separated micro-batch bucket sizes")
    ap.add_argument("--mesh", choices=("none", "host"), default="none",
                    help="host: shard waves over a mesh of local devices")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare-b1", action="store_true",
                    help="also serve via a batch-size-1 loop and report "
                    "the batched speedup")
    ap.add_argument("--export", metavar="DIR", default=None,
                    help="also dump the served model as an MCU artifact "
                    "(.capsbin + manifest + .c/.h via repro.edge) and "
                    "print the flash/RAM report")
    ap.add_argument("--check", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="statically verify imported --capsbin artifacts "
                    "and --export programs (repro.analysis)")
    ap.add_argument("--profile", action="store_true",
                    help="print the static MCU cycle/latency estimate of "
                    "the served model (repro.edge.costmodel, both "
                    "calibrated profiles)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="record spans for the whole run (PTQ, wave "
                    "compile, enqueue->execute) and write Chrome "
                    "trace-event JSON to PATH (load in "
                    "chrome://tracing / Perfetto)")
    ap.add_argument("--trace-summary", action="store_true",
                    help="print the trace analyzer's report of this "
                    "run (repro.obs.analyze: span stats, wave critical "
                    "paths, per-request timelines); implies recording "
                    "spans even without --trace")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="dump the run's final metrics snapshots as "
                    "JSON on exit (schema repro.metrics/v1: process + "
                    "run registries + the serve window summary); "
                    "repro.obs.analyze accepts it via --metrics")
    ap.add_argument("--numerics-out", metavar="PATH", default=None,
                    help="after serving, run a probed numeric-health "
                    "pass of the served model (repro.obs.numerics: "
                    "saturation, int32 clips, bound tightness, SNR) "
                    "and write the repro.numerics/v1 JSON doc to PATH")
    args = ap.parse_args(argv)

    from repro import obs
    tracer = None
    if args.trace or args.trace_summary:
        tracer = obs.Tracer()
        obs.set_tracer(tracer)
    # one run-scoped registry sees the model-registry counters and the
    # serve window's ServeMetrics mirror; METRICS (process) keeps the
    # singleton counters (pallas fallbacks)
    run_metrics = obs.MetricsRegistry("serve_caps") \
        if args.metrics_out else None

    # serving waves shard over BATCH=("pod","data"): give "data" the
    # devices (make_host_mesh fills the LAST axis; "model" would make the
    # batch constraint a 1x1 no-op and replicate every wave)
    mesh = make_host_mesh(("pod", "model", "data")) \
        if args.mesh == "host" else None
    registry = ModelRegistry(mesh=mesh, metrics=run_metrics)
    buckets = tuple(int(b) for b in args.buckets.split(","))

    if args.capsbin:
        try:
            qnet = registry.install_artifact(args.capsbin,
                                             check=args.check)
        except CheckError as e:      # refuse to serve a bad artifact
            print(f"[serve_caps] STATIC CHECK FAILED for "
                  f"{args.capsbin}:\n{e}", file=sys.stderr)
            return 1
        model_id = qnet.pipeline.cfg.name        # the program's name
        if args.softmax or args.squash:          # plan edit on the artifact
            vs = dataclasses.replace(
                qnet.variants,
                **{k: v for k, v in (("softmax", args.softmax),
                                     ("squash", args.squash)) if v})
            qnet = qnet.with_variants(vs)
            registry.install(model_id, qnet)
        rng = np.random.default_rng(args.seed)
        images = rng.uniform(0, 1, (args.requests,)
                             + registry.input_shape(model_id)) \
            .astype(np.float32)
        print(f"[serve_caps] imported {args.capsbin} as {model_id!r} "
              f"({qnet.memory_bytes() / 1000:.1f} KB int8) "
              f"variants={qnet.variants.tag} buckets={buckets} "
              f"mesh={'none' if mesh is None else dict(mesh.shape)}")
    else:
        model_id = args.model
        if model_id not in registry.specs:
            ap.error(f"unknown model {model_id!r}; have "
                     f"{sorted(registry.specs)} (or pass --capsbin)")
        spec = registry.specs[model_id]
        if args.softmax or args.squash:
            spec = dataclasses.replace(
                spec,
                **{f"{k}_impl": v for k, v in (("softmax", args.softmax),
                                               ("squash", args.squash))
                   if v})
            registry.register(spec)
        images = spec.images(args.requests, args.seed)
        print(f"[serve_caps] model={model_id} ({spec.config.name}, "
              f"backend={spec.backend}, variants={spec.variants.tag}) "
              f"buckets={buckets} "
              f"mesh={'none' if mesh is None else dict(mesh.shape)}")
        t0 = time.perf_counter()
        registry.model(model_id)
        print(f"[serve_caps] lazy PTQ build: "
              f"{time.perf_counter() - t0:.2f} s "
              f"({registry.model(model_id).memory_bytes() / 1000:.1f} "
              "KB int8)")
    if args.export:
        from repro.edge import format_export
        result = registry.export(model_id, args.export, check=args.check)
        print("[serve_caps] exported MCU artifact:")
        print(format_export(result))
    if args.profile:
        from repro.edge import format_estimates, lower
        program = lower(registry.model(model_id))
        print("[serve_caps] static MCU latency estimate:")
        print(format_estimates(program))

    engine, wall = serve_window(registry, buckets, images, model_id,
                                metrics_registry=run_metrics)
    print("[serve_caps]", engine.metrics.report())
    print(f"[serve_caps] executables compiled: {registry.compile_count}, "
          f"cache hits: {registry.exec_hits}")
    if registry.variant_fallbacks:
        print(f"[serve_caps] pallas->oracle variant fallbacks: "
              f"{registry.variant_fallbacks}")
    if args.compare_b1:
        b1_engine, b1_wall = serve_window(registry, (1,), images, model_id)
        print("[serve_caps] b1  :", b1_engine.metrics.report())
        print(f"[serve_caps] batched speedup over b1 loop: "
              f"{b1_wall / max(wall, 1e-9):.2f}x")
    if args.numerics_out:
        import json
        import pathlib

        from repro.obs import numerics as health
        qnet = registry.model(model_id)
        params = None
        if not args.capsbin:             # spec path: rebuild the float
            import jax                   # oracle weights for SNR rows
            params = qnet.pipeline.init(jax.random.key(spec.seed))
        report = health.run_numerics(qnet, images[:16], params=params,
                                     metrics=run_metrics)
        path = pathlib.Path(args.numerics_out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(report.to_doc(), indent=1,
                                   sort_keys=True))
        print(f"[serve_caps] numerics: int32 clips "
              f"{report.total_int32_clip()}, worst saturation "
              f"{report.worst_saturation_rate() * 100:.2f}%, "
              f"wrote {path}")
    if args.metrics_out:
        import json
        import pathlib
        doc = {"schema": "repro.metrics/v1",
               "process": obs.METRICS.snapshot(),
               "run": run_metrics.snapshot(),
               "serve_summary": engine.metrics.summary()}
        path = pathlib.Path(args.metrics_out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(doc, indent=1, sort_keys=True))
        print(f"[serve_caps] wrote metrics snapshot to {path}")
    if tracer is not None:
        obs.set_tracer(None)
        if args.trace:
            tracer.write_chrome_trace(args.trace)
            print(f"[serve_caps] wrote {tracer.span_count()} spans to "
                  f"{args.trace} (chrome://tracing)")
        if args.trace_summary:
            from repro.obs import analyze
            print("[serve_caps] trace summary:")
            print(analyze.format_analysis(analyze.analyze(tracer)))


if __name__ == "__main__":
    raise SystemExit(main())
