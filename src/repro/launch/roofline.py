"""Roofline-term derivation from a compiled dry-run artifact.

Hardware constants (TPU v5e, per assignment):
  197 TFLOP/s bf16 per chip (int8 ~2x), 819 GB/s HBM, ~50 GB/s/link ICI.

cost_analysis() of the SPMD-partitioned module reports PER-DEVICE flops /
bytes (verified: sharded flops = unsharded / n_devices), so:
  compute_term    = flops_per_dev / PEAK
  memory_term     = bytes_per_dev / HBM_BW
  collective_term = collective_bytes_per_dev / ICI_LINK_BW
MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), 2*N*D forward-only.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.dist.hlo_analysis import analyze_collectives
from repro.launch.mesh import mesh_chips

PEAK_BF16 = 197e12      # FLOP/s per chip
PEAK_INT8 = 394e12
HBM_BW = 819e9          # B/s per chip
ICI_LINK_BW = 50e9      # B/s per link


def active_param_count(cfg: ModelConfig) -> int:
    """Non-embedding active parameters (MoE counts top-k experts only)."""
    n = cfg.param_count(active_only=True)
    n -= cfg.vocab_size * cfg.d_model          # input embedding
    return max(n, 1)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Useful model FLOPs per step, whole job (all chips)."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per row + attention over the cache
    flops = 2.0 * n * shape.global_batch
    per_layer_kv = {"attn": shape.seq_len,
                    "swa": min(cfg.window_size, shape.seq_len)}
    kv_positions = sum(per_layer_kv.get(m, 0)
                       for m, _ in cfg.blocks) * cfg.num_cycles
    flops += 4.0 * cfg.num_heads * cfg.head_dim * kv_positions \
        * shape.global_batch
    return flops


def analyze_cell(compiled, cfg: ModelConfig, shape: ShapeSpec, mesh,
                 mesh_kind: str, int8: bool = False) -> dict:
    from repro.dist.hlo_analysis import analyze_hlo
    chips = mesh_chips(mesh)
    peak = PEAK_INT8 if int8 else PEAK_BF16
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):        # older jax: one dict per device
        ca = ca[0] if ca else {}

    # XLA's cost_analysis counts while bodies once (everything here is
    # scanned) -> use our own trip-count-aware HLO cost model instead,
    # keeping XLA's raw numbers for reference.
    cost = analyze_hlo(compiled.as_text())
    flops_dev = float(cost.flops)
    bytes_dev = float(cost.hbm_bytes)
    coll_dev = float(cost.collective_bytes)

    ma = compiled.memory_analysis()
    mem = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem[f] = int(getattr(ma, f, 0))
    hbm_dev = (mem["argument_size_in_bytes"] + mem["output_size_in_bytes"]
               + mem["temp_size_in_bytes"] - mem["alias_size_in_bytes"])

    mf = model_flops(cfg, shape)
    terms = {
        "compute_s": flops_dev / peak,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mfu = (mf / chips / peak) / bound if bound > 0 else 0.0
    return {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_kind,
        "kind": shape.kind, "chips": chips,
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "collectives": {"total_bytes": coll_dev,
                        "bytes_by_kind": cost.collective_bytes_by_kind,
                        "count_by_kind": cost.collective_count_by_kind},
        "xla_cost_analysis_raw": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))},
        "n_whiles": cost.n_whiles,
        "memory": mem, "hbm_bytes_per_dev": hbm_dev,
        "hbm_gib_per_dev": hbm_dev / 2**30,
        "model_flops_total": mf,
        "model_flops_per_dev": mf / chips,
        "useful_flop_ratio": (mf / chips) / flops_dev if flops_dev else 0.0,
        "terms": terms,
        "dominant": dominant,
        "roofline_fraction": mfu,
        "step_time_lower_bound_s": bound,
    }
