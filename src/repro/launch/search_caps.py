"""Run the quantization/variant search and write a verified Pareto
frontier doc.

    PYTHONPATH=src python -m repro.launch.search_caps \
        --model edge_tiny --budget 24 --out /tmp/search.json

trains the float model (seeded), explores the design space with the
chosen strategy under an evaluation budget, computes the Pareto
frontier over accuracy x packed flash x RAM x estimated Cortex-M7
latency, export/check/bit-verifies every frontier point, and writes a
`repro.search/v1` JSON doc.  Identical seeds reproduce an identical
doc, and any point can later be exported as a deployable artifact with

    python -m repro.launch.export_caps --from-search search.json \
        --point 0 --out /tmp/e
"""
from __future__ import annotations

import argparse
import sys

from repro.captrain.evalq import format_rows
from repro.search import (SearchConfig, frontier_table_rows, run_search,
                          save_doc)
from repro.search.strategies import STRATEGIES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="edge_tiny",
                    help="search model: edge_tiny or a dataset with a "
                    "capsnet config (mnist, smallnorb, cifar10)")
    ap.add_argument("--strategy", choices=sorted(STRATEGIES),
                    default="coordinate")
    ap.add_argument("--budget", type=int, default=24,
                    help="unique candidate evaluations")
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds training, calibration subsampling and "
                    "the strategy (one generator; identical seeds -> "
                    "identical docs)")
    ap.add_argument("--out", required=True,
                    help="path for the repro.search/v1 result JSON")
    ap.add_argument("--float-steps", type=int, default=60)
    ap.add_argument("--qat-steps", type=int, default=0,
                    help=">0: QAT-refine each accepted candidate on its "
                    "fixed plan and record acc_qat (slower)")
    ap.add_argument("--eval-n", type=int, default=256,
                    help="held-out images for the accuracy axis")
    ap.add_argument("--rounding", choices=("floor", "nearest"),
                    default="floor")
    ap.add_argument("--acc-tol", type=float, default=0.005,
                    help="accuracy loss the strategies treat as "
                    "acceptable when keeping a cheaper candidate")
    args = ap.parse_args(argv)

    cfg = SearchConfig(model=args.model, strategy=args.strategy,
                       budget=args.budget, seed=args.seed,
                       float_steps=args.float_steps,
                       qat_steps=args.qat_steps, eval_n=args.eval_n,
                       rounding=args.rounding, acc_tol=args.acc_tol)
    try:
        doc = run_search(cfg, log=print)
    except ValueError as e:
        print(f"[search_caps] {e}", file=sys.stderr)
        return 2
    save_doc(doc, args.out)

    front = doc["frontier"]
    n_bad = sum(1 for p in front if not (p["verified"] and p["checked"]))
    print(f"[search_caps] wrote {args.out}: {len(front)} frontier "
          f"points, {len(doc['evaluated'])} evaluated")
    print(format_rows(frontier_table_rows(doc)))
    if not front:
        print("[search_caps] EMPTY FRONTIER", file=sys.stderr)
        return 1
    if n_bad:
        print(f"[search_caps] {n_bad} frontier point(s) failed "
              "export verification", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
