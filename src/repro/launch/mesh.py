"""Production mesh builders.

Single pod = one v5e 16x16 pod (256 chips), axes (data, model).
Multi-pod  = 2 pods = 512 chips, axes (pod, data, model).

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run process
forces 512 host devices; the single-pod mesh then uses the first 256, which
is why construction goes through an explicit device array rather than
`jax.make_mesh` (which insists on consuming every device).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(axes=("pod", "data", "model")) -> Mesh:
    """A mesh over whatever devices exist (tests / local runs).

    Greedily factors the device count over the requested axes, model last.
    """
    devs = jax.devices()
    n = len(devs)
    shape = [1] * len(axes)
    shape[-1] = n
    return Mesh(np.asarray(devs).reshape(shape), axes)


def mesh_chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
