import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.
"""Multi-pod dry-run driver.

For every (architecture x input shape x mesh) cell:
  with mesh:
      lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(**input_specs)
      compiled = lowered.compile()
      print(compiled.memory_analysis())   # proves it fits
      print(compiled.cost_analysis())     # FLOPs/bytes for the roofline
plus collective-byte parsing of the compiled HLO.  Results land as JSON in
artifacts/dryrun/<arch>__<shape>__<mesh>.json (resumable: existing artifacts
are skipped unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all            # full grid
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, cell_is_runnable, get_config
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_cell

DEFAULT_OUT = pathlib.Path("artifacts/dryrun")


def donate_for(kind: str):
    if kind == "train":
        return (0,)       # state
    if kind == "decode":
        return (1,)       # cache
    return ()


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             outdir: pathlib.Path, force: bool = False,
             arch_override=None, quant: bool = False,
             tag: str = "") -> dict:
    outdir.mkdir(parents=True, exist_ok=True)
    suffix = ("__w8a8" if quant else "") + (f"__{tag}" if tag else "")
    path = outdir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    if path.exists() and not force:
        rec = json.loads(path.read_text())
        print(f"[skip-existing] {path.name}: {rec.get('status')}")
        return rec

    cfg = arch_override or get_config(arch)
    shape = SHAPES[shape_name]
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "quant": quant, "tag": tag, "status": "?"}
    if quant and shape.kind == "train":
        record.update(status="skipped",
                      reason="W8A8 is a serving path (PTQ after training)")
        path.write_text(json.dumps(record, indent=1))
        return record
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        path.write_text(json.dumps(record, indent=1))
        print(f"[skipped ] {arch} x {shape_name} x {mesh_kind}: {why}")
        return record

    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        with mesh:
            fn, args, in_sh, out_sh = steps.make_cell(cfg, shape, mesh,
                                                      quant=quant)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate_for(shape.kind))
            t0 = time.time()
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            print(compiled.memory_analysis())
            ca = compiled.cost_analysis()
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})
            record.update(analyze_cell(compiled, cfg, shape, mesh,
                                       mesh_kind, int8=quant))
            record.update(status="ok", lower_s=round(t1 - t0, 2),
                          compile_s=round(t2 - t1, 2))
            del compiled, lowered, jitted
    except Exception as e:  # a failing cell is a bug: record it loudly
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-4000:])
        print(f"[ERROR   ] {arch} x {shape_name} x {mesh_kind}: {e}")
    path.write_text(json.dumps(record, indent=1, default=str))
    t = record.get("terms", {})
    if record["status"] == "ok":
        print(f"[ok {record['compile_s']:7.1f}s] {arch} x {shape_name} x "
              f"{mesh_kind}: dominant={record['dominant']} "
              f"frac={record['roofline_fraction']:.3f} "
              f"hbm={record['hbm_gib_per_dev']:.2f}GiB "
              f"terms={{c:{t['compute_s']:.4f},m:{t['memory_s']:.4f},"
              f"n:{t['collective_s']:.4f}}}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quant", action="store_true",
                    help="W8A8 parameter tree (prefill/decode cells)")
    ap.add_argument("--tag", default="",
                    help="artifact suffix for perf-iteration variants")
    ap.add_argument("--kv8", action="store_true",
                    help="int8 KV cache (decode cells)")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = list(SHAPES) if (args.all or not args.shape) else (args.shape,)

    n_err = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                override = None
                if args.kv8:
                    override = get_config(arch).scaled(kv_cache_int8=True)
                rec = run_cell(arch, shape, mesh_kind, outdir, args.force,
                               quant=args.quant, tag=args.tag,
                               arch_override=override)
                n_err += rec.get("status") == "error"
    print(f"done; {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
