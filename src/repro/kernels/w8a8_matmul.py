"""Pallas TPU kernel: W8A8 matmul with per-output-channel power-of-two
rescale (the paper's quantization framework generalized to transformer
serving — beyond-paper granularity, still shift-only: DESIGN §7).

Same MXU int8 tiling as q7_matmul; the epilogue applies a per-column shift
vector (int32, one entry per output channel) instead of a scalar shift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT8_MIN, INT8_MAX = -128, 127


def _w8a8_kernel(a_ref, w_ref, sh_ref, o_ref, acc_ref, *, n_k: int,
                 rounding: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32),
                            w_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        sh = sh_ref[...].astype(jnp.int32)[None, :]
        if rounding == "nearest":
            acc = acc + jnp.where(
                sh > 0, jnp.left_shift(1, jnp.maximum(sh - 1, 0)), 0)
        acc = jnp.where(sh >= 0,
                        jnp.right_shift(acc, jnp.maximum(sh, 0)),
                        jnp.left_shift(acc, jnp.maximum(-sh, 0)))
        o_ref[...] = jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("rounding", "bm", "bn", "bk",
                                             "interpret"))
def w8a8_matmul_pallas(a, w, col_shift, *, rounding: str = "nearest",
                       bm: int = 128, bn: int = 128, bk: int = 128,
                       interpret: bool = True):
    """a [M,K] int8, w [K,N] int8, col_shift [N] int32 -> int8 [M,N]."""
    M, K = a.shape
    _, N = w.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_w8a8_kernel, n_k=n_k, rounding=rounding),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, w, col_shift)
