"""jit'd public wrappers around the Pallas kernels: padding to tile
boundaries (zeros are exact in integer arithmetic), batching, and the
interpret-mode switch (interpret=True executes the kernel body in Python —
the validation mode on this CPU container; on TPU pass interpret=False).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import q7_matmul as _q7
from repro.kernels import routing as _routing
from repro.kernels import squash as _squash
from repro.kernels import w8a8_matmul as _w8a8


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul_q7(a, b, shift: int, rounding: str = "floor",
              bm: int = 128, bn: int = 128, bk: int = 128,
              interpret: bool | None = None):
    """[M,K] x [K,N] int8 -> int8 (paper's mat_mult_q7; TPU tiling)."""
    interpret = default_interpret() if interpret is None else interpret
    M, N = a.shape[0], b.shape[1]
    bm_, bn_, bk_ = min(bm, M), min(bn, N), min(bk, a.shape[1])
    ap = _pad_to(a, bm_, bk_)
    bp = _pad_to(b, bk_, bn_)
    out = _q7.q7_matmul_pallas(ap, bp, shift=shift, rounding=rounding,
                               bm=bm_, bn=bn_, bk=bk_, interpret=interpret)
    return out[:M, :N]


def bmm_q7(a, b, shift: int, rounding: str = "floor",
           interpret: bool | None = None):
    """Batched [..., M, K] x [..., K, N] via vmap over the 2D kernel."""
    interpret = default_interpret() if interpret is None else interpret
    lead = a.shape[:-2]
    a2 = a.reshape((-1,) + a.shape[-2:])
    b2 = b.reshape((-1,) + b.shape[-2:])
    fn = lambda x, y: matmul_q7(x, y, shift, rounding, interpret=interpret)
    out = jax.vmap(fn)(a2, b2)
    return out.reshape(lead + out.shape[-2:])


def squash_q7(s, in_frac: int, out_frac: int = 7,
              interpret: bool | None = None):
    """[..., D] int8 -> int8 (paper Eq. 8); rows flattened and padded."""
    interpret = default_interpret() if interpret is None else interpret
    lead, D = s.shape[:-1], s.shape[-1]
    s2 = s.reshape(-1, D)
    R = s2.shape[0]
    br = min(256, R)
    pad = (-R) % br
    if pad:
        s2 = jnp.pad(s2, ((0, pad), (0, 0)))
    out = _squash.squash_q7_pallas(s2, in_frac=in_frac, out_frac=out_frac,
                                   block_rows=br, interpret=interpret)
    return out[:R].reshape(lead + (D,))


def squash_float(s, interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    lead, D = s.shape[:-1], s.shape[-1]
    s2 = s.reshape(-1, D)
    R = s2.shape[0]
    br = min(256, R)
    pad = (-R) % br
    if pad:
        s2 = jnp.pad(s2, ((0, pad), (0, 0)))
    out = _squash.squash_float_pallas(s2, block_rows=br, interpret=interpret)
    return out[:R].reshape(lead + (D,))


def routing_q7(u_hat, num_iters: int, caps_out_shifts, caps_out_fracs,
               agree_shifts, logit_frac: int, rounding: str = "floor",
               interpret: bool | None = None):
    """Fused dynamic routing: u_hat [B,J,I,O] int8 -> v [B,J,O] int8."""
    interpret = default_interpret() if interpret is None else interpret
    return _routing.routing_q7_pallas(
        u_hat, num_iters=num_iters,
        caps_out_shifts=tuple(caps_out_shifts),
        caps_out_fracs=tuple(caps_out_fracs),
        agree_shifts=tuple(agree_shifts), logit_frac=logit_frac,
        rounding=rounding, interpret=interpret)


def w8a8_matmul(a, w, col_shift, rounding: str = "nearest",
                interpret: bool | None = None):
    """W8A8 with per-channel shifts: [M,K] x [K,N] + [N] -> int8 [M,N]."""
    interpret = default_interpret() if interpret is None else interpret
    M, N = a.shape[0], w.shape[1]
    bm_, bn_, bk_ = min(128, M), min(128, N), min(128, a.shape[1])
    ap = _pad_to(a, bm_, bk_)
    wp = _pad_to(w, bk_, bn_)
    shp = col_shift
    p = (-N) % bn_
    if p:
        shp = jnp.pad(col_shift, (0, p))
    out = _w8a8.w8a8_matmul_pallas(ap, wp, shp, rounding=rounding,
                                   bm=bm_, bn=bn_, bk=bk_,
                                   interpret=interpret)
    return out[:M, :N]
