"""Pallas TPU kernel: integer squash activation (paper Eq. 8 + Alg. 4).

Row-blocked over the capsule axis: each grid step loads a [block_rows, D]
tile of int8 capsule vectors into VMEM, computes the int32 sum of squares,
runs the fixed-iteration Newton-Raphson integer sqrt on the VPU, applies
the guarded power-of-two ratio, and writes int8 back.  D (the capsule
dimension, 4-8 in the paper) is far below the 128-lane width; the ops.py
wrapper keeps rows as the lane dimension by blocking many rows per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.int8_ops import SQUASH_GUARD_BITS

INT8_MIN, INT8_MAX = -128, 127


def _isqrt(n):
    x0 = jnp.maximum(n // 2, 1)

    def body(_, x):
        nxt = (x + n // jnp.maximum(x, 1)) // 2
        return jnp.where(nxt < x, nxt, x)

    x = jax.lax.fori_loop(0, 32, body, x0)
    return jnp.where(n <= 1, n, x)


def _squash_kernel(s_ref, o_ref, *, in_frac: int, out_frac: int):
    s = s_ref[...].astype(jnp.int32)
    Q = jnp.sum(s * s, axis=-1, keepdims=True)
    S = _isqrt(Q)
    P = SQUASH_GUARD_BITS
    shift = out_frac - in_frac + P
    num = jnp.left_shift(S, shift) if shift >= 0 \
        else jnp.right_shift(S, -shift)
    den = (1 << in_frac) + jnp.right_shift(Q, in_frac)
    ratio = num // jnp.maximum(den, 1)
    v = jnp.right_shift(ratio * s, P)
    o_ref[...] = jnp.clip(v, INT8_MIN, INT8_MAX).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("in_frac", "out_frac",
                                             "block_rows", "interpret"))
def squash_q7_pallas(s, *, in_frac: int, out_frac: int = 7,
                     block_rows: int = 256, interpret: bool = True):
    """s int8 [R, D] -> int8 [R, D] (rows padded by the ops wrapper)."""
    R, D = s.shape
    br = min(block_rows, R)
    assert R % br == 0
    return pl.pallas_call(
        functools.partial(_squash_kernel, in_frac=in_frac,
                          out_frac=out_frac),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), jnp.int8),
        interpret=interpret,
    )(s)


def _squash_float_kernel(s_ref, o_ref):
    s = s_ref[...].astype(jnp.float32)
    sq = jnp.sum(s * s, axis=-1, keepdims=True)
    o_ref[...] = ((sq / (1.0 + sq)) * s * jax.lax.rsqrt(sq + 1e-7)) \
        .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def squash_float_pallas(s, *, block_rows: int = 256, interpret: bool = True):
    """Float squash (Eq. 1) for the fp training path."""
    R, D = s.shape
    br = min(block_rows, R)
    assert R % br == 0
    return pl.pallas_call(
        _squash_float_kernel,
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), s.dtype),
        interpret=interpret,
    )(s)
