"""Pallas TPU kernel: int8 x int8 -> int32-accumulated matmul with
power-of-two requantization (the `mat_mult_q7` family, TPU-native).

Hardware adaptation (DESIGN.md §2): the paper's SIMD/transposed-B variants
are MCU register-blocking strategies; on TPU the equivalent decisions are
(a) MXU-native int8 pairs (jnp.dot with preferred_element_type=int32 — the
MXU runs int8 at 2x the bf16 rate), (b) BlockSpec tiles sized to VMEM and
aligned to the 128-lane MXU, (c) the K reduction as the innermost
("arbitrary") grid dimension accumulating into an int32 VMEM scratch, and
(d) the power-of-two rescale as a vector shift in the epilogue — no FP
multiplier anywhere, exactly the paper's Qm.n contract.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

INT8_MIN, INT8_MAX = -128, 127


def _q7_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int,
                      shift: int, rounding: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32),
                            b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if shift > 0:
            if rounding == "nearest":
                acc = acc + (1 << (shift - 1))
            acc = jnp.right_shift(acc, shift)
        elif shift < 0:
            acc = jnp.left_shift(acc, -shift)
        o_ref[...] = jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("shift", "rounding", "bm", "bn",
                                             "bk", "interpret"))
def q7_matmul_pallas(a, b, *, shift: int, rounding: str = "floor",
                     bm: int = 128, bn: int = 128, bk: int = 128,
                     interpret: bool = True):
    """a [M,K] int8, b [K,N] int8 -> int8 [M,N].  Caller pads to tiles
    (zeros are exact in integer arithmetic)."""
    M, K = a.shape
    _, N = b.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_q7_matmul_kernel, n_k=n_k, shift=shift,
                          rounding=rounding),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int8),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, b)
