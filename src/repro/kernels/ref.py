"""Pure-jnp oracles for every Pallas kernel in this package.

The integer semantics live in repro.quant.int8_ops (the quantization
framework and the kernels must agree bit-for-bit); this module re-exports
them under kernel-facing names and adds the per-channel W8A8 reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.int8_ops import (  # noqa: F401  (re-exported oracles)
    INT8_MAX, INT8_MIN, add_q7, conv2d_q7, isqrt_newton, matmul_q7,
    matmul_q7_acc, relu_q7, rshift_sat8, softmax_q7, softmax_q7_precise,
    squash_q7,
)
from repro.core.routing import squash as squash_float_ref  # noqa: F401


def w8a8_matmul_ref(a, w, col_shift, rounding: str = "nearest"):
    """[M,K] int8 x [K,N] int8 -> int8 [M,N] with per-output-channel
    power-of-two shifts (beyond-paper granularity; still shift-only)."""
    acc = jax.lax.dot_general(a, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    sh = col_shift.astype(jnp.int32)[None, :]
    if rounding == "nearest":
        acc = acc + jnp.where(sh > 0, jnp.left_shift(1, jnp.maximum(sh - 1, 0)), 0)
    acc = jnp.where(sh >= 0, jnp.right_shift(acc, jnp.maximum(sh, 0)),
                    jnp.left_shift(acc, jnp.maximum(-sh, 0)))
    return jnp.clip(acc, INT8_MIN, INT8_MAX).astype(jnp.int8)


def routing_q7_ref(u_hat, num_iters: int, caps_out_shifts, caps_out_fracs,
                   agree_shifts, logit_frac: int, rounding: str = "floor",
                   softmax_impl: str = "q7"):
    """Fused dynamic-routing oracle (Alg. 5 inner loop, int8).

    u_hat int8 [B, J, I, O] -> v int8 [B, J, O] (Q0.7).
    """
    from repro.nn.variants import REGISTRY
    from repro.quant import int8_ops as q
    B, J, I, O = u_hat.shape
    sm = REGISTRY.get("softmax", softmax_impl).q7
    b = jnp.zeros((B, J, I), jnp.int8)
    v = None
    for r in range(num_iters):
        c = sm(b.swapaxes(1, 2), in_frac=logit_frac).swapaxes(1, 2)
        acc = jnp.einsum("bji,bjio->bjo", c.astype(jnp.int32),
                         u_hat.astype(jnp.int32))
        s_q = q.rshift_sat8(acc, caps_out_shifts[r], rounding)
        v = q.squash_q7(s_q, in_frac=caps_out_fracs[r], out_frac=7)
        if r < num_iters - 1:
            acc = jnp.einsum("bjio,bjo->bji", u_hat.astype(jnp.int32),
                             v.astype(jnp.int32))
            a = q.rshift_sat8(acc, agree_shifts[r], rounding)
            b = q.add_q7(b, a)
    return v
