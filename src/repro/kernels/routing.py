"""Pallas TPU kernel: FUSED int8 dynamic routing (beyond-paper, DESIGN §7).

The paper's capsule layer round-trips u_hat / b / c / v through memory
between its four support functions on every routing iteration (Alg. 5).
On TPU the whole routing state is tiny — u_hat for one sample is
J x I x O int8 (60 KB for the paper's MNIST layer) and b/c are J x I —
so the entire r-iteration loop fits in VMEM.  This kernel grids over the
batch, holds u_hat resident, and runs softmax -> weighted-sum -> squash ->
agreement entirely on-chip, eliminating (2r-1) HBM round-trips of u_hat.

Integer semantics match repro.kernels.ref.routing_q7_ref bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.int8_ops import SQUASH_GUARD_BITS

INT8_MIN, INT8_MAX = -128, 127


def _isqrt(n):
    x0 = jnp.maximum(n // 2, 1)

    def body(_, x):
        nxt = (x + n // jnp.maximum(x, 1)) // 2
        return jnp.where(nxt < x, nxt, x)

    return jnp.where(n <= 1, n, jax.lax.fori_loop(0, 32, body, x0))


def _squash_rows(s32, in_frac: int, out_frac: int = 7):
    Q = jnp.sum(s32 * s32, axis=-1, keepdims=True)
    S = _isqrt(Q)
    P = SQUASH_GUARD_BITS
    shift = out_frac - in_frac + P
    num = jnp.left_shift(S, shift) if shift >= 0 \
        else jnp.right_shift(S, -shift)
    den = (1 << in_frac) + jnp.right_shift(Q, in_frac)
    ratio = num // jnp.maximum(den, 1)
    return jnp.clip(jnp.right_shift(ratio * s32, P), INT8_MIN, INT8_MAX)


def _softmax_q7_cols(b32, in_frac: int):
    """Shift-based integer softmax over axis 0 (the J axis of b [J, I])."""
    m = jnp.max(b32, axis=0, keepdims=True)
    e = jnp.maximum(jnp.right_shift(b32 - m, in_frac), -20)
    p = jnp.left_shift(jnp.ones_like(e), 20 + e)
    tot = jnp.sum(p, axis=0, keepdims=True)
    return jnp.clip(jnp.left_shift(p, 7) // jnp.maximum(tot, 1), 0, INT8_MAX)


def _rshift_sat8(acc, shift: int, rounding: str):
    if shift > 0:
        if rounding == "nearest":
            acc = acc + (1 << (shift - 1))
        acc = jnp.right_shift(acc, shift)
    elif shift < 0:
        acc = jnp.left_shift(acc, -shift)
    return jnp.clip(acc, INT8_MIN, INT8_MAX)


def _routing_kernel(u_ref, v_ref, *, num_iters, caps_out_shifts,
                    caps_out_fracs, agree_shifts, logit_frac, rounding):
    u = u_ref[0].astype(jnp.int32)              # [J, I, O] resident in VMEM
    J, I, O = u.shape
    b = jnp.zeros((J, I), jnp.int32)
    v = jnp.zeros((J, O), jnp.int32)
    for r in range(num_iters):
        c = _softmax_q7_cols(b, logit_frac)                      # [J, I]
        s = jnp.einsum("ji,jio->jo", c, u,
                       preferred_element_type=jnp.int32)
        s_q = _rshift_sat8(s, caps_out_shifts[r], rounding)
        v = _squash_rows(s_q, in_frac=caps_out_fracs[r])         # [J, O]
        if r < num_iters - 1:
            a = jnp.einsum("jio,jo->ji", u, v,
                           preferred_element_type=jnp.int32)
            a = _rshift_sat8(a, agree_shifts[r], rounding)
            b = jnp.clip(b + a, INT8_MIN, INT8_MAX)              # q7 add
    v_ref[0] = v.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=(
    "num_iters", "caps_out_shifts", "caps_out_fracs", "agree_shifts",
    "logit_frac", "rounding", "interpret"))
def routing_q7_pallas(u_hat, *, num_iters: int, caps_out_shifts: tuple,
                      caps_out_fracs: tuple, agree_shifts: tuple,
                      logit_frac: int, rounding: str = "floor",
                      interpret: bool = True):
    """u_hat int8 [B, J, I, O] -> v int8 [B, J, O], all r iterations fused."""
    B, J, I, O = u_hat.shape
    return pl.pallas_call(
        functools.partial(
            _routing_kernel, num_iters=num_iters,
            caps_out_shifts=caps_out_shifts, caps_out_fracs=caps_out_fracs,
            agree_shifts=agree_shifts, logit_frac=logit_frac,
            rounding=rounding),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, J, I, O), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, J, O), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, J, O), jnp.int8),
        interpret=interpret,
    )(u_hat)
