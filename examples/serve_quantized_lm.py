"""Serve a small LM with batched requests, float vs W8A8 side by side.

    PYTHONPATH=src python examples/serve_quantized_lm.py --arch stablelm_3b

The paper's Qm.n power-of-two int8 framework generalized to transformer
serving: per-output-channel int8 weights + dynamic per-tensor int8
activations (repro.quant.lm_quant).  Prints weight-bytes reduction, decode
throughput for both paths, and the greedy-token agreement between them.
"""
import sys
sys.path.insert(0, "src")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.data.synthetic import TokenTask
from repro.launch.train import reduced
from repro.models.transformer import build_model, decode_alloc
from repro.quant.lm_quant import quantize_lm_params, quantized_bytes


def run_wave(model, params, prompts, gen, alloc, extra):
    batch = dict(extra, inputs=prompts)
    prefill = jax.jit(lambda p, b: model.prefill(p, b, alloc=alloc))
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_pre = time.perf_counter() - t0
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    toks = [np.asarray(tok)]
    pos0 = prompts.shape[1]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(pos0 + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks.append(np.asarray(tok))
    jax.block_until_ready(logits)
    return np.concatenate(toks, 1), t_pre, time.perf_counter() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="stablelm_3b")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), d_model=args.d_model)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    fp_bytes = sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(params))
    qparams = quantize_lm_params(params)
    print(f"== {args.arch} (reduced d_model={args.d_model}): "
          f"weights {fp_bytes/2**20:.1f} MiB bf16 -> "
          f"{quantized_bytes(qparams)/2**20:.1f} MiB W8A8")

    prompts = jnp.asarray(
        TokenTask(cfg.vocab_size, args.prompt_len, seed=3)
        .batch(0, args.requests)["inputs"])
    alloc = decode_alloc(args.prompt_len + args.gen)
    extra = {}
    if cfg.family == "vlm":
        extra["prefix_embeds"] = jnp.zeros(
            (args.requests, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    if cfg.is_encoder_decoder:
        extra["frames"] = jnp.zeros(
            (args.requests, args.prompt_len, cfg.d_model), jnp.float32)

    g_f, pre_f, dec_f = run_wave(model, params, prompts, args.gen, alloc,
                                 extra)
    g_q, pre_q, dec_q = run_wave(model, qparams, prompts, args.gen, alloc,
                                 extra)
    agree = (g_f == g_q).mean()
    n_tok = args.requests * (args.gen - 1)
    print(f"  float: prefill {pre_f*1e3:7.1f} ms, decode "
          f"{n_tok/max(dec_f,1e-9):7.1f} tok/s")
    print(f"  w8a8 : prefill {pre_q*1e3:7.1f} ms, decode "
          f"{n_tok/max(dec_q,1e-9):7.1f} tok/s  "
          f"(CPU interpret; on TPU the int8 MXU path is 2x bf16)")
    print(f"  greedy-token agreement float vs w8a8: {agree:.3f}")


if __name__ == "__main__":
    main()
