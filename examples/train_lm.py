"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with checkpoint/restart (deliverable (b)).

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --params 25e6 --steps 100

Thin wrapper over repro.launch.train with a config sized to the requested
parameter count.  Kill it mid-run and re-run: it resumes from the atomic
checkpoint (repro.ckpt) on the exact batch index.
"""
import sys
sys.path.insert(0, "src")

import argparse

from repro.configs.base import ModelConfig


def sized_config(target_params: float) -> ModelConfig:
    """Dense LM sized to ~target_params (12 * L * d^2 + 2 V d)."""
    V = 8192
    best = None
    for d in (256, 384, 512, 640, 768, 1024):
        for L in (2, 4, 6, 8, 12, 16):
            n = 12 * L * d * d + 2 * V * d
            if best is None or abs(n - target_params) < abs(best[0]
                                                            - target_params):
                best = (n, d, L)
    n, d, L = best
    print(f"[config] d_model={d} layers={L}  (~{n/1e6:.1f}M params)")
    return ModelConfig(
        name="train_lm_100m", family="dense", num_layers=L, d_model=d,
        num_heads=8, num_kv_heads=4, head_dim=d // 8, d_ff=4 * d,
        vocab_size=V)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", type=float, default=100e6)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = sized_config(args.params)

    # reuse the production training loop with an explicit config
    import jax, jax.numpy as jnp
    from repro import ckpt
    from repro.data.synthetic import TokenTask
    from repro.dist.fault import StepTimer, run_with_restarts
    from repro.models.transformer import build_model
    from repro.optim.adam import AdamW, cosine_schedule

    model = build_model(cfg)
    # short-run schedule (the production default warms up over 2000 steps)
    opt = AdamW(lr=cosine_schedule(1e-3, warmup=20, total=args.steps),
                weight_decay=0.01, clip_norm=1.0)
    task = TokenTask(cfg.vocab_size, args.seq, seed=11)

    @jax.jit
    def train_step(state, batch):
        (loss, m), g = jax.value_and_grad(
            lambda p: model.train_loss(p, batch), has_aux=True)(
                state["params"])
        p, o, om = opt.update(g, state["opt"], state["params"])
        return ({"params": p, "opt": o, "step": state["step"] + 1},
                dict(m, **om))

    def make_and_run(attempt):
        params = model.init(jax.random.key(0))
        state = {"params": params, "opt": opt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        start = 0
        got = ckpt.restore_latest(args.ckpt_dir, state)
        if got[0] is not None:
            start, state = got
            print(f"[resume] step {start}")
        timer = StepTimer()
        for i in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, task.batch(i, args.batch))
            timer.start()
            state, m = train_step(state, batch)
            jax.block_until_ready(m["loss"])   # sync for honest step timing
            dt = timer.stop()
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d}: loss={float(m['loss']):.4f} "
                      f"lr={float(m['lr']):.2e} {dt*1e3:6.0f} ms/step")
            if (i + 1) % 50 == 0:
                ckpt.save(args.ckpt_dir, i + 1, state)
                ckpt.gc_keep_n(args.ckpt_dir, keep=2)
        ckpt.save(args.ckpt_dir, args.steps, state)
        return args.steps

    run_with_restarts(make_and_run, max_restarts=2)
    print("train_lm done")


if __name__ == "__main__":
    main()
