"""End-to-end CapsNet driver: train (float) for a few hundred steps, then
post-training-quantize and reproduce the paper's Table 2 —
memory-footprint saving and float-vs-int8 accuracy delta.

    PYTHONPATH=src python examples/train_capsnet.py --dataset mnist --steps 250
    PYTHONPATH=src python examples/train_capsnet.py --dataset smallnorb
    PYTHONPATH=src python examples/train_capsnet.py --dataset cifar10

Both rounding modes are reported: "floor" is the paper/CMSIS `>> shift`
truncation; "nearest" adds the half-LSB (beyond-paper; see EXPERIMENTS.md
for why truncation bias amplifies through the 1024-capsule coupling sum).
"""
import sys
sys.path.insert(0, "src")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capsnet as C
from repro.data.synthetic import make_image_dataset
from repro.optim.adam import AdamW
from repro.quant import ptq

DATASETS = {"mnist": C.MNIST, "smallnorb": C.SMALLNORB,
            "cifar10": C.CIFAR10}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=list(DATASETS), default="mnist")
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--eval-n", type=int, default=768)
    args = ap.parse_args()

    cfg = DATASETS[args.dataset]
    print(f"== {cfg.name}  (paper Table 1 config; input "
          f"{cfg.input_shape}, {cfg.num_input_caps} input capsules)")
    params = C.init_capsnet(jax.random.key(0), cfg)
    opt = AdamW(lr=cfg.lr, clip_norm=0.0, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        def loss_fn(p):
            v = C.capsnet_forward(p, x, cfg)
            return C.margin_loss(v, y, cfg.num_classes), v
        (loss, v), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, state, _ = opt.update(g, state, params)
        return params, state, loss, C.accuracy(v, y)

    t0 = time.time()
    for i in range(args.steps):
        x, y = make_image_dataset(args.dataset, args.batch, seed=i)
        params, state, loss, acc = step(params, state, jnp.asarray(x),
                                        jnp.asarray(y))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"  step {i:4d}: loss={float(loss):.4f} "
                  f"acc={float(acc):.3f}  ({time.time()-t0:.0f}s)")

    # --- evaluation: Table 2 analogue -------------------------------------
    tx, ty = make_image_dataset(args.dataset, args.eval_n, seed=999_999)
    tx, ty = jnp.asarray(tx), jnp.asarray(ty)
    calib = jnp.asarray(
        make_image_dataset(args.dataset, 256, seed=555_555)[0])

    acc_f = ptq.eval_float(params, cfg, tx, ty)
    rows = []
    for rounding in ("floor", "nearest"):
        qm = ptq.quantize_capsnet(params, cfg, calib, rounding=rounding)
        acc_q = ptq.eval_q7(qm, tx, ty)
        rep = ptq.footprint_report(params, qm)
        rows.append((rounding, acc_q, rep))

    print(f"\n  {'':14s}{'fp32':>10s}{'int8/floor':>12s}{'int8/nearest':>14s}")
    print(f"  {'accuracy':14s}{acc_f:10.4f}{rows[0][1]:12.4f}"
          f"{rows[1][1]:14.4f}")
    print(f"  {'acc loss':14s}{'-':>10s}{acc_f-rows[0][1]:12.4f}"
          f"{acc_f-rows[1][1]:14.4f}")
    rep = rows[1][2]
    print(f"  footprint: {rep['fp32_kb']:.2f} KB -> {rep['int8_kb']:.2f} KB"
          f"  (saving {rep['saving_pct']:.2f} %; paper: 74.99 %)")
    print(f"  paper accuracy-loss band: 0.07 % – 0.18 %")


if __name__ == "__main__":
    main()
