"""End-to-end CapsNet driver on the typed training subsystem: train
(float) with `repro.captrain.CapsTrainer`, then reproduce the paper's
Table 2 — memory-footprint saving and float-vs-int8 accuracy delta —
for plain PTQ and for QAT fine-tuning.

    PYTHONPATH=src python examples/train_capsnet.py --dataset mnist --steps 250
    PYTHONPATH=src python examples/train_capsnet.py --dataset edge_tiny \
        --steps 120 --qat-steps 40
    PYTHONPATH=src python examples/train_capsnet.py --dataset cifar10

Both rounding modes are reported: "floor" is the paper/CMSIS `>> shift`
truncation; "nearest" adds the half-LSB (beyond-paper; truncation bias
amplifies through the 1024-capsule coupling sum, which is also why QAT
under floor rounding recovers the most accuracy — see
src/repro/captrain/README.md for the harness docs).
"""
import sys
sys.path.insert(0, "src")

import argparse
import time

from repro.captrain import TrainConfig, format_rows, table2_rows
from repro.nn.config import CIFAR10, MNIST, SMALLNORB
from repro.serving.registry import EDGE_TINY

DATASETS = {"mnist": MNIST, "smallnorb": SMALLNORB, "cifar10": CIFAR10,
            "edge_tiny": EDGE_TINY}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=list(DATASETS), default="mnist")
    ap.add_argument("--steps", type=int, default=250,
                    help="float training steps")
    ap.add_argument("--qat-steps", type=int, default=60,
                    help="fake-quant fine-tuning steps per rounding mode")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--eval-n", type=int, default=768)
    ap.add_argument("--lr", type=float, default=None,
                    help="override the config's learning rate")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint/resume directory (repro.ckpt)")
    args = ap.parse_args()

    cfg = DATASETS[args.dataset]
    tcfg = TrainConfig(
        dataset=args.dataset, batch=args.batch,
        lr=args.lr if args.lr is not None else cfg.lr,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50 if args.ckpt_dir else 0)
    print(f"== {cfg.name}  (input {cfg.input_shape}, "
          f"{cfg.num_input_caps} input capsules)")

    t0 = time.time()
    rows = table2_rows(cfg, tcfg, float_steps=args.steps,
                       qat_steps=args.qat_steps, eval_n=args.eval_n,
                       log=print)
    print(f"\n== Table 2 analogue ({time.time() - t0:.0f}s)")
    print(format_rows(rows))


if __name__ == "__main__":
    main()
