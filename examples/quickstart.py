"""Quickstart: quantize a CapsNet to int8 with the typed pipeline API,
verify the Pallas kernels bit-for-bit, serve batched requests, then
export the model as a bit-exact MCU artifact.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's MNIST CapsNet (Table 1) as a `repro.nn.CapsPipeline`,
post-training-quantizes it with the Qm.n power-of-two framework
(Alg. 6/7), checks the jnp oracle against the Pallas kernel backend,
prints the footprint report (Table 2 analogue), and finally drives the
quantized model through `repro.serving.CapsServeEngine` — the bucketed
micro-batch scheduler that turns one-shot int8 inference into a service.
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import make_image_dataset
from repro.nn import MNIST, CapsPipeline
from repro.quant import ptq
from repro.serving import CapsServeEngine, ModelRegistry


def main():
    cfg = MNIST
    print(f"== {cfg.name}: conv{cfg.conv_filters} -> primary caps "
          f"{cfg.pcap_caps}x{cfg.pcap_dim} -> class caps "
          f"{cfg.num_classes}x{cfg.caps_dim} (routings={cfg.routings})")
    print(f"   capsule layer geometry: {cfg.num_classes}x"
          f"{cfg.num_input_caps}x{cfg.caps_dim}x{cfg.pcap_dim} "
          f"(paper Table 7 'L')")

    pipe = CapsPipeline.from_config(cfg)
    params = pipe.init(jax.random.key(0))

    # --- post-training quantization (paper §4, Alg. 6/7) ------------------
    calib = jnp.asarray(make_image_dataset("mnist", 64, seed=1)[0])
    qnet = pipe.quantize(params, calib, rounding="nearest")
    rep = ptq.footprint_report(params, qnet)
    print(f"   footprint: fp32 {rep['fp32_kb']:.2f} KB -> int8 "
          f"{rep['int8_kb']:.2f} KB  (saving {rep['saving_pct']:.2f} %)")
    caps_plan = qnet.plan["caps"]
    print(f"   caps plan: uhat_shift={caps_plan.uhat_shift} "
          f"logit_frac={caps_plan.logit_frac} "
          f"caps_out_shifts={caps_plan.caps_out_shifts} "
          f"variants={qnet.variants.tag}")

    # --- int8 inference: jnp oracle vs Pallas kernel backend --------------
    x = jnp.asarray(make_image_dataset("mnist", 4, seed=2)[0])
    xq = qnet.quantize_input(x)
    v_ref = qnet.forward(xq)                       # jnp oracle semantics
    v_kern = qnet.with_backend("pallas").forward(xq)   # fused routing
    match = bool(jnp.all(v_ref == v_kern))
    print(f"   fused Pallas routing kernel == int8 oracle: {match}")
    assert match
    print(f"   class lengths (sample 0): "
          f"{np.asarray(qnet.class_lengths(v_ref))[0].round(3)}")

    # --- serve it: bucketed micro-batch waves -----------------------------
    registry = ModelRegistry(specs={})
    registry.install("mnist", qnet)
    engine = CapsServeEngine(registry, buckets=(1, 4, 8))
    engine.warmup("mnist")
    images = make_image_dataset("mnist", 6, seed=3)[0]
    engine.submit_many(images, "mnist")
    done = engine.drain()
    print(f"   served preds: {[c.pred for c in done]} "
          f"(wave buckets: {sorted({c.bucket for c in done})})")
    print(f"   {engine.metrics.report()}")
    # engine waves are bit-identical to direct QuantCapsNet.forward
    v_direct = np.asarray(qnet.forward(qnet.quantize_input(
        jnp.asarray(images))))
    assert all(np.array_equal(c.v_q, v_direct[c.rid]) for c in done)

    # --- export it: the paper's actual endgame (repro.edge) ---------------
    import tempfile

    from repro.edge import export_artifacts
    with tempfile.TemporaryDirectory() as d:
        result = export_artifacts(qnet, d, stem="mnist_L",
                                  verify_images=np.asarray(x))
        r = result["report"]
        print(f"   MCU artifact: flash {r['flash_bytes'] / 1000:.1f} KB, "
              f"RAM {r['ram_bytes'] / 1000:.1f} KB "
              f"(arena {r['arena_bytes']} B), "
              f"{r['saving_pct']:.1f}% below fp32 — VM re-verified "
              f"bit-exact on {result['verified']} images")
    print("quickstart OK")


if __name__ == "__main__":
    main()
