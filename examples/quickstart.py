"""Quickstart: quantize a CapsNet to int8 and run the paper's kernels.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's MNIST CapsNet (Table 1), post-training-quantizes it with
the Qm.n power-of-two framework (Alg. 6/7), and runs one int8 inference
through (a) the exact jnp semantics and (b) the Pallas kernels — verifying
they agree bit-for-bit — then prints the footprint report (Table 2
analogue).
"""
import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capsnet as C
from repro.core.capsnet_q7 import qcapsnet_forward, qclass_lengths, pcap_q7
from repro.data.synthetic import make_image_dataset
from repro.kernels import ops as kops
from repro.quant import int8_ops as q, ptq


def main():
    cfg = C.MNIST
    print(f"== {cfg.name}: conv{cfg.conv_filters} -> primary caps "
          f"{cfg.pcap_caps}x{cfg.pcap_dim} -> class caps "
          f"{cfg.num_classes}x{cfg.caps_dim} (routings={cfg.routings})")
    print(f"   capsule layer geometry: {cfg.num_classes}x"
          f"{cfg.num_input_caps}x{cfg.caps_dim}x{cfg.pcap_dim} "
          f"(paper Table 7 'L')")

    params = C.init_capsnet(jax.random.key(0), cfg)

    # --- post-training quantization (paper §4) ---------------------------
    calib = jnp.asarray(make_image_dataset("mnist", 64, seed=1)[0])
    qm = ptq.quantize_capsnet(params, cfg, calib, rounding="nearest")
    rep = ptq.footprint_report(params, qm)
    print(f"   footprint: fp32 {rep['fp32_kb']:.2f} KB -> int8 "
          f"{rep['int8_kb']:.2f} KB  (saving {rep['saving_pct']:.2f} %)")
    print(f"   shift table: { {k: v for k, v in list(qm.shifts.items())[:6]} } ...")

    # --- int8 inference: jnp oracle vs Pallas kernels ---------------------
    x, _ = make_image_dataset("mnist", 4, seed=2)
    xq = ptq.quantize_input(jnp.asarray(x), qm.shifts["input_frac"])
    v_ref = qcapsnet_forward(qm, xq)

    h = xq
    for i in range(len(cfg.conv_filters)):
        h = q.conv2d_q7(h, qm.weights[f"conv{i}"]["w"],
                        qm.weights[f"conv{i}"]["b"],
                        qm.shifts[f"conv{i}_out_shift"],
                        qm.shifts[f"conv{i}_bias_shift"],
                        stride=cfg.conv_strides[i], rounding=qm.rounding)
        h = q.relu_q7(h)
    u = pcap_q7(qm, h)
    acc = jnp.einsum("jiod,bid->bjio",
                     qm.weights["caps"]["W"].astype(jnp.int32),
                     u.astype(jnp.int32))
    u_hat = q.rshift_sat8(acc, qm.shifts["uhat_shift"], qm.rounding)
    v_kern = kops.routing_q7(
        u_hat, num_iters=cfg.routings,
        caps_out_shifts=tuple(qm.shifts[f"caps_out_shift_{r}"]
                              for r in range(cfg.routings)),
        caps_out_fracs=tuple(qm.shifts[f"caps_out_frac_{r}"]
                             for r in range(cfg.routings)),
        agree_shifts=tuple(qm.shifts[f"agree_shift_{r}"]
                           for r in range(cfg.routings - 1)),
        logit_frac=qm.shifts["logit_frac"], rounding=qm.rounding)
    match = bool(jnp.all(v_ref == v_kern))
    print(f"   fused Pallas routing kernel == int8 oracle: {match}")
    assert match
    print(f"   class lengths (sample 0): "
          f"{np.asarray(qclass_lengths(qm, v_ref))[0].round(3)}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
