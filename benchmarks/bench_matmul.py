"""Paper Tables 3 & 4 analogue: int8 matrix-multiplication variants.

The paper times mat_mult_q7{,_trb,_simd} on a 20x30 @ 30x40 int8 matmul
(Cortex-M: 1.20-6.35 ms; GAP-8 octa-core: 0.31-0.64 ms).  Here the
variants are the TPU-native decisions: the XLA int8 dot (oracle), the
Pallas kernel in interpret mode (correctness harness; on real TPU the MXU
runs this at 2x bf16 rate), and the fp32 baseline the paper compares
against.  CPU wall times are indicative; the derived column reports
MAC/us.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import util
from benchmarks.util import csv_row, time_call
from repro.kernels import ops, ref

SHAPES = [(20, 30, 40), (128, 128, 128), (256, 256, 256)]


def main():
    rng = np.random.default_rng(0)
    for (M, K, N) in SHAPES[:1] if util.SMOKE else SHAPES:
        a = jnp.asarray(rng.integers(-128, 128, (M, K)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (K, N)), jnp.int8)
        af = a.astype(jnp.float32)
        bf = b.astype(jnp.float32)
        macs = M * K * N

        f = jax.jit(lambda x, y: ref.matmul_q7(x, y, 7))
        us = time_call(f, a, b)
        csv_row(f"matmul_q7_xla_{M}x{K}x{N}", us, f"{macs/us:.0f}MAC/us")

        us = time_call(lambda x, y: ops.matmul_q7(x, y, 7), a, b)
        csv_row(f"matmul_q7_pallas_interp_{M}x{K}x{N}", us,
                f"{macs/us:.0f}MAC/us")

        g = jax.jit(lambda x, y: x @ y)
        us = time_call(g, af, bf)
        csv_row(f"matmul_fp32_baseline_{M}x{K}x{N}", us,
                f"{macs/us:.0f}MAC/us")


if __name__ == "__main__":
    main()
