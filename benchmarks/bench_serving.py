"""Serving-engine benchmark: batched micro-batch waves vs a batch-size-1
request loop on the same quantized model.

The paper stops at per-layer kernel latency; this section measures the
deployment quantity the ROADMAP asks for — sustained images/sec through
`repro.serving.CapsServeEngine`.  Two rows per model:

  serve_b1_*       the naive loop: every request its own bucket-1 wave
  serve_batched_*  bucketed waves (requests padded up to the buckets)

Models: `edge_tiny@jnp` — the deep-edge micro geometry where a batch-1
loop is dominated by per-request dispatch/sync overhead, i.e. the regime
the wave scheduler exists for (this is where the >=2x batched win lives)
— and, outside smoke mode, the paper's MNIST "L" geometry, whose int8
routing is memory-bound on the CPU validation substrate, so its wall
clock mostly shows that batching does not cost anything there (on the
paper's target parts the win returns because kernel-launch overhead per
request is the dominating term — same argument as the fused-routing
rows in bench_capsule_layer).

derived carries img/s; the batched row adds speedup over b1, p95 request
latency, and wave occupancy.  Executables are warmed before timing so
both rows pay zero compiles.
"""
from benchmarks import util
from benchmarks.util import csv_row
from repro.serving import ModelRegistry, serve_window


def main():
    if util.SMOKE:
        cases = [("edge_tiny@jnp", 16, (1, 8))]
    else:
        cases = [("edge_tiny@jnp", 64, (1, 8, 32)),
                 ("mnist@jnp", 32, (1, 8, 32))]
    registry = ModelRegistry()
    for model_id, n_req, buckets in cases:
        images = registry.specs[model_id].images(n_req, seed=5)

        _, b1_wall = serve_window(registry, (1,), images, model_id)
        csv_row(f"serve_b1_{model_id}", b1_wall * 1e6 / n_req,
                f"{n_req / b1_wall:.1f}img/s")

        engine, wall = serve_window(registry, buckets, images, model_id)
        s = engine.metrics.summary()
        csv_row(f"serve_batched_{model_id}", wall * 1e6 / n_req,
                f"{s['images_per_s']:.1f}img/s_speedup="
                f"{b1_wall / wall:.1f}x_p95={s['p95_ms']:.1f}ms"
                f"_occ={s['occupancy']:.2f}",
                images_per_s=s["images_per_s"],
                occupancy=s["occupancy"], p95_ms=s["p95_ms"],
                speedup=b1_wall / wall, waves=s["waves"])


if __name__ == "__main__":
    main()
