"""Search benchmark: the Pareto frontier as a measured, gated artifact.

Runs a small coordinate search on edge_tiny and records ONE stable row
(`search_frontier`) so baseline comparison never chases frontier
membership across machines — the per-point detail lives in the
repro.search/v1 doc, not here.  The row's `acc` figure (best frontier
accuracy) is baseline-gated; the section figures carry the validator's
hard invariants: every frontier point statically clean
(checker_findings == 0) and mutually non-dominated
(frontier_dominated_pairs == 0).

Smoke mode shrinks training and the budget (CI bit-rot check); the full
run uses the search CLI's defaults.
"""
import time

from benchmarks import util
from benchmarks.util import csv_row
from repro.search import SearchConfig, dominated_pairs, run_search


def main():
    budget, f_steps, eval_n = (8, 8, 64) if util.SMOKE else (24, 60, 256)
    cfg = SearchConfig(model="edge_tiny", strategy="coordinate",
                       budget=budget, float_steps=f_steps, eval_n=eval_n,
                       seed=0)
    t0 = time.perf_counter()
    doc = run_search(cfg)
    us = (time.perf_counter() - t0) * 1e6

    front = doc["frontier"]
    best_acc = max((p["metrics"]["acc"] for p in front), default=0.0)
    findings = sum(p["metrics"].get("checker_findings", 0) for p in front)
    unverified = sum(1 for p in front
                     if not (p["verified"] and p["checked"]))
    base = doc["baseline"]["metrics"]
    best_flash = min((p["metrics"]["flash_packed_bytes"] for p in front),
                     default=0)

    csv_row("search_frontier", us,
            f"points={len(front)}_evaluated={len(doc['evaluated'])}"
            f"_best_acc={best_acc:.4f}_best_flash={best_flash}B",
            acc=best_acc)
    util.add_figures(
        frontier_points=len(front),
        evaluated=len(doc["evaluated"]),
        rejected=sum(1 for c in doc["evaluated"] if not c["ok"]),
        checker_findings=findings,
        frontier_dominated_pairs=dominated_pairs(front),
        unverified_points=unverified,
        baseline_flash_packed_bytes=base["flash_packed_bytes"],
        best_flash_packed_bytes=best_flash)


if __name__ == "__main__":
    main()
