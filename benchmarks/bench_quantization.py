"""Paper Table 2 analogue: quantization framework — memory footprint and
PTQ cost per CapsNet config (accuracy deltas are measured end-to-end in
examples/train_capsnet.py, which trains first; this bench keeps the table
fast by reporting footprint + calibration/quantization wall time).
"""
import jax
import jax.numpy as jnp

from benchmarks import util
from benchmarks.util import csv_row, time_call
from repro.core import capsnet as C
from repro.data.synthetic import make_image_dataset
from repro.quant import ptq

CASES = [("mnist", C.MNIST), ("smallnorb", C.SMALLNORB),
         ("cifar10", C.CIFAR10)]


def main():
    n_calib = 16 if util.SMOKE else 64
    for name, cfg in CASES[-1:] if util.SMOKE else CASES:
        params = C.init_capsnet(jax.random.key(0), cfg)
        calib = jnp.asarray(make_image_dataset(name, n_calib, seed=1)[0])
        qm = ptq.quantize_capsnet(params, cfg, calib)
        rep = ptq.footprint_report(params, qm)
        us = time_call(lambda: ptq.quantize_capsnet(params, cfg, calib),
                       warmup=0, reps=3)
        csv_row(f"ptq_{name}", us,
                f"{rep['fp32_kb']:.1f}KB->{rep['int8_kb']:.1f}KB_"
                f"save{rep['saving_pct']:.2f}pct")


if __name__ == "__main__":
    main()
