"""Benchmark timing helpers + the BENCH_<section>.json recorder.

Every section of `benchmarks.run` prints its rows as CSV (the
human-facing stream) and, when recording is on, also lands them in one
JSON document per section:

    {"schema": "repro.bench/v1", "section": "serving",
     "stamp": "<run stamp>", "smoke": false,
     "config": {...},               # what the section ran
     "figures": {...},              # section-level derived figures
     "rows": [{"name", "us_per_call", "derived", "figures"}, ...]}

The stamp comes from --stamp / REPRO_BENCH_STAMP (CI passes the commit
SHA) — never from ambient wall-clock time, so re-running a commit
produces byte-comparable artifacts.  `benchmarks.validate` checks every
emitted document against this schema and gates CI on the deterministic
invariants (occupancy > 0, zero default-variant Pallas fallbacks).
"""
import json
import os
import pathlib
import time

import jax

# schema id + known-section registry live in the validator (the module
# that enforces them); re-exported here for the emitters
from benchmarks.validate import KNOWN_SECTIONS, SCHEMA  # noqa: F401

# CI bit-rot check: REPRO_BENCH_SMOKE=1 (or `python -m benchmarks.run
# --smoke`) runs every section with minimal reps/sizes — the point is
# that each harness still executes, not that its numbers are stable.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"

_RECORDER = None


class BenchRecorder:
    """Accumulates csv_row() calls into per-section JSON artifacts."""

    def __init__(self, out_dir, stamp: str):
        self.out_dir = pathlib.Path(out_dir)
        self.stamp = stamp
        self.section = None
        self._config: dict = {}
        self._figures: dict = {}
        self._rows: list = []
        self.written: list = []

    def begin_section(self, name: str, **config) -> None:
        if self.section is not None:
            self.end_section()
        self.section = name
        self._config = dict(config)
        self._figures = {}
        self._rows = []

    def add_row(self, name: str, us: float, derived: str,
                figures: dict) -> None:
        if self.section is None:        # row outside any section: skip
            return
        self._rows.append({"name": name, "us_per_call": float(us),
                           "derived": derived, "figures": figures})

    def add_figures(self, **figures) -> None:
        self._figures.update(figures)

    def end_section(self) -> None:
        if self.section is None:
            return
        doc = {"schema": SCHEMA, "section": self.section,
               "stamp": self.stamp, "smoke": SMOKE,
               "config": self._config, "figures": self._figures,
               "rows": self._rows}
        self.out_dir.mkdir(parents=True, exist_ok=True)
        path = self.out_dir / f"BENCH_{self.section}.json"
        path.write_text(json.dumps(doc, indent=1, sort_keys=True))
        self.written.append(path)
        self.section = None


def start_recording(out_dir, stamp: str) -> BenchRecorder:
    global _RECORDER
    _RECORDER = BenchRecorder(out_dir, stamp)
    return _RECORDER


def recorder() -> BenchRecorder | None:
    return _RECORDER


def begin_section(name: str, **config) -> None:
    if _RECORDER is not None:
        _RECORDER.begin_section(name, **config)


def end_section() -> None:
    if _RECORDER is not None:
        _RECORDER.end_section()


def add_figures(**figures) -> None:
    """Attach section-level derived figures to the active section."""
    if _RECORDER is not None:
        _RECORDER.add_figures(**figures)


def time_call(fn, *args, warmup: int = 2, reps: int = 10) -> float:
    """Median wall time of fn(*args) in microseconds (blocking)."""
    if SMOKE:
        warmup, reps = 0, 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str, **figures):
    """Print one CSV row; `figures` are machine-readable extras that
    only land in the JSON artifact (e.g. occupancy=0.94)."""
    print(f"{name},{us:.1f},{derived}")
    if _RECORDER is not None:
        _RECORDER.add_row(name, us, derived, figures)
