"""Benchmark timing helpers."""
import os
import time

import jax

# CI bit-rot check: REPRO_BENCH_SMOKE=1 (or `python -m benchmarks.run
# --smoke`) runs every section with minimal reps/sizes — the point is
# that each harness still executes, not that its numbers are stable.
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def time_call(fn, *args, warmup: int = 2, reps: int = 10) -> float:
    """Median wall time of fn(*args) in microseconds (blocking)."""
    if SMOKE:
        warmup, reps = 0, 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
