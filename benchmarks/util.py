"""Benchmark timing helpers."""
import time

import jax


def time_call(fn, *args, warmup: int = 2, reps: int = 10) -> float:
    """Median wall time of fn(*args) in microseconds (blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
