"""Paper Tables 7 & 8 analogue: capsule-layer (dynamic routing) latency.

The paper's geometries: MNIST 10x1024x6x4 (L), smallNORB 5x1600x6x4 (M),
CIFAR-10 10x64x5x4 (S) — cap_q7 on STM32H755: 103.40 / 90.60 / 29.63 ms;
GAP-8 octa-core: 46.83 / 38.03 / 11.28 ms.  Two rows per geometry:
the paper-faithful unfused pipeline (Alg. 5's four support functions,
u_hat through memory every iteration) and the beyond-paper FUSED Pallas
routing kernel (u_hat resident, DESIGN §7) — derived = u_hat HBM
round-trips eliminated.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import util
from benchmarks.util import csv_row, time_call
from repro.core import capsnet as C
from repro.core.capsnet_q7 import QCapsNet, capsule_layer_q7
from repro.kernels import ops as kops

CASES = [("mnist_L", C.MNIST, 1024), ("smallnorb_M", C.SMALLNORB, 1600),
         ("cifar10_S", C.CIFAR10, 64)]


def main():
    rng = np.random.default_rng(0)
    for name, cfg, I in CASES[-1:] if util.SMOKE else CASES:
        J, O, D, R = cfg.num_classes, cfg.caps_dim, cfg.pcap_dim, \
            cfg.routings
        W = jnp.asarray(rng.integers(-128, 128, (J, I, O, D)), jnp.int8)
        u = jnp.asarray(rng.integers(-128, 128, (1, I, D)), jnp.int8)
        shifts = {"uhat_shift": 7, "logit_frac": 7}
        for r in range(R):
            shifts[f"caps_out_shift_{r}"] = 9
            shifts[f"caps_out_frac_{r}"] = 7
            if r < R - 1:
                shifts[f"agree_shift_{r}"] = 8
        model = QCapsNet(cfg=cfg, weights={"caps": {"W": W}}, shifts=shifts)

        fn = jax.jit(lambda uu, m=model: capsule_layer_q7(m, uu))
        us = time_call(fn, u)
        macs = J * I * O * D + R * 2 * J * I * O
        csv_row(f"cap_q7_unfused_{name}_{J}x{I}x{O}x{D}", us,
                f"{macs/us:.0f}MAC/us")

        # fused: u_hat precomputed once, routing fully in VMEM
        from repro.quant import int8_ops as q
        acc = jnp.einsum("jiod,bid->bjio", W.astype(jnp.int32),
                         u.astype(jnp.int32))
        u_hat = q.rshift_sat8(acc, 7)
        kw = dict(num_iters=R,
                  caps_out_shifts=tuple([9] * R),
                  caps_out_fracs=tuple([7] * R),
                  agree_shifts=tuple([8] * (R - 1)), logit_frac=7)
        fn2 = lambda uh: kops.routing_q7(uh, **kw)
        us2 = time_call(fn2, u_hat)
        saved = (2 * R - 1) * J * I * O  # u_hat bytes no longer re-read
        csv_row(f"cap_q7_fused_routing_{name}_{J}x{I}x{O}x{D}", us2,
                f"{saved}B_hbm_saved")


if __name__ == "__main__":
    main()
