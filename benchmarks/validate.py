"""Validate BENCH_<section>.json artifacts (schema + invariants):

    PYTHONPATH=src python -m benchmarks.validate artifacts/bench

Checks every `BENCH_*.json` in the directory against the
`repro.bench/v1` schema (this module is the schema's source of truth;
benchmarks/util.py imports SCHEMA from here) and gates on the
deterministic invariants a bench run must satisfy regardless of how
fast the machine was:

  * every doc names a KNOWN section and carries a non-empty stamp —
    an unknown section means a typo'd `begin_section` (or a section
    added without registering it here), and an unstamped artifact
    cannot be tied back to a commit, so neither may become a baseline;
  * serving: every `serve_batched_*` row carries occupancy > 0 —
    an empty/NaN occupancy means the engine served nothing;
  * observability: `default_variant_fallbacks == 0` — a fallback on a
    DEFAULT variant means the fused pallas kernels stopped covering
    the default plan (non-default fallbacks are expected: the variants
    section drives them deliberately);
  * numerics: `int32_clip_total == 0` — a runtime int32-clip event
    contradicts the static range proofs (repro.analysis.ranges), so
    the artifact is evidence of a soundness bug, not a perf number;
  * search: at least one frontier point, zero static-checker findings
    across the frontier, zero mutually-dominating frontier pairs, and
    every point export/check/bit-verified — a dominated or unverified
    "frontier" point means repro.search's selection or verification
    broke, whatever the machine speed.

Exit 1 on any finding; CI runs this right after `benchmarks.run
--smoke --out ...` and uploads the artifacts.
"""
from __future__ import annotations

import json
import pathlib
import sys

SCHEMA = "repro.bench/v1"

# every section benchmarks.run may emit; validate_doc refuses others
KNOWN_SECTIONS = frozenset({
    "quantization", "matmul", "primary_caps", "capsule_layer",
    "serving", "edge_vm", "numerics", "training", "variants",
    "observability", "search",
})

_TOP_KEYS = {"schema": str, "section": str, "stamp": str, "smoke": bool,
             "config": dict, "figures": dict, "rows": list}
_ROW_KEYS = {"name": str, "us_per_call": (int, float), "derived": str,
             "figures": dict}


def validate_doc(doc: dict, where: str) -> list:
    """Schema findings for one parsed artifact (empty list = clean)."""
    findings = []
    for key, typ in _TOP_KEYS.items():
        if key not in doc:
            findings.append(f"{where}: missing key {key!r}")
        elif not isinstance(doc[key], typ):
            findings.append(f"{where}: {key!r} is {type(doc[key]).__name__},"
                            f" wanted {typ}")
    if doc.get("schema") not in (None, SCHEMA):
        findings.append(f"{where}: schema {doc.get('schema')!r} != {SCHEMA!r}")
    section = doc.get("section")
    if isinstance(section, str) and section not in KNOWN_SECTIONS:
        findings.append(f"{where}: unknown section {section!r}; known: "
                        f"{sorted(KNOWN_SECTIONS)}")
    stamp = doc.get("stamp")
    if isinstance(stamp, str) and not stamp.strip():
        findings.append(f"{where}: empty stamp — pass --stamp / "
                        "REPRO_BENCH_STAMP so the artifact ties back "
                        "to a commit")
    for i, row in enumerate(doc.get("rows", [])):
        if not isinstance(row, dict):
            findings.append(f"{where}: rows[{i}] is not an object")
            continue
        for key, typ in _ROW_KEYS.items():
            if key not in row:
                findings.append(f"{where}: rows[{i}] missing {key!r}")
            elif not isinstance(row[key], typ):
                findings.append(f"{where}: rows[{i}].{key} is "
                                f"{type(row[key]).__name__}, wanted {typ}")
    return findings


def validate_invariants(doc: dict, where: str) -> list:
    """Deterministic gates (machine-speed independent)."""
    findings = []
    if doc.get("section") == "serving":
        for row in doc.get("rows", []):
            if not str(row.get("name", "")).startswith("serve_batched_"):
                continue
            occ = row.get("figures", {}).get("occupancy")
            if not isinstance(occ, (int, float)) or not occ > 0:
                findings.append(
                    f"{where}: {row.get('name')}: occupancy {occ!r} "
                    "is not > 0 (engine served nothing?)")
    if doc.get("section") == "observability":
        dflt = doc.get("figures", {}).get("default_variant_fallbacks")
        if dflt != 0:
            findings.append(
                f"{where}: default_variant_fallbacks == {dflt!r}, "
                "wanted 0 — the fused pallas kernels no longer cover "
                "the default softmax/squash plan")
    if doc.get("section") == "numerics":
        clips = doc.get("figures", {}).get("int32_clip_total")
        if clips != 0:
            findings.append(
                f"{where}: int32_clip_total == {clips!r}, wanted 0 — "
                "runtime int32 clipping contradicts the static range "
                "proofs (repro.analysis.ranges)")
    if doc.get("section") == "search":
        figs = doc.get("figures", {})
        points = figs.get("frontier_points")
        if not isinstance(points, int) or points < 1:
            findings.append(
                f"{where}: frontier_points == {points!r}, wanted >= 1 — "
                "the search produced no verified operating point")
        for key in ("checker_findings", "frontier_dominated_pairs",
                    "unverified_points"):
            val = figs.get(key)
            if val != 0:
                findings.append(
                    f"{where}: {key} == {val!r}, wanted 0 — the search "
                    "frontier is not clean (see benchmarks/bench_search)")
    return findings


def validate_dir(out_dir) -> tuple:
    """(checked_paths, findings) over every BENCH_*.json in out_dir."""
    out_dir = pathlib.Path(out_dir)
    paths = sorted(out_dir.glob("BENCH_*.json"))
    findings = []
    if not paths:
        findings.append(f"{out_dir}: no BENCH_*.json artifacts found")
    for path in paths:
        where = path.name
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            findings.append(f"{where}: unreadable ({e})")
            continue
        findings += validate_doc(doc, where)
        findings += validate_invariants(doc, where)
    return paths, findings


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    out_dir = argv[0] if argv else "artifacts/bench"
    paths, findings = validate_dir(out_dir)
    for f in findings:
        print(f"FINDING: {f}")
    print(f"benchmarks.validate: {len(paths)} artifacts, "
          f"{len(findings)} findings -> "
          f"{'FAIL' if findings else 'ok'}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
