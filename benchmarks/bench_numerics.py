"""Numeric-health benchmark: saturation / bound tightness / q7-vs-f32
SNR of the shipped models under the repro.obs.numerics probes.

One row per model:

  numerics_*   us/img of a fully probed EdgeVM pass, with the health
               figures the baseline gate tracks — worst per-site
               saturation rate (may only shrink), worst per-layer SNR
               against the fwd_f32 oracle (may only improve), bound
               tightness (observed |acc| peak / statically proven
               acc_bound), total int32-clip events (exact 0: the
               verifier proves them impossible), and the probe's
               overhead factor over the unprobed hot path.

The section figure `int32_clip_total` must be 0 (benchmarks.validate
invariant) — a nonzero value means runtime behaviour escaped the static
proofs, which gates the run before the baseline compare even looks.
"""
import jax
import numpy as np

from benchmarks import util
from benchmarks.util import csv_row
from repro.edge import EdgeVM, lower
from repro.obs import numerics as health
from repro.serving import ModelRegistry


def main():
    if util.SMOKE:
        cases = [("edge_tiny@jnp", 8)]
    else:
        cases = [("edge_tiny@jnp", 64), ("mnist@jnp", 16)]
    registry = ModelRegistry()
    total_clips = 0
    for model_id, n in cases:
        spec = registry.specs[model_id]
        qnet = registry.model(model_id)
        program = lower(qnet)
        vm = EdgeVM(program)
        images = np.asarray(spec.images(n, seed=11))
        x_q = np.asarray(qnet.quantize_input(images))

        base_us = util.time_call(lambda: vm.run(x_q))
        probe = health.NumericsProbe()
        with health.probing(probe):
            probed_us = util.time_call(lambda: vm.run(x_q))

        # the gated report: fresh probe, float oracle for the SNR rows
        params = qnet.pipeline.init(jax.random.key(spec.seed))
        report = health.run_numerics(qnet, images, params=params,
                                     program=program)
        clips = report.total_int32_clip()
        total_clips += clips
        sat = report.worst_saturation_rate()
        snr = report.min_snr_db()
        tight = report.max_bound_tightness()
        csv_row(f"numerics_{model_id}", probed_us / n,
                f"sat={sat * 100:.2f}%_snr={snr:.1f}dB"
                f"_tight={tight * 100:.1f}%_clips={clips}"
                f"_probe={probed_us / base_us:.2f}x",
                saturation_rate=sat,
                snr_db=snr,
                bound_tightness=tight,
                int32_clip=clips,
                probe_overhead_x=probed_us / base_us)
    util.add_figures(int32_clip_total=int(total_clips))


if __name__ == "__main__":
    main()
