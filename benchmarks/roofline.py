"""Roofline table from the dry-run artifacts (deliverable (g)).

Reads artifacts/dryrun/*.json and prints, per (arch x shape x mesh):
the three roofline terms (compute / memory / collective, seconds), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and the roofline fraction.

    PYTHONPATH=src python -m benchmarks.roofline            # table
    PYTHONPATH=src python -m benchmarks.roofline --csv      # CSV
    PYTHONPATH=src python -m benchmarks.roofline --mesh single --md
"""
import argparse
import json
import pathlib

ART = pathlib.Path("artifacts/dryrun")


def load(mesh: str | None = None, include_tagged: bool = False,
         tag: str | None = None):
    rows = []
    for f in sorted(ART.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok":
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        if tag is not None:
            if rec.get("tag") != tag or rec.get("quant"):
                continue
        elif not include_tagged and (rec.get("tag") or rec.get("quant")):
            continue
        rec["_file"] = f.name
        rows.append(rec)
    return rows


def fmt_row(r):
    t = r["terms"]
    return (r["arch"], r["shape"], r["mesh"],
            f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
            f"{t['collective_s']:.4f}", r["dominant"].replace("_s", ""),
            f"{r['useful_flop_ratio']:.3f}",
            f"{r['roofline_fraction']:.4f}",
            f"{r['hbm_gib_per_dev']:.2f}")


HDR = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
       "dominant", "useful/HLO", "roofline_frac", "HBM_GiB/dev")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=("single", "multi"), default=None)
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--all-variants", action="store_true")
    ap.add_argument("--tag", default=None,
                    help="show only artifacts with this tag (e.g. opt)")
    args = ap.parse_args()
    rows = load(args.mesh, include_tagged=args.all_variants, tag=args.tag)
    if args.csv:
        print(",".join(HDR))
        for r in rows:
            print(",".join(fmt_row(r)))
        return
    sep = " | " if args.md else "  "
    widths = [20, 12, 7, 10, 10, 12, 10, 10, 13, 11]
    line = sep.join(h.ljust(w) for h, w in zip(HDR, widths))
    print(("| " + line + " |") if args.md else line)
    if args.md:
        print("|" + "|".join("-" * (w + 2) for w in widths) + "|")
    for r in rows:
        cells = sep.join(c.ljust(w) for c, w in zip(fmt_row(r), widths))
        print(("| " + cells + " |") if args.md else cells)


if __name__ == "__main__":
    main()
