"""Edge-export benchmark: NumPy q7 VM throughput + arena plan quality.

Two row families per model:

  edge_vm_*     images/sec of the bit-exact NumPy interpreter executing
                the exported EdgeProgram (the host-side stand-in for the
                MCU kernels — useful as a conservative lower bound and
                as the regression canary for the export path's cost)
  edge_arena_*  arena peak vs the naive sum of all activation tensors
                (what a no-liveness allocator would reserve), plus the
                flash/RAM split of the memory report

The derived column carries the deployment quantities the paper's Table 2
cares about: arena bytes, savings vs naive, and int8-vs-fp32 footprint.
"""
import numpy as np

from benchmarks import util
from benchmarks.util import csv_row
from repro.edge import EdgeVM, lower, memory_report, plan_arena
from repro.serving import ModelRegistry


def main():
    if util.SMOKE:
        cases = [("edge_tiny@jnp", 8)]
    else:
        cases = [("edge_tiny@jnp", 64), ("mnist@jnp", 16)]
    registry = ModelRegistry()
    for model_id, n in cases:
        spec = registry.specs[model_id]
        qnet = registry.model(model_id)
        program = lower(qnet)
        vm = EdgeVM(program)
        x_q = np.asarray(
            qnet.quantize_input(np.asarray(spec.images(n, seed=11))))

        us = util.time_call(lambda: vm.run(x_q))
        csv_row(f"edge_vm_{model_id}", us / n,
                f"{n / (us * 1e-6):.1f}img/s",
                images_per_s=n / (us * 1e-6))

        plan = plan_arena(program)
        rep = memory_report(program, plan)
        csv_row(f"edge_arena_{model_id}", 0.0,
                f"arena={plan.arena_bytes}B_naive={plan.naive_bytes}B"
                f"_saved={100 * (1 - plan.arena_bytes / plan.naive_bytes):.0f}%"
                f"_flash={rep['flash_bytes'] / 1000:.1f}KB"
                f"_ram={rep['ram_bytes'] / 1000:.1f}KB"
                f"_vs_fp32={rep['saving_pct']:.1f}%",
                arena_bytes=plan.arena_bytes,
                naive_bytes=plan.naive_bytes,
                flash_bytes=rep["flash_bytes"],
                ram_bytes=rep["ram_bytes"])


if __name__ == "__main__":
    main()
