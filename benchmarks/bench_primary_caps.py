"""Paper Tables 5 & 6 analogue: primary-capsule layer latency.

The paper's three kernels: MNIST 7x7x16x64 (M), smallNORB 7x7x32x64 (L),
CIFAR-10 3x3x64x64 (S) — pcap_q7 on STM32H755 took 119.94 / 740.03 /
21.87 ms; GAP-8 octa-core 7.02 / 55.32 / 1.30 ms.  Here: the full int8
primary-capsule layer (conv + reshape + integer squash) at the paper's
exact geometries.  derived = MAC/us over the conv.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import util
from benchmarks.util import csv_row, time_call
from repro.core import capsnet as C
from repro.core.capsnet_q7 import QCapsNet, pcap_q7
from repro.quant import qformat as qf

CASES = [("mnist_M", C.MNIST), ("smallnorb_L", C.SMALLNORB),
         ("cifar10_S", C.CIFAR10)]


def main():
    rng = np.random.default_rng(0)
    for name, cfg in CASES[-1:] if util.SMOKE else CASES:
        h, w = cfg.conv_out_hw
        cin = cfg.conv_filters[-1]
        x = jnp.asarray(rng.integers(-128, 128, (1, h, w, cin)), jnp.int8)
        k = cfg.pcap_kernel
        pout = cfg.pcap_caps * cfg.pcap_dim
        weights = {"pcap": {
            "w": jnp.asarray(rng.integers(-128, 128, (k, k, cin, pout)),
                             jnp.int8),
            "b": jnp.asarray(rng.integers(-128, 128, (pout,)), jnp.int8)}}
        shifts = {"pcap_out_shift": 9, "pcap_bias_shift": 2,
                  "pcap_out_frac": 5}
        model = QCapsNet(cfg=cfg, weights=weights, shifts=shifts)
        fn = jax.jit(lambda xx, m=model: pcap_q7(m, xx))
        us = time_call(fn, x)
        ph, pw = cfg.pcap_out_hw
        macs = ph * pw * pout * k * k * cin
        csv_row(f"pcap_q7_{name}_{k}x{k}x{cin}x{pout}", us,
                f"{macs/us:.0f}MAC/us")


if __name__ == "__main__":
    main()
