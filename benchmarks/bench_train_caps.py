"""Capsule training benchmark: float vs fake-quant (QAT) step cost, and
the Table-2 accuracy harness as a measured artifact.

The paper trains in the cloud and ships int8 to the MCU; the training
subsystem's cost question is what QAT adds on top of a float step —
every tensor the int8 graph quantizes gains a fake-quant snap
(`qformat.fake_quant`), so the fwd/bwd graph roughly doubles its
elementwise work while the matmuls stay identical.  Rows:

  train_step_float_*   us per optimizer step, float pipeline
  train_step_qat_*     us per optimizer step, fake-quant on a live plan
  train_accuracy_*     the evalq harness: float/ptq/qat accuracy and the
                       float-vs-int8 deltas per rounding mode (derived
                       column; the repo's Table-2 accuracy reproduction)

Smoke mode runs a few steps of edge_tiny only (CI bit-rot check);
the full run adds the paper's MNIST "L" geometry step costs.
"""
from benchmarks import util
from benchmarks.util import csv_row, time_call
from repro.captrain import CapsTrainer, TrainConfig, table2_rows
from repro.nn.config import MNIST
from repro.serving.registry import EDGE_TINY


def _step_cost(cfg, tcfg):
    trainer = CapsTrainer(cfg, tcfg)
    state = trainer.init_state()
    x, y = trainer.task.batch(0, tcfg.batch)
    plan = trainer.derive_plan(state)

    us = time_call(lambda: trainer.train_step(state, x, y))
    csv_row(f"train_step_float_{cfg.name}", us,
            f"{tcfg.batch * 1e6 / us:.1f}img/s")
    us_q = time_call(lambda: trainer.train_step(state, x, y, plan))
    csv_row(f"train_step_qat_{cfg.name}", us_q,
            f"{tcfg.batch * 1e6 / us_q:.1f}img/s_overhead="
            f"{us_q / us:.2f}x")


def main():
    tiny = TrainConfig(dataset="edge_tiny", batch=32, calib_n=16)
    _step_cost(EDGE_TINY, tiny)
    if not util.SMOKE:
        _step_cost(MNIST, TrainConfig(dataset="mnist", batch=32,
                                      calib_n=16))

    f_steps, q_steps, eval_n = (8, 4, 64) if util.SMOKE else (150, 40, 512)
    rows = table2_rows(EDGE_TINY, tiny, float_steps=f_steps,
                       qat_steps=q_steps, eval_n=eval_n)
    for r in rows:
        csv_row(f"train_accuracy_{r.name}_{r.rounding}", 0.0,
                f"f32={r.acc_f32:.4f}_ptq={r.acc_ptq:.4f}"
                f"_qat={r.acc_qat:.4f}_dptq={r.delta_ptq:.4f}"
                f"_dqat={r.delta_qat:.4f}_saving={r.saving_pct:.1f}%")


if __name__ == "__main__":
    main()
