"""Operator-variant sweep: accuracy + host throughput per registered
(softmax, squash) combination, per rounding mode (ISLPED'22 study).

One short float training run on the edge_tiny seed, then — per rounding
mode — one PTQ quantization whose plan is EDITED per variant set
(`QuantCapsNet.with_variants`; weights and shifts are untouched, so the
sweep isolates exactly what the operator approximation costs):

  variant_<softmax>+<squash>_<rounding>
      us_per_call  host (jnp oracle) time per image for the int8 forward
      derived      int8 accuracy, delta vs fp32, and delta vs the
                   q7+exact baseline of the same rounding

The MCU-side latency argument (division-free softmax, sqrt-free squash)
lives in the emitted C kernels; the host numbers here are the regression
canary plus the accuracy half of the trade-off.  Smoke mode shrinks
steps/eval to a bit-rot check.
"""
import jax.numpy as jnp
import numpy as np

from benchmarks import util
from benchmarks.util import csv_row
from repro.captrain import CapsTrainer, TrainConfig
from repro.captrain.evalq import eval_float, eval_q7
from repro.data.synthetic import make_image_dataset
from repro.nn.variants import VariantSet, all_variant_sets
from repro.serving import EDGE_TINY


def main():
    steps, eval_n, timed_n = (8, 64, 8) if util.SMOKE else (150, 512, 64)
    tcfg = TrainConfig(dataset="edge_tiny", batch=32, microbatches=4,
                       calib_n=32, lr=3e-3, recon_weight=0.0)
    trainer = CapsTrainer(EDGE_TINY, tcfg)
    state = trainer.init_state()
    state, _, _ = trainer.fit(state, steps)

    images, labels = make_image_dataset("edge_tiny", eval_n, seed=123_123)
    acc_f = eval_float(trainer.pipeline, state["params"]["caps"],
                       images, labels)
    csv_row("variant_fp32_reference", 0.0, f"acc={acc_f:.4f}",
            acc=float(acc_f))

    baseline = VariantSet()                      # q7+exact
    sweep = [baseline] + [vs for vs in all_variant_sets()
                          if vs != baseline]
    for rounding in ("floor", "nearest"):
        qnet = trainer.quantize(state, rounding=rounding)
        x_t = qnet.quantize_input(jnp.asarray(images[:timed_n]))
        acc_base = None
        for vs in sweep:
            q = qnet.with_variants(vs)
            us = util.time_call(lambda: q.forward(x_t))
            acc = eval_q7(q, images, labels)
            if acc_base is None:                 # baseline runs first
                acc_base = acc
            csv_row(f"variant_{vs.tag}_{rounding}", us / timed_n,
                    f"acc={acc:.4f}_dfp32={acc_f - acc:+.4f}"
                    f"_dq7={acc - acc_base:+.4f}", acc=float(acc))


if __name__ == "__main__":
    main()
