# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point:

    PYTHONPATH=src python -m benchmarks.run

Sections (one per paper table):
  Table 2  -> bench_quantization   (footprint / PTQ cost)
  Tables 3/4 -> bench_matmul       (int8 matmul variants)
  Tables 5/6 -> bench_primary_caps (primary capsule layer)
  Tables 7/8 -> bench_capsule_layer(capsule layer / dynamic routing,
                                    unfused vs fused-VMEM kernel)
beyond-paper:
  serving    -> bench_serving      (batched engine vs batch-1 loop)
  training   -> bench_train_caps   (float vs QAT step cost, Table-2
                                    accuracy deltas via repro.captrain)
  variants   -> bench_variants     (ISLPED'22 approx softmax/squash:
                                    accuracy/throughput per registered
                                    operator-variant set x rounding)
plus the roofline summary from the dry-run artifacts (if present).

CPU wall-clock is the validation substrate (interpret-mode kernels); the
derived column carries the hardware-independent figure.  `--smoke` (CI)
runs every section at minimal reps/sizes so harness bit-rot fails fast.
"""
import os
import sys


def main() -> None:
    if "--smoke" in sys.argv[1:]:
        # must land before benchmarks.util is imported (it reads the env)
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    print("name,us_per_call,derived")
    from benchmarks import (bench_capsule_layer, bench_edge_vm,
                            bench_matmul, bench_primary_caps,
                            bench_quantization, bench_serving,
                            bench_train_caps, bench_variants)
    print("# --- Table 2: quantization framework ---")
    bench_quantization.main()
    print("# --- Tables 3/4: int8 matmul variants ---")
    bench_matmul.main()
    print("# --- Tables 5/6: primary capsule layer ---")
    bench_primary_caps.main()
    print("# --- Tables 7/8: capsule layer (dynamic routing) ---")
    bench_capsule_layer.main()
    print("# --- Serving: batched int8 engine vs b1 loop ---")
    bench_serving.main()
    print("# --- Edge export: q7 VM + arena plan ---")
    bench_edge_vm.main()
    print("# --- Training: float vs QAT steps + Table-2 accuracy ---")
    bench_train_caps.main()
    print("# --- Operator variants: ISLPED'22 approx softmax/squash ---")
    bench_variants.main()

    import pathlib
    if pathlib.Path("artifacts/dryrun").exists():
        from benchmarks import roofline
        opt = roofline.load("single", tag="opt")
        rows = opt or roofline.load("single")
        grid = "optimized (§Perf)" if opt else "baseline"
        base = {(r["arch"], r["shape"]): r
                for r in roofline.load("single")}
        print(f"# --- Roofline summary: {grid} grid, single-pod "
              "(full table: python -m benchmarks.roofline) ---")
        for r in rows:
            t = r["terms"]
            bound = max(t.values())
            b = base.get((r["arch"], r["shape"]))
            speedup = ""
            if b is not None and opt:
                b_bound = max(b["terms"].values())
                speedup = f"_speedup={b_bound/max(bound,1e-12):.1f}x"
            print(f"roofline_{r['arch']}_{r['shape']},"
                  f"{bound*1e6:.0f},"
                  f"dom={r['dominant'].replace('_s','')}"
                  f"_frac={r['roofline_fraction']:.4f}{speedup}")


if __name__ == "__main__":
    main()
