# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point:

    PYTHONPATH=src python -m benchmarks.run [--smoke]
        [--out artifacts/bench] [--stamp <id>] [--sections a,b,...]

Sections (one per paper table):
  Table 2  -> bench_quantization   (footprint / PTQ cost)
  Tables 3/4 -> bench_matmul       (int8 matmul variants)
  Tables 5/6 -> bench_primary_caps (primary capsule layer)
  Tables 7/8 -> bench_capsule_layer(capsule layer / dynamic routing,
                                    unfused vs fused-VMEM kernel)
beyond-paper:
  serving    -> bench_serving      (batched engine vs batch-1 loop)
  training   -> bench_train_caps   (float vs QAT step cost, Table-2
                                    accuracy deltas via repro.captrain)
  variants   -> bench_variants     (ISLPED'22 approx softmax/squash:
                                    accuracy/throughput per registered
                                    operator-variant set x rounding)
  numerics   -> bench_numerics      (probed q7 numeric health:
                                    saturation, bound tightness,
                                    q7-vs-f32 SNR; the validator gates
                                    on zero int32-clip events)
  search     -> bench_search        (quantization/variant Pareto search;
                                    the validator gates on a clean,
                                    mutually non-dominated frontier)
  observability -> process metrics snapshot (pallas fallback counters;
                                    the validator gates on zero
                                    default-variant fallbacks)
plus the roofline summary from the dry-run artifacts (if present).

Every section also lands as `<out>/BENCH_<section>.json`
(schema repro.bench/v1, see benchmarks/util.py); `--stamp` (or
REPRO_BENCH_STAMP — CI passes the commit SHA) identifies the run
instead of ambient time, so artifacts are reproducible.
`benchmarks.validate` checks the emitted set.

CPU wall-clock is the validation substrate (interpret-mode kernels); the
derived column carries the hardware-independent figure.  `--smoke` (CI)
runs every section at minimal reps/sizes so harness bit-rot fails fast.
"""
import argparse
import os
import sys


def _observability_section(util) -> None:
    """Snapshot the process metrics registry after every section ran:
    how often the pallas backend fell back to the jnp oracle, split
    default vs non-default variant (bench_variants legitimately drives
    non-default fallbacks; a DEFAULT-variant fallback would mean the
    fused kernels stopped covering the default plan — the validator
    fails the run on it)."""
    from repro.nn.backend import BACKENDS
    from repro.nn.variants import REGISTRY
    defaults = {REGISTRY.default("softmax"), REGISTRY.default("squash")}
    fallbacks = BACKENDS["pallas"].fallbacks
    total = sum(fallbacks.values())
    default_hits = sum(n for (op, variant), n in fallbacks.items()
                       if variant in defaults)
    util.begin_section("observability")
    util.add_figures(total_fallback_decisions=int(total),
                     default_variant_fallbacks=int(default_hits),
                     fallback_series={f"{op}:{variant}": int(n)
                                      for (op, variant), n
                                      in fallbacks.items()})
    util.csv_row("pallas_fallbacks", 0.0,
                 f"total={total}_default={default_hits}",
                 total=int(total), default=int(default_hits))
    util.end_section()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="minimal reps/sizes (CI bit-rot check)")
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="also write BENCH_<section>.json artifacts "
                    "into DIR (schema repro.bench/v1)")
    ap.add_argument("--stamp", default=None,
                    help="run identifier stored in every artifact "
                    "(default: $REPRO_BENCH_STAMP, else 'unstamped'; "
                    "CI passes the commit SHA)")
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of sections to run "
                    "(default: all), e.g. serving,edge_vm,variants,"
                    "observability — the perf-gate set CI re-records")
    args = ap.parse_args(sys.argv[1:] if argv is None else argv)
    if args.smoke:
        # must land before benchmarks.util is imported (it reads the env)
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    from benchmarks import util
    if args.out:
        stamp = args.stamp or os.environ.get("REPRO_BENCH_STAMP",
                                             "unstamped")
        util.start_recording(args.out, stamp)
    print("name,us_per_call,derived")
    from benchmarks import (bench_capsule_layer, bench_edge_vm,
                            bench_matmul, bench_numerics,
                            bench_primary_caps, bench_quantization,
                            bench_search, bench_serving,
                            bench_train_caps, bench_variants)
    sections = [
        ("quantization", {"tables": [2]}, bench_quantization.main,
         "Table 2: quantization framework"),
        ("matmul", {"tables": [3, 4]}, bench_matmul.main,
         "Tables 3/4: int8 matmul variants"),
        ("primary_caps", {"tables": [5, 6]}, bench_primary_caps.main,
         "Tables 5/6: primary capsule layer"),
        ("capsule_layer", {"tables": [7, 8]}, bench_capsule_layer.main,
         "Tables 7/8: capsule layer (dynamic routing)"),
        ("serving", {}, bench_serving.main,
         "Serving: batched int8 engine vs b1 loop"),
        ("edge_vm", {}, bench_edge_vm.main,
         "Edge export: q7 VM + arena plan"),
        ("numerics", {}, bench_numerics.main,
         "Numerics: saturation / bound tightness / q7-vs-f32 SNR"),
        ("training", {}, bench_train_caps.main,
         "Training: float vs QAT steps + Table-2 accuracy"),
        ("variants", {}, bench_variants.main,
         "Operator variants: ISLPED'22 approx softmax/squash"),
        ("search", {}, bench_search.main,
         "Search: verified Pareto frontier over quantization/variants"),
        ("observability", {}, lambda: _observability_section(util),
         "Observability: process metrics snapshot"),
    ]
    only = None
    if args.sections:
        only = set(args.sections.split(","))
        unknown = only - util.KNOWN_SECTIONS
        if unknown:
            ap.error(f"unknown sections {sorted(unknown)}; known: "
                     f"{sorted(util.KNOWN_SECTIONS)}")
    for name, config, fn, title in sections:
        if only is not None and name not in only:
            continue
        print(f"# --- {title} ---")
        if name != "observability":    # it opens its own section
            util.begin_section(name, **config)
        fn()
        util.end_section()

    import pathlib
    if pathlib.Path("artifacts/dryrun").exists():
        from benchmarks import roofline
        opt = roofline.load("single", tag="opt")
        rows = opt or roofline.load("single")
        grid = "optimized (§Perf)" if opt else "baseline"
        base = {(r["arch"], r["shape"]): r
                for r in roofline.load("single")}
        print(f"# --- Roofline summary: {grid} grid, single-pod "
              "(full table: python -m benchmarks.roofline) ---")
        for r in rows:
            t = r["terms"]
            bound = max(t.values())
            b = base.get((r["arch"], r["shape"]))
            speedup = ""
            if b is not None and opt:
                b_bound = max(b["terms"].values())
                speedup = f"_speedup={b_bound/max(bound,1e-12):.1f}x"
            print(f"roofline_{r['arch']}_{r['shape']},"
                  f"{bound*1e6:.0f},"
                  f"dom={r['dominant'].replace('_s','')}"
                  f"_frac={r['roofline_fraction']:.4f}{speedup}")
    rec = util.recorder()
    if rec is not None:
        rec.end_section()
        print(f"# wrote {len(rec.written)} BENCH_*.json artifacts "
              f"(stamp={rec.stamp}) to {rec.out_dir}")


if __name__ == "__main__":
    main()
