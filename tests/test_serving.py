"""Serving-subsystem tests (repro.serving) + the PR's satellite fixes.

Pinned guarantees:
  * engine waves are bit-identical to direct QuantCapsNet.forward —
    bucket padding cannot perturb real rows;
  * the scheduler is deterministic: same submissions -> same waves,
    buckets and bits;
  * the registry quantizes lazily (once) and reuses compiled wave
    executables per (model, bucket);
  * the sharded wave path matches the unsharded one bit-for-bit on a
    1-device mesh (and on a real 8-device mesh, slow tier);
  * with_softmax is a pure plan edit; class_lengths dequantizes with the
    plan's out_frac; calibrate's device-side accumulation matches the
    per-batch host-sync semantics it replaced.

Everything runs on the CIFAR-10 geometry (the paper's smallest) with one
module-scoped PTQ build.
"""
import dataclasses
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.nn import CIFAR10, CapsPipeline
from repro.nn.plans import ConvPlan, RoutingPlan
from repro.serving import (CapsServeEngine, ModelRegistry, ModelSpec,
                           ServeMetrics, compile_wave)

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


class FakeClock:
    """Monotone fake clock: every read advances 1s."""
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        self.t += 1.0
        return self.t


@pytest.fixture(scope="module")
def served():
    cfg = CIFAR10
    pipe = CapsPipeline.from_config(cfg)
    params = pipe.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    calib = jnp.asarray(
        rng.uniform(0, 1, (16,) + cfg.input_shape).astype(np.float32))
    qnet = pipe.quantize(params, calib)
    images = rng.uniform(0, 1, (9,) + cfg.input_shape).astype(np.float32)
    return params, calib, qnet, images


def _registry(qnet, ids=("m",)):
    reg = ModelRegistry(specs={})
    for i in ids:
        reg.install(i, qnet)
    return reg


# ---------------------------------------------------------------------------
# engine: bit parity + scheduling
# ---------------------------------------------------------------------------
def test_engine_bit_identical_to_direct_forward(served):
    """Acceptance: every completion's int8 capsules equal a direct
    QuantCapsNet.forward on the same image — through a padded bucket."""
    _, _, qnet, images = served
    engine = CapsServeEngine(_registry(qnet), buckets=(2, 4, 8),
                             clock=FakeClock())
    engine.submit_many(images[:5], "m")
    done = engine.drain()
    assert [c.rid for c in done] == [0, 1, 2, 3, 4]
    assert [c.bucket for c in done] == [8] * 5      # 5 pads up to 8

    v = np.asarray(qnet.forward(qnet.quantize_input(
        jnp.asarray(images[:5]))))
    lengths = np.asarray(qnet.class_lengths(jnp.asarray(v)))
    for c in done:
        assert c.v_q.dtype == np.int8
        np.testing.assert_array_equal(c.v_q, v[c.rid])
        np.testing.assert_array_equal(c.lengths, lengths[c.rid])
        assert c.pred == int(np.argmax(lengths[c.rid]))


def test_scheduler_bucketing_and_determinism(served):
    """Waves take the longest same-model run at the head, capped at the
    max bucket; identical submissions replay to identical waves/bits."""
    _, _, qnet, images = served
    reg = _registry(qnet, ids=("m1", "m2"))
    pattern = ["m1", "m1", "m2", "m2", "m2", "m1"]

    def run():
        engine = CapsServeEngine(reg, buckets=(1, 2, 4), clock=FakeClock())
        for img, mid in zip(images, pattern):
            engine.submit(img, mid)
        done = engine.drain()
        return [(c.rid, c.model_id, c.wave, c.bucket) for c in done], \
            [c.v_q for c in done]

    sched1, bits1 = run()
    assert sched1 == [(0, "m1", 0, 2), (1, "m1", 0, 2),
                      (2, "m2", 1, 4), (3, "m2", 1, 4), (4, "m2", 1, 4),
                      (5, "m1", 2, 1)]
    sched2, bits2 = run()
    assert sched1 == sched2
    for a, b in zip(bits1, bits2):
        np.testing.assert_array_equal(a, b)


def test_wave_split_across_buckets(served):
    """More requests than the max bucket split FIFO into several waves,
    each padded to its own bucket."""
    _, _, qnet, images = served
    engine = CapsServeEngine(_registry(qnet), buckets=(2, 4, 8),
                             clock=FakeClock())
    engine.submit_many(images, "m")                  # 9 requests
    done = engine.drain()
    assert [(c.wave, c.bucket) for c in done] == \
        [(0, 8)] * 8 + [(1, 2)]
    m = engine.metrics
    assert m.waves_run == 2 and m.images_done == 9
    assert m.occupancy() == pytest.approx((8 / 8 + 1 / 2) / 2)
    assert m.max_queue_depth() == 9


def test_failed_wave_leaves_queue_intact(served):
    """A raising executable must not drop the wave's requests: the queue
    stays as-is so a later drain can retry them."""
    _, _, qnet, images = served
    reg = _registry(qnet)
    engine = CapsServeEngine(reg, buckets=(4,), clock=FakeClock())
    engine.submit_many(images[:3], "m")
    orig, calls = reg.executable, {"n": 0}

    def flaky(model_id, bucket):
        if calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("transient compile failure")
        return orig(model_id, bucket)

    reg.executable = flaky
    with pytest.raises(RuntimeError):
        engine.step()
    assert engine.queue_depth() == 3
    done = engine.drain()                        # retry succeeds
    assert [c.rid for c in done] == [0, 1, 2]


def test_engine_validates_inputs(served):
    _, _, qnet, images = served
    engine = CapsServeEngine(_registry(qnet), buckets=(1, 4))
    with pytest.raises(KeyError):
        engine.submit(images[0], "nope")
    with pytest.raises(ValueError):
        engine.submit(images[0][:16], "m")
    with pytest.raises(ValueError):
        CapsServeEngine(_registry(qnet), buckets=())
    with pytest.raises(ValueError):
        CapsServeEngine(_registry(qnet), buckets=(0, 4))
    assert engine.step() == []                       # idle engine


# ---------------------------------------------------------------------------
# registry: lazy PTQ + executable cache
# ---------------------------------------------------------------------------
def test_registry_lazy_quantize_and_executable_reuse(served):
    _, _, qnet, images = served
    reg = ModelRegistry(specs={"tiny": ModelSpec(
        "tiny", CIFAR10, dataset="uniform", calib_n=8)})
    assert reg.quantize_count == 0                   # lazy until requested
    # static geometry queries (submit-time shape validation) must not
    # trigger the PTQ build either
    assert reg.input_shape("tiny") == tuple(CIFAR10.input_shape)
    assert reg.quantize_count == 0
    engine = CapsServeEngine(reg, buckets=(4,), clock=FakeClock())
    engine.submit_many(images[:3], "tiny")
    engine.drain()
    assert reg.quantize_count == 1
    assert reg.compile_count == 1

    # second wave of the same bucket: no new PTQ, no new executable
    engine.submit_many(images[3:6], "tiny")
    engine.drain()
    assert reg.quantize_count == 1
    assert reg.compile_count == 1
    assert reg.exec_hits >= 1
    assert reg.executable("tiny", 4) is reg.executable("tiny", 4)

    # a new bucket is a new executable, same model
    reg.executable("tiny", 2)
    assert reg.compile_count == 2 and reg.quantize_count == 1

    with pytest.raises(KeyError):
        reg.model("missing")


def test_install_invalidates_stale_executables(served):
    """Re-installing a model under an id must drop wave executables that
    hold the previous model's weights as baked-in constants."""
    _, _, qnet, images = served
    reg = _registry(qnet)
    e1 = reg.executable("m", 2)
    q2 = qnet.with_softmax("precise")
    reg.install("m", q2)
    e2 = reg.executable("m", 2)
    assert e2 is not e1
    x = np.zeros((2,) + tuple(CIFAR10.input_shape), np.float32)
    x[:2] = images[:2]
    np.testing.assert_array_equal(
        np.asarray(e2(x)[0]),
        np.asarray(q2.forward(q2.quantize_input(jnp.asarray(x)))))


# ---------------------------------------------------------------------------
# sharded execution
# ---------------------------------------------------------------------------
def test_sharded_wave_bit_parity_on_1device_mesh(served):
    """Acceptance: serving/sharded.py under a 1-device mesh returns the
    same bits as the unsharded path — both standalone and end-to-end
    through an engine whose registry carries the mesh."""
    _, _, qnet, images = served
    mesh = make_host_mesh(("pod", "data", "model"))
    x = np.zeros((4,) + tuple(CIFAR10.input_shape), np.float32)
    x[:3] = images[:3]
    plain, meshed = compile_wave(qnet, 4), compile_wave(qnet, 4, mesh=mesh)
    for a, b in zip(plain(x), meshed(x)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    reg = _registry(qnet)
    reg.mesh = mesh
    engine = CapsServeEngine(reg, buckets=(4,), clock=FakeClock())
    engine.submit_many(images[:3], "m")
    done = engine.drain()
    v = np.asarray(qnet.forward(qnet.quantize_input(
        jnp.asarray(images[:3]))))
    for c in done:
        np.testing.assert_array_equal(c.v_q, v[c.rid])


@pytest.mark.slow
def test_sharded_wave_bit_parity_on_8device_mesh():
    """The wave really splits over the BATCH axes of a multi-device mesh
    (forced-host-device subprocess, same pattern as test_distributed) and
    still matches the unsharded bits."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.nn import CIFAR10, CapsPipeline
        from repro.serving import compile_wave

        pipe = CapsPipeline.from_config(CIFAR10)
        params = pipe.init(jax.random.key(0))
        rng = np.random.default_rng(3)
        calib = jnp.asarray(rng.uniform(
            0, 1, (8,) + CIFAR10.input_shape).astype(np.float32))
        qnet = pipe.quantize(params, calib)
        mesh = Mesh(np.asarray(jax.devices()).reshape(1, 8, 1),
                    ("pod", "data", "model"))
        x = rng.uniform(0, 1, (8,) + CIFAR10.input_shape).astype(np.float32)
        plain, meshed = compile_wave(qnet, 8), compile_wave(qnet, 8, mesh=mesh)
        assert not meshed.in_sharding.is_fully_replicated  # really split
        for a, b in zip(plain(x), meshed(x)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """) % SRC
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])


# ---------------------------------------------------------------------------
# satellites: with_softmax plan edit, class_lengths out_frac, calibrate
# ---------------------------------------------------------------------------
def test_with_softmax_is_a_pure_plan_edit(served):
    params, calib, qnet, images = served
    q2 = qnet.with_softmax("precise")
    # original untouched; every RoutingPlan flipped; conv plans untouched
    assert qnet.plan["caps"].softmax_impl == "q7"
    for name, p in q2.plan.layers.items():
        if isinstance(p, RoutingPlan):
            assert p.softmax_impl == "precise"
        else:
            assert p is qnet.plan.layers[name]

    # the edit is equivalent to building the pipeline with that softmax
    pipe2 = CapsPipeline.from_config(CIFAR10, softmax_impl="precise")
    qnet2 = pipe2.quantize(params, calib)
    xq = qnet.quantize_input(jnp.asarray(images[:2]))
    np.testing.assert_array_equal(np.asarray(q2.forward(xq)),
                                  np.asarray(qnet2.forward(xq)))
    # and round-trips back to the original bits
    np.testing.assert_array_equal(
        np.asarray(q2.with_softmax("q7").forward(xq)),
        np.asarray(qnet.forward(xq)))


def test_class_lengths_uses_plan_out_frac(served):
    """Regression for the hardcoded /128: a non-default squash_out_frac
    must rescale class lengths by its own 2^-out_frac."""
    _, _, qnet, images = served
    xq = qnet.quantize_input(jnp.asarray(images[:2]))
    def ref_lengths(v, out_frac):
        ss = np.sum(np.asarray(v, np.int64) ** 2, -1).astype(np.float32)
        return np.sqrt(ss) * np.float32(2.0 ** -out_frac)

    v7 = qnet.forward(xq)
    np.testing.assert_array_equal(np.asarray(qnet.class_lengths(v7)),
                                  ref_lengths(v7, 7))

    plan6 = dataclasses.replace(
        qnet.plan, layers={**qnet.plan.layers, "caps": dataclasses.replace(
            qnet.plan.layers["caps"], squash_out_frac=6)})
    q6 = dataclasses.replace(qnet, plan=plan6)
    assert q6.plan["caps"].out_frac == 6
    v6 = q6.forward(xq)
    np.testing.assert_array_equal(np.asarray(q6.class_lengths(v6)),
                                  ref_lengths(v6, 6))
    # Q0.6 lengths land near the Q0.7 ones once both are dequantized
    np.testing.assert_allclose(np.asarray(q6.class_lengths(v6)),
                               np.asarray(qnet.class_lengths(v7)),
                               atol=0.15)
    # the pallas backend falls back to the oracle loop off the Q0.7 plan
    np.testing.assert_array_equal(
        np.asarray(q6.with_backend("pallas").forward(xq)), np.asarray(v6))


def test_calibrate_device_side_accumulation_matches(served):
    """The single-sync calibrate must reproduce the per-batch max|x|
    semantics, including a partial trailing batch."""
    params, calib, qnet, _ = served
    pipe = qnet.pipeline
    stats_batched = pipe.calibrate(params, calib[:10], batch=4)
    stats_single = pipe.calibrate(params, calib[:10], batch=10)
    assert set(stats_batched.max_abs) == set(stats_single.max_abs)
    for k, v in stats_single.max_abs.items():
        assert stats_batched[k] == pytest.approx(v, rel=1e-6), k
    # and against an unjitted reference walk
    _, taps = pipe.forward(params, calib[:10], with_taps=True)
    for k, t in taps.items():
        assert stats_batched[k] == pytest.approx(
            float(jnp.max(jnp.abs(t))), rel=1e-5), k


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_metrics_accounting():
    m = ServeMetrics()
    m.record_submit(0.0, 1)
    m.record_submit(0.5, 2)
    m.record_wave(bucket=8, n_real=4, exec_s=2.0, t_done=4.0,
                  latencies_s=[1.0, 2.0, 3.0, 4.0])
    m.record_wave(bucket=2, n_real=1, exec_s=1.0, t_done=10.0,
                  latencies_s=[5.0])
    assert m.images_done == 5 and m.waves_run == 2
    assert m.latency_percentile(50) == pytest.approx(3.0)
    assert m.latency_percentile(99) == pytest.approx(4.96)
    assert m.occupancy() == pytest.approx((0.5 + 0.5) / 2)
    assert m.images_per_s() == pytest.approx(5 / 10.0)   # wall 0 -> 10
    assert m.max_queue_depth() == 2
    assert "5 imgs in 2 waves" in m.report()

    empty = ServeMetrics()
    assert np.isnan(empty.latency_percentile(50))
    assert np.isnan(empty.images_per_s())
