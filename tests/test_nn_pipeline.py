"""Tests for the typed capsule layer/pipeline API (repro.nn).

Core guarantees:
  * the typed int8 path is bit-identical to the legacy string-keyed
    qcapsnet_forward for every paper config (same weights, same
    calibration set) — through BOTH the typed plan and a round-trip via
    the legacy shift table;
  * calibration is complete by construction: every stats key a layer's
    plan() reads is emitted as a tap by its fwd_f32();
  * footprint accounting uses real itemsizes (int32 leaves count 4 B)
    and reproduces the paper's ~75 % saving (Table 2).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capsnet as C
from repro.core.capsnet_q7 import QCapsNet, qcapsnet_forward
from repro.nn import compat
from repro.nn.pipeline import CapsPipeline
from repro.quant import ptq


def _setup(cfg, n_calib=32, n_test=2, seed=7):
    params = C.init_capsnet(jax.random.key(0), cfg)
    rng = np.random.default_rng(seed)
    calib = jnp.asarray(
        rng.uniform(0, 1, (n_calib,) + cfg.input_shape).astype(np.float32))
    x = jnp.asarray(
        rng.uniform(0, 1, (n_test,) + cfg.input_shape).astype(np.float32))
    return params, calib, x


@pytest.mark.parametrize("name", sorted(C.CAPSNET_CONFIGS))
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
def test_pipeline_q7_bit_identical_to_legacy(name, rounding):
    """CapsPipeline.forward_q7 == legacy qcapsnet_forward, bit for bit,
    for all three paper configs — and the legacy shift table derived from
    the typed plans reproduces the same output when translated back."""
    cfg = C.CAPSNET_CONFIGS[name]
    params, calib, x = _setup(cfg)

    qnet = ptq.quantize_pipeline(params, cfg, calib, rounding=rounding)
    legacy = ptq.quantize_capsnet(params, cfg, calib, rounding=rounding)

    xq = qnet.quantize_input(x)
    np.testing.assert_array_equal(
        np.asarray(xq),
        np.asarray(ptq.quantize_input(x, legacy.shifts["input_frac"])))

    v_typed = qnet.forward(xq)
    v_legacy = qcapsnet_forward(legacy, xq)
    assert v_typed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(v_typed), np.asarray(v_legacy))

    # weights agree leaf-for-leaf too (same Alg. 7 quantization)
    for a, b in zip(jax.tree_util.tree_leaves(qnet.qweights),
                    jax.tree_util.tree_leaves(legacy.weights)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_taps_cover_every_plan_input():
    """Completeness: the tap names each layer's plan() reads are exactly
    emitted by the float forward — no silent KeyError paths."""
    for cfg in C.CAPSNET_CONFIGS.values():
        pipe = CapsPipeline.from_config(cfg)
        params = pipe.init(jax.random.key(0))
        x = jnp.zeros((1,) + cfg.input_shape, jnp.float32)
        _, taps = pipe.forward(params, x, with_taps=True)
        missing = set(pipe.tap_names()) - set(taps)
        assert not missing, (cfg.name, missing)
        # and the plan actually builds from those taps alone
        stats = pipe.calibrate(params, jnp.ones((2,) + cfg.input_shape))
        plan = pipe.plan(params, stats)
        assert set(plan.layers) == {l.name for l in pipe.layers}


def test_plan_shift_table_round_trip():
    """plan -> legacy shift table -> plan is lossless for execution."""
    cfg = C.MNIST
    params, calib, x = _setup(cfg)
    qnet = ptq.quantize_pipeline(params, cfg, calib)
    shifts = compat.plan_to_shifts(qnet.plan)
    plan2 = compat.shifts_to_plan(shifts, len(cfg.conv_filters),
                                  cfg.routings)
    xq = qnet.quantize_input(x)
    v1 = qnet.pipeline.forward_q7(qnet.qweights, qnet.plan, xq)
    v2 = qnet.pipeline.forward_q7(qnet.qweights, plan2, xq)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_softmax_impl_is_a_plan_field():
    """The q7/precise softmax choice travels through the plan (no
    monkey-patched method on QCapsNet)."""
    assert "softmax" not in vars(QCapsNet)
    cfg = C.CIFAR10
    params, calib, x = _setup(cfg)
    qnet = ptq.quantize_pipeline(params, cfg, calib)
    xq = qnet.quantize_input(x)
    v_q7 = qnet.forward(xq)
    v_precise = qnet.with_softmax("precise").forward(xq)
    assert qnet.plan["caps"].softmax_impl == "q7"
    assert v_precise.shape == v_q7.shape
    # the legacy shim honours the field the same way
    legacy = ptq.quantize_capsnet(params, cfg, calib)
    lp = dataclasses.replace(legacy, softmax_impl="precise")
    np.testing.assert_array_equal(np.asarray(qcapsnet_forward(lp, xq)),
                                  np.asarray(v_precise))


def test_pallas_backend_matches_oracle():
    """backend="pallas" (interpret mode on CPU) is bit-identical to the
    jnp oracle on the smallest paper geometry."""
    cfg = C.CIFAR10
    params, calib, x = _setup(cfg, n_calib=16, n_test=1)
    qnet = ptq.quantize_pipeline(params, cfg, calib)
    xq = qnet.quantize_input(x)
    v_jnp = qnet.forward(xq)
    v_pal = qnet.with_backend("pallas").forward(xq)
    np.testing.assert_array_equal(np.asarray(v_jnp), np.asarray(v_pal))


def test_memory_bytes_uses_itemsize():
    """Regression: non-int8 leaves must be counted at their real width
    (the old sum counted every element as one byte)."""
    cfg = C.MNIST
    w = {"conv0": {"w": jnp.zeros((10,), jnp.int8),
                   "b": jnp.zeros((5,), jnp.int32)}}
    m = QCapsNet(cfg=cfg, weights=w, shifts={"input_frac": 7})
    assert m.memory_bytes() == 10 * 1 + 5 * 4 + 4 * 1


def test_mnist_L_footprint_matches_table2():
    """Paper Table 2, MNIST 'L': 1187.20 KB fp32 -> ~75 % int8 saving."""
    cfg = C.MNIST
    params, calib, _ = _setup(cfg)
    qm = ptq.quantize_capsnet(params, cfg, calib)
    rep = ptq.footprint_report(params, qm)
    # paper's KB are decimal: 296.8k params x 4 B = 1187.20 KB
    assert C.param_bytes_fp32(params) / 1000.0 == pytest.approx(1187.20)
    assert 74.5 <= rep["saving_pct"] <= 75.0       # paper: 74.99 %
    assert qm.memory_bytes() / 1000.0 == pytest.approx(1187.20 / 4, abs=0.5)
    # typed container agrees with the legacy accounting (plan table is a
    # few dozen int32 scalars, just like the shift dict)
    qnet = ptq.quantize_pipeline(params, cfg, calib)
    assert abs(qnet.memory_bytes() - qm.memory_bytes()) < 256
