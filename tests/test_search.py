"""Tests for repro.search — the quantization/variant Pareto search.

Pinned guarantees:
  * CandidateSpec round-trips JSON, canonicalizes deltas, and rejects
    out-of-range reductions and unknown variant names;
  * SearchSpace's delta algebra produces plans that pass the full
    plancheck shift algebra by construction, with every dependent
    shift (out/bias/per-channel/per-out) recomputed;
  * the per-out routing W chain: spec -> qnet -> EdgeVM bits match the
    jnp oracle, survive the `.capsbin` round-trip, and a corrupted
    per-out shift table is a plancheck finding;
  * costmodel overhead surcharges are exact (per-channel conv, per-out
    routing, approximate variants) and zero for default plans;
  * `CapsTrainer(rng=...)` calibration subsampling is reproducible per
    seed, and `rng=None` keeps the legacy fixed calibration set;
  * identical SearchConfig seeds reproduce byte-identical
    `repro.search/v1` docs, for both strategies;
  * acceptance (tiny budget on edge_tiny): >= 3 frontier points, every
    one export/check/bit-verified with zero checker findings, mutually
    non-dominated, and at least one point strictly dominating the
    default q7 plan on packed memory or estimated latency within 0.5 %
    accuracy;
  * frontier points rebuild deterministically (`rebuild_point`) and
    export through `export_caps --from-search`.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.captrain.trainer import CapsTrainer, TrainConfig
from repro.data.synthetic import ImageTask
from repro.edge import EdgeVM, lower, total_latency_ms
from repro.edge.costmodel import (MCU_PROFILES,
                                  PER_CHANNEL_CONV_ELEM_FACTOR,
                                  PER_OUT_ROUTING_ELEM_FACTOR,
                                  SOFTMAX_ELEM_FACTOR,
                                  SQUASH_ELEM_FACTOR, op_counts)
from repro.launch import export_caps, search_caps
from repro.nn.pipeline import CapsPipeline
from repro.search import (SearchConfig, CandidateSpec, SearchSpace,
                          dominated_pairs, dominates, frontier_table_rows,
                          pareto, rebuild_point, run_search, save_doc)
from repro.search.objective import Candidate
from repro.serving.registry import EDGE_TINY


# ---------------------------------------------------------------------------
# fixtures
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_space():
    """An untrained edge_tiny SearchSpace (plan algebra and lowering do
    not need trained weights)."""
    pipe = CapsPipeline.from_config(EDGE_TINY)
    params = pipe.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    calib = rng.uniform(0, 1, (16, 16, 16, 1)).astype(np.float32)
    return SearchSpace(EDGE_TINY, params, calib)


@pytest.fixture(scope="module")
def search_doc():
    """One real (tiny) coordinate search run on edge_tiny."""
    cfg = SearchConfig(model="edge_tiny", strategy="coordinate",
                       budget=8, float_steps=8, eval_n=64,
                       verify_n=2, seed=0)
    return cfg, run_search(cfg)


# ---------------------------------------------------------------------------
# CandidateSpec
# ---------------------------------------------------------------------------
def test_spec_roundtrip_canonical_and_validation():
    s = CandidateSpec(softmax="approx",
                      w_frac_deltas=(("pcap", -2), ("conv0", -1)),
                      out_frac_deltas=(("conv0", -1),))
    assert s.w_frac_deltas == (("conv0", -1), ("pcap", -2))  # sorted
    assert CandidateSpec.from_json(
        json.loads(json.dumps(s.to_json()))) == s
    assert s.with_delta("w_frac_deltas", "pcap", 0).w_frac_deltas == \
        (("conv0", -1),)                                     # 0 removes
    # the default variant canonicalizes to "" (one spec per model)
    assert CandidateSpec().with_variant("softmax", "q7") == CandidateSpec()
    with pytest.raises(ValueError):
        CandidateSpec(w_frac_deltas=(("conv0", -4),))        # too deep
    with pytest.raises(ValueError):
        CandidateSpec(w_frac_deltas=(("conv0", 1),))         # refinement
    with pytest.raises(ValueError):
        CandidateSpec(softmax="nope")


# ---------------------------------------------------------------------------
# SearchSpace delta algebra
# ---------------------------------------------------------------------------
def test_build_plan_recomputes_all_shifts(tiny_space):
    spec = CandidateSpec(per_channel=True, per_channel_w=True,
                         w_frac_deltas=(("conv0", -2), ("caps", -1)),
                         out_frac_deltas=(("conv0", -1),))
    base = tiny_space.build_plan(CandidateSpec(per_channel=True,
                                               per_channel_w=True))
    plan = tiny_space.build_plan(spec)
    assert plan.check() == []
    c0, b0 = plan["conv0"], base["conv0"]
    assert c0.w_frac == b0.w_frac - 2
    assert c0.out_frac == b0.out_frac - 1
    assert c0.out_shift == c0.in_frac + c0.w_frac - c0.out_frac
    assert c0.w_frac_per_channel == tuple(f - 2
                                          for f in b0.w_frac_per_channel)
    caps, bcaps = plan["caps"], base["caps"]
    assert caps.W_frac == bcaps.W_frac - 1
    assert caps.W_frac_per_out == tuple(f - 1
                                        for f in bcaps.W_frac_per_out)
    assert caps.uhat_shift_per_out == tuple(
        caps.in_frac + f - caps.uhat_frac for f in caps.W_frac_per_out)
    # chaining: conv0's new out_frac is pcap's in_frac
    assert plan["pcap"].conv.in_frac == c0.out_frac


def test_axes_deterministic(tiny_space):
    axes = tiny_space.axes()
    assert axes == tiny_space.axes()
    assert ("w_frac", "caps") in axes
    assert ("out_frac", "caps") not in axes       # routing out is squash
    assert axes[-2:] == [("flag", "per_channel"), ("flag", "per_channel_w")]


# ---------------------------------------------------------------------------
# per-out routing W chain (spec -> oracle == VM -> capsbin -> plancheck)
# ---------------------------------------------------------------------------
def test_per_out_routing_bits_and_roundtrip(tiny_space, tmp_path):
    qnet = tiny_space.build_qnet(CandidateSpec(per_channel_w=True))
    assert qnet.plan["caps"].per_out
    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1, (4, 16, 16, 1)).astype(np.float32)
    x_q = np.asarray(qnet.quantize_input(x))
    program = lower(qnet)
    assert program.ops[-1].attrs["uhat_shift_per_out"] == \
        tuple(qnet.plan["caps"].uhat_shift_per_out)
    np.testing.assert_array_equal(EdgeVM(program).run(x_q),
                                  np.asarray(qnet.forward(x_q)))
    paths = program.save(tmp_path / "per_out")
    from repro.edge.program import EdgeProgram
    reloaded = EdgeProgram.load(paths["capsbin"])
    assert program.same_as(reloaded)
    np.testing.assert_array_equal(EdgeVM(reloaded).run(x_q),
                                  np.asarray(qnet.forward(x_q)))


def test_per_out_corruption_is_plancheck_finding(tiny_space):
    plan = tiny_space.build_plan(CandidateSpec(per_channel_w=True))
    caps = plan["caps"]
    bad = dataclasses.replace(caps, uhat_shift_per_out=tuple(
        s + 1 for s in caps.uhat_shift_per_out))
    findings = dataclasses.replace(
        plan, layers={**plan.layers, "caps": bad}).check()
    assert any("uhat-shift" in f.check for f in findings)
    short = dataclasses.replace(caps,
                                W_frac_per_out=caps.W_frac_per_out[:-1])
    findings = dataclasses.replace(
        plan, layers={**plan.layers, "caps": short}).check()
    assert any("per-out-length" in f.check for f in findings)


# ---------------------------------------------------------------------------
# costmodel on per-channel / non-default-variant programs (satellite)
# ---------------------------------------------------------------------------
def test_costmodel_overhead_exact(tiny_space):
    base = lower(tiny_space.build_qnet(CandidateSpec()))
    for op in base.ops:
        assert op_counts(base, op)["overhead_ops"] == 0.0
    base_ms = total_latency_ms(base, "cortex-m7")

    pc = lower(tiny_space.build_qnet(CandidateSpec(per_channel=True)))
    saw_per_channel = 0
    for op in pc.ops:
        c = op_counts(pc, op)
        if not op.attrs.get("out_shift_per_channel"):
            continue
        saw_per_channel += 1
        requant_elems = (c["elems"] if op.kind == "CONV_Q7"
                         else c["elems"] - pc.tensor(op.output).size)
        # default squash -> the per-channel table is the only surcharge
        assert c["overhead_ops"] == \
            requant_elems * PER_CHANNEL_CONV_ELEM_FACTOR
    assert saw_per_channel >= 2                  # conv0 and pcap
    assert total_latency_ms(pc, "cortex-m7") > base_ms

    po = lower(tiny_space.build_qnet(CandidateSpec(per_channel_w=True)))
    rop = po.ops[-1]
    a = rop.attrs
    c = op_counts(po, rop)
    assert c["overhead_ops"] == (a["num_out"] * a["num_in"] * a["out_dim"]
                                 * PER_OUT_ROUTING_ELEM_FACTOR)
    assert total_latency_ms(po, "cortex-m7") > base_ms

    ap = lower(tiny_space.build_qnet(
        CandidateSpec(softmax="approx", squash="approx")))
    rop = ap.ops[-1]
    c = op_counts(ap, rop)
    a = rop.attrs
    r, j, i, o = a["routings"], a["num_out"], a["num_in"], a["out_dim"]
    assert c["overhead_ops"] == pytest.approx(
        r * j * i * (SOFTMAX_ELEM_FACTOR["approx"] - 1.0)
        + r * j * o * (SQUASH_ELEM_FACTOR["approx"] - 1.0))
    assert total_latency_ms(ap, "cortex-m7") < base_ms
    for profile in MCU_PROFILES:              # both parts rank the same way
        assert total_latency_ms(ap, profile) < \
            total_latency_ms(base, profile)


# ---------------------------------------------------------------------------
# trainer calibration rng (satellite)
# ---------------------------------------------------------------------------
def test_trainer_calib_rng_reproducible():
    tcfg = TrainConfig(dataset="edge_tiny", calib_n=8)
    a = CapsTrainer(EDGE_TINY, tcfg, rng=np.random.default_rng(7))
    b = CapsTrainer(EDGE_TINY, tcfg, rng=np.random.default_rng(7))
    first = np.asarray(a.calib_images())
    np.testing.assert_array_equal(first, np.asarray(b.calib_images()))
    # a second draw advances the generator (same on both replicas)
    second = np.asarray(a.calib_images())
    np.testing.assert_array_equal(second, np.asarray(b.calib_images()))
    assert not np.array_equal(first, second)
    # rng=None keeps the legacy fixed calibration set bit-exactly
    legacy = CapsTrainer(EDGE_TINY, tcfg).calib_images()
    imgs, _ = ImageTask("edge_tiny", seed=tcfg.calib_seed).batch(0, 8)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(imgs))


# ---------------------------------------------------------------------------
# frontier math (pure)
# ---------------------------------------------------------------------------
def _cand(acc, flash, ram=1, ms=1.0, ok=True):
    return Candidate(CandidateSpec(), {"acc": acc,
                                       "flash_packed_bytes": flash,
                                       "ram_bytes": ram, "est_ms_m7": ms},
                     ok)


def test_pareto_and_dominance():
    a = _cand(0.9, 100)
    b = _cand(0.8, 100)              # dominated by a
    c = _cand(0.8, 50)               # trades acc for flash
    d = _cand(0.9, 100)              # duplicate of a -> deduped
    e = _cand(0.99, 10, ok=False)    # rejected: never on the frontier
    front = pareto([a, b, c, d, e])
    assert [f.metrics["acc"] for f in front] == [0.9, 0.8]
    assert dominates(a.metrics, b.metrics)
    assert not dominates(b.metrics, c.metrics)
    assert not dominates(a.metrics, a.metrics)   # no strict edge
    assert dominated_pairs([f.to_json() for f in front]) == 0
    assert dominated_pairs([a.to_json(), b.to_json()]) == 1


# ---------------------------------------------------------------------------
# end-to-end: reproducibility, acceptance, rebuild, CLIs
# ---------------------------------------------------------------------------
def test_search_reproducible_per_seed(search_doc):
    cfg, doc = search_doc
    again = run_search(cfg)
    assert json.dumps(doc, sort_keys=True) == \
        json.dumps(again, sort_keys=True)


def test_random_strategy_reproducible():
    cfg = SearchConfig(model="edge_tiny", strategy="random", budget=5,
                       float_steps=8, eval_n=64, calib_n=16,
                       numerics_n=16, verify_n=2, seed=11)
    d1, d2 = run_search(cfg), run_search(cfg)
    assert json.dumps(d1, sort_keys=True) == json.dumps(d2, sort_keys=True)
    assert len(d1["evaluated"]) >= 2


def test_acceptance_frontier(search_doc):
    _, doc = search_doc
    front = doc["frontier"]
    assert len(front) >= 3
    for p in front:
        assert p["verified"] and p["checked"]
        assert p["metrics"]["checker_findings"] == 0
        assert p["plan"] is not None
    assert dominated_pairs(front) == 0
    # >= 1 point strictly dominates the default plan on memory or
    # estimated latency within the paper's 0.5 % accuracy band
    base = doc["baseline"]["metrics"]
    assert any(
        p["metrics"]["acc"] >= base["acc"] - 0.005
        and (p["metrics"]["flash_packed_bytes"] < base["flash_packed_bytes"]
             or p["metrics"]["est_ms_m7"] < base["est_ms_m7"])
        for p in front)


def test_frontier_table_rows(search_doc):
    from repro.captrain.evalq import format_rows
    _, doc = search_doc
    rows = frontier_table_rows(doc)
    assert len(rows) == len(doc["frontier"])
    for r in rows:
        assert r.source == "search"
        assert r.flash_bytes > 0 and r.ram_bytes > 0
    assert "search" in format_rows(rows)


def test_rebuild_point_matches_doc(search_doc):
    _, doc = search_doc
    point = doc["frontier"][0]["point"]
    qnet, entry, _ = rebuild_point(doc, point)     # asserts plan equality
    assert qnet.plan.check() == []
    with pytest.raises(ValueError):
        rebuild_point(doc, 10_000)


def test_export_caps_from_search(search_doc, tmp_path):
    _, doc = search_doc
    doc_path = tmp_path / "search.json"
    save_doc(doc, doc_path)
    out = tmp_path / "export"
    rc = export_caps.main(["--from-search", str(doc_path), "--point", "0",
                           "--out", str(out), "--verify-n", "2"])
    assert rc == 0
    assert list(out.glob("*.capsbin"))
    # a tampered plan in the doc must fail the rebuild drift guard
    bad = json.loads(json.dumps(doc))
    bad["frontier"][0]["plan"]["input_frac"] += 1
    bad_path = tmp_path / "bad.json"
    save_doc(bad, bad_path)
    rc = export_caps.main(["--from-search", str(bad_path), "--point", "0",
                           "--out", str(tmp_path / "bad_export")])
    assert rc == 2


def test_search_caps_cli(tmp_path):
    out = tmp_path / "doc.json"
    rc = search_caps.main(["--model", "edge_tiny", "--budget", "4",
                           "--float-steps", "4", "--eval-n", "32",
                           "--out", str(out), "--seed", "1"])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["schema"] == "repro.search/v1"
    assert doc["frontier"]
