"""Regenerate the C-emitter golden files after an INTENTIONAL emitter
change:

    PYTHONPATH=src python tests/golden/regen.py

then review the diff of tests/golden/golden_caps.{c,h} like any other
code change — the golden test exists to make emitter drift visible.

    PYTHONPATH=src python tests/golden/regen.py --check

compares instead of writing and exits 1 on any drift (the CI gate: a
PR that changes the emitter must also regenerate and commit the
goldens in the same diff).
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from test_edge import golden_program, golden_program_approx  # noqa: E402

from repro.edge import emit_c  # noqa: E402


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    check = "--check" in argv
    out = pathlib.Path(__file__).parent
    drifted = []
    for make in (golden_program, golden_program_approx):
        program = make()
        src = emit_c(program)
        for ext in ("c", "h"):
            path = out / f"{program.name}.{ext}"
            want = src[ext] + "\n"
            if not check:
                path.write_text(want)
                print(f"wrote {path}")
            elif not path.exists() or path.read_text() != want:
                drifted.append(path)
                print(f"DRIFT: {path} no longer matches the emitter "
                      f"output", file=sys.stderr)
            else:
                print(f"ok: {path}")
    if drifted:
        print(f"[regen] {len(drifted)} golden file(s) drifted — run "
              f"`python tests/golden/regen.py` and commit the diff",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
