"""Regenerate the C-emitter golden files after an INTENTIONAL emitter
change:

    PYTHONPATH=src python tests/golden/regen.py

then review the diff of tests/golden/golden_caps.{c,h} like any other
code change — the golden test exists to make emitter drift visible.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from test_edge import golden_program, golden_program_approx  # noqa: E402

from repro.edge import emit_c  # noqa: E402


def main():
    out = pathlib.Path(__file__).parent
    for make in (golden_program, golden_program_approx):
        program = make()
        src = emit_c(program)
        for ext in ("c", "h"):
            path = out / f"{program.name}.{ext}"
            path.write_text(src[ext] + "\n")
            print(f"wrote {path}")


if __name__ == "__main__":
    main()
