#include "golden_caps_approx.h"

const q7_t conv0_b[4] = {
    -90, -53, -16, 21
};

const q7_t conv0_w[36] = {
    -90, -53, -16, 21, 58, -86, -49, -12, 25, 62, -82, -45,
    -8, 29, 66, -78, -41, -4, 33, 70, -74, -37, 0, 37,
    74, -70, -33, 4, 41, 78, -66, -29, 8, 45, 82, -62
};

const int8_t conv0_out_shift_per_ch[4] = {
    9, 10, 9, 9
};

const int8_t conv0_bias_shift_per_ch[4] = {
    6, 7, 6, 6
};

const q7_t pcap_b[4] = {
    -90, -53, -16, 21
};

const q7_t pcap_w[144] = {
    -90, -53, -16, 21, 58, -86, -49, -12, 25, 62, -82, -45,
    -8, 29, 66, -78, -41, -4, 33, 70, -74, -37, 0, 37,
    74, -70, -33, 4, 41, 78, -66, -29, 8, 45, 82, -62,
    -25, 12, 49, 86, -58, -21, 16, 53, 90, -54, -17, 20,
    57, -87, -50, -13, 24, 61, -83, -46, -9, 28, 65, -79,
    -42, -5, 32, 69, -75, -38, -1, 36, 73, -71, -34, 3,
    40, 77, -67, -30, 7, 44, 81, -63, -26, 11, 48, 85,
    -59, -22, 15, 52, 89, -55, -18, 19, 56, -88, -51, -14,
    23, 60, -84, -47, -10, 27, 64, -80, -43, -6, 31, 68,
    -76, -39, -2, 35, 72, -72, -35, 2, 39, 76, -68, -31,
    6, 43, 80, -64, -27, 10, 47, 84, -60, -23, 14, 51,
    88, -56, -19, 18, 55, -89, -52, -15, 22, 59, -85, -48
};

const q7_t caps_W[64] = {
    -90, -53, -16, 21, 58, -86, -49, -12, 25, 62, -82, -45,
    -8, 29, 66, -78, -41, -4, 33, 70, -74, -37, 0, 37,
    74, -70, -33, 4, 41, 78, -66, -29, 8, 45, 82, -62,
    -25, 12, 49, 86, -58, -21, 16, 53, 90, -54, -17, 20,
    57, -87, -50, -13, 24, 61, -83, -46, -9, 28, 65, -79,
    -42, -5, 32, 69
};

const int8_t caps_caps_out_shifts[2] = {
    5, 5
};

const int8_t caps_caps_out_fracs[2] = {
    9, 9
};

const int8_t caps_agree_shifts[1] = {
    7
};

static q7_t arena[GOLDEN_CAPS_APPROX_ARENA_BYTES];
static q15_t scratch[(GOLDEN_CAPS_APPROX_SCRATCH_BYTES + 1) / 2];

void golden_caps_approx_run(const q7_t *input, q7_t *output)
{
    /* conv0: CONV_Q7 -> 6x6x4 q5 */
    capsnet_convolve_HWC_q7_per_channel(input, 8, 1, conv0_w, 4,
        3, 0, 1, conv0_b, conv0_bias_shift_per_ch,
        conv0_out_shift_per_ch, arena, 6, scratch, NULL);
    arm_relu_q7(arena, 144);
    /* pcap: PRIMARY_CAPS_Q7 -> 8x2 q7 */
    arm_convolve_HWC_q7_basic(arena, 6, 4, pcap_w, 4,
        3, 0, 2, pcap_b, PCAP_BIAS_SHIFT,
        PCAP_OUT_SHIFT, arena + 144, 2, scratch, NULL);
    capsnet_squash_q7_approx(arena + 144, 8, 2, PCAP_SQUASH_IN_FRAC, PCAP_SQUASH_OUT_FRAC);
    /* caps: CAPS_ROUTING_Q7 -> 2x2 q7 */
    capsnet_dynamic_routing_q7_softmax_approx_squash_approx(arena + 144, caps_W, 2,
        8, 2, 2, 2,
        CAPS_UHAT_SHIFT, CAPS_LOGIT_FRAC, caps_caps_out_shifts,
        caps_caps_out_fracs, caps_agree_shifts, CAPS_SQUASH_OUT_FRAC,
        arena, (q7_t *)scratch);
    for (int i = 0; i < GOLDEN_CAPS_APPROX_OUTPUT_BYTES; i++)
        output[i] = (arena)[i];
}

