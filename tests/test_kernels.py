"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracles,
swept over shapes / shifts / rounding modes.  Integer kernels must match
BIT-EXACTLY; float kernels to allclose."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def i8(shape):
    return jnp.asarray(RNG.integers(-128, 128, shape), jnp.int8)


@pytest.mark.parametrize("mkn", [(20, 30, 40), (128, 128, 128),
                                 (7, 257, 130), (1, 5, 3), (200, 64, 96)])
@pytest.mark.parametrize("shift", [0, 3, 9])
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
def test_q7_matmul_exact(mkn, shift, rounding):
    M, K, N = mkn
    a, b = i8((M, K)), i8((K, N))
    got = ops.matmul_q7(a, b, shift, rounding)
    want = ref.matmul_q7(a, b, shift, rounding)
    np.testing.assert_array_equal(got, want)


def test_q7_matmul_negative_shift():
    a, b = i8((8, 8)), i8((8, 8))
    got = ops.matmul_q7(a, b, -2)
    want = ref.matmul_q7(a, b, -2)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("batch", [(), (3,), (2, 5)])
def test_bmm_q7(batch):
    a = i8(batch + (12, 20))
    b = i8(batch + (20, 8))
    got = ops.bmm_q7(a, b, 4)
    want = ref.matmul_q7(a, b, 4) if not batch else None
    # oracle: einsum per batch
    acc = jnp.einsum("...mk,...kn->...mn", a.astype(jnp.int32),
                     b.astype(jnp.int32))
    want = ref.rshift_sat8(acc, 4)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("rd", [(100, 4), (1024, 6), (3, 8), (64, 16)])
@pytest.mark.parametrize("in_frac", [3, 5, 7, 9])
def test_squash_q7_exact(rd, in_frac):
    R, D = rd
    s = i8((R, D))
    got = ops.squash_q7(s, in_frac=in_frac)
    want = ref.squash_q7(s, in_frac=in_frac)
    np.testing.assert_array_equal(got, want)


def test_squash_q7_batched_shape():
    s = i8((2, 7, 11, 4))
    got = ops.squash_q7(s, in_frac=5)
    want = ref.squash_q7(s, in_frac=5)
    assert got.shape == s.shape
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("rounding", ["floor", "nearest"])
def test_routing_fused_exact(rounding):
    B, J, I, O = 3, 10, 64, 6
    u = i8((B, J, I, O))
    kw = dict(num_iters=3, caps_out_shifts=(8, 9, 9),
              caps_out_fracs=(7, 6, 6), agree_shifts=(8, 8), logit_frac=7)
    got = ops.routing_q7(u, rounding=rounding, **kw)
    want = ref.routing_q7_ref(u, 3, (8, 9, 9), (7, 6, 6), (8, 8), 7,
                              rounding=rounding)
    np.testing.assert_array_equal(got, want)


def test_routing_fused_matches_unfused_capsule_layer():
    """The fused kernel must agree with the step-by-step int8 capsule
    layer (core.capsnet_q7.capsule_layer_q7) — the fusion is a pure perf
    change, not a semantics change."""
    from repro.core.capsnet import MNIST
    from repro.core import capsnet_q7 as cq
    import dataclasses
    cfg = dataclasses.replace(MNIST, routings=3)
    B, J, I, O, D = 2, cfg.num_classes, 32, cfg.caps_dim, cfg.pcap_dim
    W = i8((J, I, O, D))
    u = i8((B, I, D))
    shifts = {"uhat_shift": 7, "logit_frac": 7,
              "caps_out_shift_0": 9, "caps_out_frac_0": 7,
              "caps_out_shift_1": 9, "caps_out_frac_1": 7,
              "caps_out_shift_2": 9, "caps_out_frac_2": 7,
              "agree_shift_0": 8, "agree_shift_1": 8}
    model = cq.QCapsNet(cfg=cfg, weights={"caps": {"W": W}}, shifts=shifts)
    want = cq.capsule_layer_q7(model, u)
    # fused path: compute u_hat the same way, then one kernel call
    acc = jnp.einsum("jiod,bid->bjio", W.astype(jnp.int32),
                     u.astype(jnp.int32))
    u_hat = ref.rshift_sat8(acc, shifts["uhat_shift"])
    got = ops.routing_q7(u_hat, num_iters=3, caps_out_shifts=(9, 9, 9),
                         caps_out_fracs=(7, 7, 7), agree_shifts=(8, 8),
                         logit_frac=7)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("mkn", [(33, 65, 19), (128, 128, 128), (4, 16, 300)])
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
def test_w8a8_exact(mkn, rounding):
    M, K, N = mkn
    a, w = i8((M, K)), i8((K, N))
    sh = jnp.asarray(RNG.integers(-2, 12, (N,)), jnp.int32)
    got = ops.w8a8_matmul(a, w, sh, rounding)
    want = ref.w8a8_matmul_ref(a, w, sh, rounding)
    np.testing.assert_array_equal(got, want)


def test_squash_float_close():
    s = jnp.asarray(RNG.normal(0, 1, (64, 6)), jnp.float32)
    np.testing.assert_allclose(ops.squash_float(s), ref.squash_float_ref(s),
                               atol=1e-5)


def test_isqrt_exact_floor_sqrt():
    n = jnp.asarray([0, 1, 2, 3, 4, 8, 15, 16, 17, 1023, 1024, 1 << 20,
                     (1 << 30) + 12345], jnp.int32)
    got = ref.isqrt_newton(n)
    want = jnp.asarray([int(np.sqrt(float(v))) for v in n], jnp.int32)
    np.testing.assert_array_equal(got, want)
