"""Per-architecture smoke tests (reduced configs, same family) + cache
consistency: prefill-then-decode must agree with a longer prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.launch.train import reduced
from repro.models.transformer import build_model, decode_alloc


def make_batch(cfg, B, S, key=0):
    rng = np.random.default_rng(key)
    batch = {"inputs": jnp.asarray(
        rng.integers(1, min(cfg.vocab_size, 128), (B, S)), jnp.int32)}
    batch["targets"] = jnp.roll(batch["inputs"], -1, axis=1)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.num_prefix_embeds, cfg.d_model)),
            jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config of the same family: one forward/train step on CPU,
    assert output shapes + finite values (assignment requirement)."""
    cfg = reduced(get_config(arch), d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = reduced(get_config(arch), d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, alloc=decode_alloc(S)))(params,
                                                                 batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    pos = S + (cfg.num_prefix_embeds if cfg.family == "vlm" else 0)
    tok = jnp.ones((B, 1), jnp.int32)
    logits2, cache = jax.jit(model.decode_step)(
        params, cache, tok, jnp.asarray(pos, jnp.int32))
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


@pytest.mark.parametrize("mixer", ["attn", "swa", "mamba", "mlstm", "slstm"])
def test_decode_consistency_with_prefill(mixer):
    """Feeding token t through decode_step after prefill(t[:n]) must agree
    with prefill(t[:n+1]) — validates cache semantics per mixer type."""
    from tests.conftest import tiny_lm_config
    kw = {}
    if mixer == "swa":
        kw = dict(blocks=(("swa", "mlp"),), window_size=8)
    elif mixer in ("mamba",):
        kw = dict(blocks=(("mamba", "mlp"),))
    elif mixer == "mlstm":
        kw = dict(blocks=(("mlstm", "none"),), d_ff=0, num_kv_heads=4)
    elif mixer == "slstm":
        kw = dict(blocks=(("slstm", "none"),), d_ff=0, num_kv_heads=4)
    cfg = tiny_lm_config(**kw)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 12
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, 200, (B, S + 1)), jnp.int32)

    lg_full, _ = model.prefill(params, {"inputs": toks},
                               alloc=decode_alloc(S + 1))
    lg_pre, cache = model.prefill(params, {"inputs": toks[:, :S]},
                                  alloc=decode_alloc(S + 1))
    lg_dec, _ = model.decode_step(params, cache, toks[:, S:S + 1],
                                  jnp.asarray(S, jnp.int32))
    a = np.asarray(lg_full, np.float32)
    b = np.asarray(lg_dec, np.float32)
    # bf16 compute along different reduction orders -> loose tolerance
    np.testing.assert_allclose(a, b, atol=0.15, rtol=0.05)
    assert (a.argmax(-1) == b.argmax(-1)).all(), mixer


def test_swa_ring_cache_drops_old_positions():
    """With window w, decode attention must ignore positions <= pos-w:
    perturbing an old token must not change the decode logits."""
    from tests.conftest import tiny_lm_config
    cfg = tiny_lm_config(blocks=(("swa", "mlp"),), window_size=4)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    S = 10
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(1, 200, (1, S)), jnp.int32)
    toks2 = toks.at[0, 0].set(7)         # outside the window at decode time
    out = []
    for t in (toks, toks2):
        _, cache = model.prefill(params, {"inputs": t},
                                 alloc=decode_alloc(S))
        lg, _ = model.decode_step(params, cache,
                                  jnp.ones((1, 1), jnp.int32),
                                  jnp.asarray(S, jnp.int32))
        out.append(np.asarray(lg, np.float32))
    np.testing.assert_allclose(out[0], out[1], atol=1e-6)


def test_w8a8_quantized_model_close_to_float():
    from tests.conftest import tiny_lm_config
    from repro.quant.lm_quant import quantize_lm_params
    cfg = tiny_lm_config()
    model = build_model(cfg)
    params = model.init(jax.random.key(3))
    batch = make_batch(cfg, 2, 16)
    lg_f, _ = model.prefill(params, batch, alloc=32)
    lg_q, _ = model.prefill(quantize_lm_params(params), batch, alloc=32)
    a, b = np.asarray(lg_f, np.float32), np.asarray(lg_q, np.float32)
    # int8 weights + dynamic int8 activations: small logit perturbation
    assert np.abs(a - b).max() < 0.35, np.abs(a - b).max()
    agree = (a.argmax(-1) == b.argmax(-1)).mean()
    assert agree >= 0.5


def test_moe_capacity_and_aux_loss():
    from tests.conftest import tiny_lm_config
    from repro.models import moe
    cfg = tiny_lm_config(blocks=(("attn", "moe"),), num_experts=4,
                         family="moe")
    params = moe.init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 16, 64)),
                    jnp.bfloat16)
    y, aux = moe.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) > 0.9  # ~1 when balanced


def test_mlstm_chunked_equals_recurrent():
    """The chunkwise-parallel mLSTM (§Perf A1) must match the per-step
    recurrence to fp32 tolerance, including carried state across chunks."""
    import dataclasses
    from tests.conftest import tiny_lm_config
    from repro.models import xlstm

    base = tiny_lm_config(blocks=(("mlstm", "none"),), d_ff=0,
                          num_kv_heads=4, vocab_size=64)
    p = xlstm.init_mlstm(jax.random.key(0), base)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (2, 32, 64)),
                    jnp.float32).astype(jnp.bfloat16)
    cfg_r = dataclasses.replace(base, xlstm_impl="recurrent")
    cfg_c = dataclasses.replace(base, xlstm_impl="chunked", xlstm_chunk=8)
    y_r, cache_r = xlstm.mlstm_apply(p, x, cfg_r, mode="prefill")
    y_c, cache_c = xlstm.mlstm_apply(p, x, cfg_c, mode="prefill")
    np.testing.assert_allclose(np.asarray(y_r, np.float32),
                               np.asarray(y_c, np.float32),
                               atol=3e-2, rtol=3e-2)
    np.testing.assert_allclose(np.asarray(cache_r["C"]),
                               np.asarray(cache_c["C"]), atol=1e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(cache_r["m"]),
                               np.asarray(cache_c["m"]), atol=1e-4)


def test_int8_kv_cache_decode_close_to_bf16():
    """§Perf C5: int8 KV cache (the paper's Q-format on the cache) must
    produce near-identical decode logits to the bf16 cache."""
    from tests.conftest import tiny_lm_config
    cfg_f = tiny_lm_config()
    cfg_q = tiny_lm_config(kv_cache_int8=True)
    model_f = build_model(cfg_f)
    model_q = build_model(cfg_q)
    params = model_f.init(jax.random.key(5))
    S = 12
    toks = jnp.asarray(np.random.default_rng(2).integers(1, 200, (2, S + 1)),
                       jnp.int32)
    lg_full, _ = model_f.prefill(params, {"inputs": toks},
                                 alloc=decode_alloc(S + 1))
    _, cache_q = model_q.prefill(params, {"inputs": toks[:, :S]},
                                 alloc=decode_alloc(S + 1))
    lg_q, _ = model_q.decode_step(params, cache_q, toks[:, S:S + 1],
                                  jnp.asarray(S, jnp.int32))
    a = np.asarray(lg_full, np.float32)
    b = np.asarray(lg_q, np.float32)
    assert np.abs(a - b).max() < 0.25, np.abs(a - b).max()
    assert (a.argmax(-1) == b.argmax(-1)).all()
