"""Tests for the first-class operator-variant API (repro.nn.variants).

Pinned guarantees:
  * every registered (softmax, squash) combination executes bit-
    identically across `fwd_q7` and the NumPy `EdgeVM` on edge_tiny,
    for both rounding modes, and round-trips through the QAT plan JSON
    side-car codec and the `.capsbin` attrs (export -> `load_qnet` ->
    re-lower `same_as` -> VM bit-parity);
  * variant references are validated everywhere they enter: plan
    construction, plan JSON, imported artifacts, and the CLIs all
    reject unknown names with the registered ones listed;
  * variant selection is a pure plan edit (`with_variants`): weights,
    shifts, and non-variant layer plans are untouched (identity-
    preserved), and editing back restores the original bits;
  * the pallas backend's oracle fallback for non-default variants is
    observable — a counter per (op, variant) plus one warning per (op,
    variant) / per (model, variant) — never silent;
  * QAT's fake-quant faces follow the plan's variants: the approx
    softmax fq face reproduces `int8_ops.softmax_q7_approx` exactly on
    the integer grid;
  * acceptance: on the trained edge_tiny seed, every approximate
    variant's int8 accuracy is within 1.0 % of the q7+exact baseline
    (the ISLPED'22 claim this repo inherits), for both roundings.
"""
import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.edge import EdgeVM, lower, to_qnet
from repro.edge.program import EdgeProgram
from repro.nn.backend import PallasBackend
from repro.nn.pipeline import CapsPipeline
from repro.nn.plans import RoutingPlan, plan_from_json, plan_to_json
from repro.nn.variants import (REGISTRY, VariantSet, all_variant_sets)
from repro.quant import int8_ops as q
from repro.serving import EDGE_TINY, ModelRegistry, ModelSpec

ALL_SETS = all_variant_sets()
_cache = {}


def built(rounding="floor"):
    """edge_tiny PTQ build + int8 probe inputs, cached per rounding;
    variant sweeps are plan edits on top (weights shared by design)."""
    if rounding not in _cache:
        pipe = CapsPipeline.from_config(EDGE_TINY)
        params = pipe.init(jax.random.key(0))
        rng = np.random.default_rng(7)
        calib = jnp.asarray(rng.uniform(
            0, 1, (16,) + EDGE_TINY.input_shape).astype(np.float32))
        x = jnp.asarray(rng.uniform(
            0, 1, (2,) + EDGE_TINY.input_shape).astype(np.float32))
        qnet = pipe.quantize(params, calib, rounding=rounding)
        _cache[rounding] = (qnet, np.asarray(qnet.quantize_input(x)))
    return _cache[rounding]


# ---------------------------------------------------------------------------
# registry + VariantSet basics
# ---------------------------------------------------------------------------
def test_registry_defaults_and_names():
    assert REGISTRY.default("softmax") == "q7"
    assert REGISTRY.default("squash") == "exact"
    assert set(REGISTRY.names("softmax")) == {"q7", "precise", "approx"}
    assert set(REGISTRY.names("squash")) == {"exact", "approx"}
    v = REGISTRY.get("softmax", "approx")
    assert v.plan_field == "softmax_impl"
    assert v.c_symbol == "capsnet_softmax_q7_approx"


def test_unknown_variant_errors_list_registered_names():
    with pytest.raises(ValueError, match="approx, precise, q7"):
        REGISTRY.get("softmax", "nope")
    with pytest.raises(ValueError, match="approx, exact"):
        VariantSet(squash="nope")
    # plan dataclasses validate at construction too (frozen replace
    # included), so no unvalidated reference can enter a plan
    rp = built()[0].plan["caps"]
    with pytest.raises(ValueError, match="registered"):
        dataclasses.replace(rp, softmax_impl="evil")


def test_variant_set_attaches_to_plan():
    qnet, x_q = built()
    assert qnet.plan.variants == VariantSet()
    assert qnet.variants.is_default()

    vs = VariantSet(softmax="approx", squash="approx")
    q2 = qnet.with_variants(vs)
    assert q2.plan.variants == vs and q2.variants.tag == "approx+approx"
    # pure plan edit: weights untouched, conv plans identity-preserved
    assert q2.qweights is qnet.qweights
    for name, p in q2.plan.layers.items():
        if not (hasattr(p, "softmax_impl") or hasattr(p, "squash_impl")):
            assert p is qnet.plan.layers[name]
    # editing back restores the original bits
    np.testing.assert_array_equal(
        np.asarray(q2.with_variants(VariantSet()).forward(
            jnp.asarray(x_q))),
        np.asarray(qnet.forward(jnp.asarray(x_q))))
    # and a with_squash edit equals building the pipeline that way
    pipe2 = CapsPipeline.from_config(EDGE_TINY, squash_impl="approx")
    qnet2 = pipe2.quantize(
        CapsPipeline.from_config(EDGE_TINY).init(jax.random.key(0)),
        jnp.asarray(np.random.default_rng(7).uniform(
            0, 1, (16,) + EDGE_TINY.input_shape).astype(np.float32)))
    np.testing.assert_array_equal(
        np.asarray(qnet.with_squash("approx").forward(jnp.asarray(x_q))),
        np.asarray(qnet2.forward(jnp.asarray(x_q))))


def test_from_config_rejects_conflicting_variant_args():
    with pytest.raises(ValueError, match="not both"):
        CapsPipeline.from_config(EDGE_TINY, softmax_impl="q7",
                                 variants=VariantSet())


# ---------------------------------------------------------------------------
# acceptance: bit-parity + serialization for EVERY registered variant
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
@pytest.mark.parametrize("vs", ALL_SETS, ids=lambda v: v.tag)
def test_every_variant_bit_identical_host_vs_vm(vs, rounding):
    qnet, x_q = built(rounding)
    qv = qnet.with_variants(vs)
    program = lower(qv)
    routing = next(op for op in program.ops
                   if op.kind == "CAPS_ROUTING_Q7")
    assert routing.attrs["softmax_impl"] == vs.softmax
    assert routing.attrs["squash_impl"] == vs.squash
    np.testing.assert_array_equal(
        EdgeVM(program).run(x_q),
        np.asarray(qv.forward(jnp.asarray(x_q))))


@pytest.mark.parametrize("vs", ALL_SETS, ids=lambda v: v.tag)
def test_every_variant_round_trips_json_and_capsbin(vs, tmp_path):
    qnet, x_q = built()
    qv = qnet.with_variants(vs)

    # QAT plan JSON side-car codec
    restored = plan_from_json(json.loads(
        json.dumps(plan_to_json(qv.plan), sort_keys=True)))
    assert restored == qv.plan and restored.variants == vs

    # .capsbin attrs: export -> load_qnet -> re-lower -> VM bit-parity
    program = lower(qv)
    paths = program.save(tmp_path / "m")
    reloaded = EdgeProgram.load(paths["capsbin"])
    assert program.same_as(reloaded)
    q2 = to_qnet(reloaded)
    assert q2.variants == vs
    assert lower(q2, name=program.name).same_as(program)
    np.testing.assert_array_equal(
        np.asarray(q2.forward(jnp.asarray(x_q))),
        EdgeVM(reloaded).run(x_q))


def test_pre_variant_artifact_defaults_everywhere(tmp_path):
    """A schedule with NO variant attrs (pre-variant artifact) defaults
    to q7+exact in every consumer — importer, VM, and C emitter — via
    the one shared registry accessor."""
    from repro.edge import emit_c

    qnet, x_q = built()
    program = lower(qnet)
    ops = tuple(dataclasses.replace(
        op, attrs={k: v for k, v in op.attrs.items()
                   if k not in ("softmax_impl", "squash_impl")})
        for op in program.ops)
    old = dataclasses.replace(program, ops=ops)
    q2 = to_qnet(old)
    assert q2.variants == VariantSet()
    np.testing.assert_array_equal(EdgeVM(old).run(x_q),
                                  np.asarray(qnet.forward(jnp.asarray(x_q))))
    assert "approx" not in emit_c(old)["c"]


def test_register_evicts_cached_model_and_executables():
    """Re-registering a spec (the CLI --softmax/--squash path) must not
    keep serving the previously built model from the cache."""
    spec = ModelSpec("t@jnp", EDGE_TINY, dataset="uniform", calib_n=4)
    reg = ModelRegistry(specs={spec.model_id: spec})
    assert reg.model("t@jnp").variants.is_default()
    reg.executable("t@jnp", 1)
    reg.register(dataclasses.replace(spec, softmax_impl="approx"))
    assert reg.model("t@jnp").variants.softmax == "approx"
    assert reg.quantize_count == 2
    exe = reg.executable("t@jnp", 1)      # recompiled, not the stale wave
    assert reg.compile_count == 2 and exe is not None


def test_tampered_unknown_variant_is_rejected(tmp_path):
    qnet, x_q = built()
    # plan JSON side-car tampered with an unregistered softmax
    d = plan_to_json(qnet.plan)
    d["layers"]["caps"]["softmax_impl"] = "evil"
    with pytest.raises(ValueError, match="approx, precise, q7"):
        plan_from_json(d)
    # .capsbin whose routing op names an unregistered variant: the file
    # parses (attrs are opaque bytes) but neither the importer nor the
    # VM will execute it
    program = lower(qnet)
    ops = tuple(dataclasses.replace(
        op, attrs={**op.attrs, "softmax_impl": "evil"})
        if op.kind == "CAPS_ROUTING_Q7" else op for op in program.ops)
    bad = dataclasses.replace(program, ops=ops)
    paths = bad.save(tmp_path / "bad")
    loaded = EdgeProgram.load(paths["capsbin"])
    with pytest.raises(ValueError, match="registered"):
        to_qnet(loaded)
    with pytest.raises(ValueError, match="registered"):
        EdgeVM(loaded).run(x_q)


# ---------------------------------------------------------------------------
# pallas fallback observability (no more silent degradation)
# ---------------------------------------------------------------------------
def test_pallas_fallback_counter_and_warn_once():
    qnet, x_q = built()
    qv = qnet.with_variants(VariantSet(softmax="approx", squash="approx"))
    be = PallasBackend()                 # fresh counters, not the shared one
    assert not be.fallbacks

    def run():
        return np.asarray(qv.pipeline.forward_q7(
            qv.qweights, qv.plan, jnp.asarray(x_q), backend=be,
            rounding=qv.rounding))

    with pytest.warns(RuntimeWarning, match="falling back"):
        v_pal = run()
    # bit-identical to the oracle, but counted: exactly ONE decision per
    # fallback site per forward (pcap squash + routing entry; the oracle
    # loop the routing falls back to must not re-count its inner squash)
    assert be.fallbacks[("squash", "approx")] == 1
    assert be.fallbacks[("routing.softmax", "approx")] == 1
    assert ("routing.squash", "approx") not in be.fallbacks
    np.testing.assert_array_equal(
        v_pal, np.asarray(qv.forward(jnp.asarray(x_q))))
    before = dict(be.fallbacks)
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # a second warning would raise
        run()
    assert be.fallbacks[("squash", "approx")] > before[("squash", "approx")]


def test_registry_warns_once_per_model_and_variant():
    spec = ModelSpec("tiny@pallas", EDGE_TINY, backend="pallas",
                     dataset="uniform", calib_n=4,
                     softmax_impl="approx", squash_impl="approx")
    reg = ModelRegistry(specs={spec.model_id: spec})
    with pytest.warns(RuntimeWarning, match="tiny@pallas"):
        reg.model("tiny@pallas")
    assert reg.variant_fallbacks == {"tiny@pallas": "approx+approx"}
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        reg.install("tiny@pallas", reg.model("tiny@pallas"))  # same pair
    # the jnp backend never records a fallback
    jreg = ModelRegistry(specs={"t@jnp": dataclasses.replace(
        spec, model_id="t@jnp", backend="jnp")})
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        jreg.model("t@jnp")
    assert jreg.variant_fallbacks == {}
    # re-registering back to defaults clears the stale fallback report
    reg.register(dataclasses.replace(spec, softmax_impl="q7",
                                     squash_impl="exact"))
    assert reg.variant_fallbacks == {}
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        reg.model("tiny@pallas")             # rebuilt on defaults: silent
    assert reg.variant_fallbacks == {}


# ---------------------------------------------------------------------------
# QAT faces follow the plan's variants
# ---------------------------------------------------------------------------
def test_fq_softmax_approx_matches_integer_op():
    """The approx softmax fake-quant face lands exactly on the codes
    `int8_ops.softmax_q7_approx` produces (both are powers of two with
    a power-of-two normalizer, so the match is bit-exact)."""
    from repro.nn.layers import CapsuleRouting

    rng = np.random.default_rng(5)
    f = 5
    b_q = rng.integers(-128, 128, (2, 7, 9)).astype(np.int8)
    b = jnp.asarray(b_q, jnp.float32) * 2.0 ** -f    # on the Q(f) grid

    c_fq = np.asarray(CapsuleRouting._softmax_fq(b, "approx"))  # axis 1
    c_int = np.asarray(q.softmax_q7_approx(
        jnp.asarray(b_q).swapaxes(1, 2), in_frac=f)).swapaxes(1, 2)
    np.testing.assert_array_equal(c_fq * 128.0, c_int.astype(np.float32))

    # adversarial normalizer: 16 max-tied logits + one tail at the -20
    # exponent clamp put the integer sum at 2^24 + 1 — a float32 sum
    # rounds that back to 2^24 and doubles every coupling; the fq face
    # must match the integer op here too (it mirrors the int32 sum, not
    # a float sum)
    f_adv = 1
    b_adv = np.zeros((1, 17, 1), np.int8)
    b_adv[0, 16, 0] = -128                   # -128 >> 1 = -64 -> clamp -20
    c_fq = np.asarray(CapsuleRouting._softmax_fq(
        jnp.asarray(b_adv, jnp.float32) * 2.0 ** -f_adv, "approx"))
    c_int = np.asarray(q.softmax_q7_approx(
        jnp.asarray(b_adv).swapaxes(1, 2), in_frac=f_adv)).swapaxes(1, 2)
    np.testing.assert_array_equal(c_fq * 128.0, c_int.astype(np.float32))


def test_fwd_fq_follows_squash_variant():
    """forward_fq trains against the plan's squash variant: flipping it
    changes the QAT forward, and its gradient still flows (STE)."""
    qnet, _ = built()
    pipe = CapsPipeline.from_config(EDGE_TINY)
    params = pipe.init(jax.random.key(1))
    x = jnp.asarray(np.random.default_rng(3).uniform(
        0, 1, (2,) + EDGE_TINY.input_shape).astype(np.float32))
    plan_exact = qnet.plan
    plan_apx = VariantSet(squash="approx").apply(plan_exact)
    v_exact = pipe.forward_fq(params, x, plan_exact)
    v_apx = pipe.forward_fq(params, x, plan_apx)
    assert not np.array_equal(np.asarray(v_exact), np.asarray(v_apx))
    g = jax.grad(lambda p: jnp.sum(
        pipe.forward_fq(p, x, plan_apx) ** 2))(params)
    assert float(jnp.max(jnp.abs(g["caps"]["W"]))) > 0.0


def test_trainer_carries_variants_into_qat_plan():
    from repro.captrain import CapsTrainer, TrainConfig

    tcfg = TrainConfig(dataset="edge_tiny", batch=8, microbatches=2,
                       calib_n=8, softmax_impl="approx",
                       squash_impl="approx")
    trainer = CapsTrainer(EDGE_TINY, tcfg)
    state = trainer.init_state()
    plan = trainer.derive_plan(state)
    assert plan.variants.tag == "approx+approx"
    qnet = trainer.quantize(state)
    assert qnet.variants.tag == "approx+approx"
    # and the quantized model still matches the VM bit for bit
    x_q = qnet.quantize_input(jnp.asarray(np.random.default_rng(0).uniform(
        0, 1, (2,) + EDGE_TINY.input_shape).astype(np.float32)))
    np.testing.assert_array_equal(
        EdgeVM(lower(qnet)).run(np.asarray(x_q)),
        np.asarray(qnet.forward(x_q)))


# ---------------------------------------------------------------------------
# CLIs
# ---------------------------------------------------------------------------
def test_export_caps_cli_exports_variants(tmp_path):
    from repro.launch import export_caps

    rc = export_caps.main(["--model", "edge_tiny", "--out", str(tmp_path),
                           "--softmax", "approx", "--squash", "approx",
                           "--verify-n", "2"])
    assert rc == 0
    manifest = json.loads(
        (tmp_path / "edge_tiny_jnp.manifest.json").read_text())
    routing = [o for o in manifest["ops"]
               if o["kind"] == "CAPS_ROUTING_Q7"][0]
    assert routing["attrs"]["softmax_impl"] == "approx"
    assert routing["attrs"]["squash_impl"] == "approx"
    c_src = (tmp_path / "edge_tiny_jnp.c").read_text()
    assert "capsnet_dynamic_routing_q7_softmax_approx_squash_approx(" \
        in c_src
    assert "capsnet_squash_q7_approx(" in c_src


def test_cli_unknown_variant_lists_choices(capsys):
    from repro.launch import export_caps, serve_caps

    for main in (export_caps.main, serve_caps.main):
        with pytest.raises(SystemExit) as e:
            main(["--softmax", "bogus"])
        assert e.value.code == 2
        err = capsys.readouterr().err
        for name in REGISTRY.names("softmax"):
            assert name in err


# ---------------------------------------------------------------------------
# accuracy acceptance (ISLPED'22 claim on the edge_tiny seed)
# ---------------------------------------------------------------------------
def test_approx_variants_within_one_percent_of_q7_baseline():
    """Trained edge_tiny seed: every approximate variant's int8 accuracy
    stays within 1.0 % (absolute) of the q7+exact baseline, for both
    roundings — and the Table-2 harness reports the tagged rows."""
    from repro.captrain import CapsTrainer, TrainConfig, eval_q7
    from repro.data.synthetic import make_image_dataset

    tcfg = TrainConfig(dataset="edge_tiny", batch=32, microbatches=4,
                       calib_n=32, lr=3e-3)
    trainer = CapsTrainer(EDGE_TINY, tcfg)
    state = trainer.init_state()
    state, _, _ = trainer.fit(state, 150)    # ~97 % converged seed
    images, labels = make_image_dataset("edge_tiny", 256, seed=999_999)

    for rounding in ("floor", "nearest"):
        qnet = trainer.quantize(state, rounding=rounding)
        acc_base = eval_q7(qnet, images, labels)
        for vs in ALL_SETS:
            if "approx" not in (vs.softmax, vs.squash):
                continue
            acc = eval_q7(qnet.with_variants(vs), images, labels)
            assert abs(acc - acc_base) <= 0.010 + 1e-9, \
                (rounding, vs.tag, acc, acc_base)


def test_table2_rows_report_variant_tag():
    from repro.captrain import TrainConfig, table2_rows
    from repro.captrain.evalq import format_rows

    tcfg = TrainConfig(dataset="edge_tiny", batch=16, microbatches=2,
                       calib_n=16)
    rows = table2_rows(EDGE_TINY, tcfg, float_steps=4, qat_steps=2,
                       roundings=("floor",), eval_n=32,
                       variants=VariantSet(softmax="approx",
                                           squash="approx"))
    assert [r.variant for r in rows] == ["approx+approx"]
    assert "approx+approx" in format_rows(rows)
