"""Int8 gradient compression with error feedback: convergence parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adam import SGDM
from repro.optim.grad_compress import EFCompressor, compress, decompress


def test_compress_roundtrip_small_error():
    g = jnp.asarray(np.random.default_rng(0).normal(0, 3, (128,)),
                    jnp.float32)
    q, e = compress(g)
    assert q.dtype == jnp.int8
    err = jnp.max(jnp.abs(decompress(q, e) - g))
    assert float(err) <= 0.5 * float(jnp.exp2(-e)) + 1e-7


def test_ef_training_converges_like_uncompressed():
    """Least squares with SGD-momentum: int8+EF must reach (near) the same
    loss as uncompressed gradients — the error-feedback guarantee."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(0, 1, (64, 16)), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)

    def loss(w):
        return jnp.mean((A @ w - y) ** 2)

    opt = SGDM(lr=2e-2, momentum=0.9)

    def train(compressed: bool, steps=300):
        w = jnp.zeros((16,), jnp.float32)
        state = opt.init(w)
        comp = EFCompressor()
        err = comp.init(w)
        for _ in range(steps):
            g = jax.grad(loss)(w)
            if compressed:
                g, err = comp.apply(g, err)
            w, state, _ = opt.update(g, state, w)
        return float(loss(w))

    l_plain = train(False)
    l_comp = train(True)
    assert l_comp <= l_plain * 1.05 + 1e-4, (l_plain, l_comp)


def test_ef_error_buffer_carries_residual():
    comp = EFCompressor()
    g = jnp.asarray([1e-8, 2e-8], jnp.float32)   # below one quant step
    err = comp.init(g)
    out1, err = comp.apply(g, err)
    # tiny gradients quantize to ~0 but accumulate in the buffer
    for _ in range(100):
        out, err = comp.apply(g, err)
    # eventually the accumulated error flushes through
    assert float(jnp.max(jnp.abs(err))) < 1.0
