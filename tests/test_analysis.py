"""Tests for the static verifier (repro.analysis).

Two halves, mirroring the subsystem's contract:

  * zero false positives — every shipped config x rounding x operator
    variant combo (and the per-channel plan) lowers to a program the
    checker passes clean, and the typed plans lint clean;
  * every seeded defect class is caught — a mutation corpus covering
    shift algebra, format threading, per-channel tables, variant
    references, arena aliasing, scratch sizing, and int32 accumulator
    overflow, each asserting the diagnostic names the right op/tensor.

Plus the wiring: `lower()` stamps `acc_bound` attrs the VM asserts,
imported `.capsbin` artifacts pass through the checker (tampered ones
are rejected as ValueError), `export_artifacts` refuses to write a
failing program, and the repo lint rules fire where they should.
"""
import dataclasses
import itertools
import json
import pathlib
import struct

import numpy as np
import pytest

from repro.analysis import (CheckError, annotate_acc_bounds, check_arena,
                            check_pipeline_plan, check_program)
from repro.analysis.ranges import analyze
from repro.analysis.repolint import lint_paths, lint_source
from repro.edge import EdgeOp, EdgeProgram, EdgeVM, TensorSpec, \
    export_artifacts, load_qnet, lower, plan_arena
from repro.nn.plans import ConvPlan
from repro.nn.variants import REGISTRY, VariantSet
from test_edge import CONFIGS, built

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def checks_of(result):
    return [d.check for d in result.diagnostics]


def tamper_attrs(program, op_idx, **attrs):
    """A copy of `program` with op `op_idx`'s attrs overridden."""
    ops = list(program.ops)
    ops[op_idx] = dataclasses.replace(
        ops[op_idx], attrs={**ops[op_idx].attrs, **attrs})
    return dataclasses.replace(program, ops=tuple(ops))


# ---------------------------------------------------------------------------
# zero false positives on everything we ship
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_checker_clean_on_all_configs(name, rounding):
    qnet, _ = built(name, rounding)
    result = check_program(lower(qnet))
    assert result.ok, result.format()


@pytest.mark.parametrize("softmax,squash", sorted(itertools.product(
    REGISTRY.names("softmax"), REGISTRY.names("squash"))))
def test_checker_clean_on_all_variant_combos(softmax, squash):
    qnet, _ = built("capsnet_edge_tiny")
    qnet = qnet.with_variants(VariantSet(softmax=softmax, squash=squash))
    result = check_program(lower(qnet))
    assert result.ok, result.format()


def test_checker_clean_on_per_channel_plan():
    qnet, _ = built("capsnet_edge_tiny", "nearest", per_channel=True)
    result = check_program(lower(qnet))
    assert result.ok, result.format()


def test_typed_plan_lints_clean():
    qnet, _ = built("capsnet_edge_tiny")
    assert qnet.plan.check() == []
    assert check_pipeline_plan(qnet.plan) == []


# ---------------------------------------------------------------------------
# satellite: lower() stamps acc_bound attrs; the VM asserts them
# ---------------------------------------------------------------------------
def test_lower_records_acc_bounds_matching_analysis():
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    bounds, diags = analyze(program)
    assert diags == []
    for i, op in enumerate(program.ops):
        if op.kind in ("CONV_Q7", "PRIMARY_CAPS_Q7"):
            assert op.attrs["acc_bound"] == bounds[i] > 0
        else:
            assert "acc_bound" not in op.attrs


def test_vm_asserts_tampered_acc_bound():
    qnet, x_q = built("capsnet_edge_tiny")
    program = lower(qnet)
    EdgeVM(program).run(x_q)                       # clean bound: runs
    bad = tamper_attrs(program, 0, acc_bound=7)
    with pytest.raises(AssertionError, match="acc_bound"):
        EdgeVM(bad).run(x_q)
    # and the checker flags the same tamper statically
    result = check_program(bad)
    (d,) = result.by_check("ranges.acc-bound-mismatch")
    assert d.op_index == 0 and d.op_name == program.ops[0].name


def test_annotate_acc_bounds_is_idempotent():
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    again = annotate_acc_bounds(program)
    assert program.same_as(again)


# ---------------------------------------------------------------------------
# mutation corpus: every defect class -> a precise diagnostic
# ---------------------------------------------------------------------------
def test_mutation_shrunk_out_shift():
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    bad = tamper_attrs(program, 0,
                       out_shift=program.ops[0].attrs["out_shift"] - 1)
    result = check_program(bad)
    (d,) = result.by_check("plan.out-shift-mismatch")
    assert d.op_index == 0 and d.op_name == program.ops[0].name


def test_mutation_shift_out_of_domain():
    qnet, _ = built("capsnet_edge_tiny")
    bad = tamper_attrs(lower(qnet), 0, out_shift=45)
    result = check_program(bad)
    (d,) = result.by_check("ranges.shift-range")
    assert d.op_index == 0 and ("shift", 45) in d.detail
    assert result.by_check("plan.out-shift-mismatch")


def test_mutation_swapped_fracs():
    """Swapping in/out fracs breaks the tensor-format contract — the
    structural stage names the mistyped tensor and short-circuits."""
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    a = program.ops[0].attrs
    bad = tamper_attrs(program, 0, in_frac=a["out_frac"],
                       out_frac=a["in_frac"])
    result = check_program(bad)
    (d,) = result.by_check("ir.frac-mismatch")
    assert d.tensor == program.ops[0].output
    assert all(c.startswith("ir.") for c in checks_of(result))


def test_mutation_broken_frac_threading():
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    bad = tamper_attrs(program, 0,
                       in_frac=program.ops[0].attrs["in_frac"] + 1)
    result = check_program(bad)
    (d,) = result.by_check("plan.frac-thread-mismatch")
    assert d.op_index == 0 and d.tensor == 0


def test_mutation_truncated_per_channel_table():
    qnet, _ = built("capsnet_edge_tiny", "nearest", per_channel=True)
    program = lower(qnet)
    table = program.ops[0].attrs["out_shift_per_channel"]
    assert len(table) > 1
    bad = tamper_attrs(program, 0, out_shift_per_channel=table[:-1])
    result = check_program(bad)
    (d,) = result.by_check("plan.per-channel-length")
    assert d.op_index == 0 and d.op_name == program.ops[0].name


def test_mutation_unregistered_variant():
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    routing_idx = next(i for i, op in enumerate(program.ops)
                       if op.kind == "CAPS_ROUTING_Q7")
    bad = tamper_attrs(program, routing_idx, softmax_impl="turbo")
    result = check_program(bad)
    assert any(d.op_index == routing_idx and ("name", "turbo") in d.detail
               for d in result.by_check("plan.unregistered-variant")), \
        result.format()


def test_mutation_overlapping_arena_offsets():
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    plan = plan_arena(program)
    bad = dataclasses.replace(
        plan, offsets={**plan.offsets, 2: plan.offsets[1]})
    result = check_program(program, arena=bad)
    overlaps = result.by_check("arena.overlap")
    assert overlaps, result.format()
    assert any({d.tensor, dict(d.detail)["other"]} == {1, 2}
               for d in overlaps)
    assert check_arena(program, plan_arena(program)) == []


def test_mutation_scratch_undersized_and_unaligned():
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    plan = plan_arena(program)
    assert plan.scratch_bytes % 2 == 0

    (d,) = check_arena(program,
                       dataclasses.replace(plan, scratch_bytes=0))
    assert d.check == "arena.scratch-undersized"
    assert d.op_name in {op.name for op in program.ops}

    (d,) = check_arena(
        program,
        dataclasses.replace(plan, scratch_bytes=plan.scratch_bytes + 1))
    assert d.check == "arena.scratch-unaligned"


def _oversized_conv_program():
    """A structurally/plan-wise valid conv whose worst-case int32
    accumulator provably wraps: 3*3*16384 taps of |w|=127 against
    |x|<=128 -> bound ~2.4e9 > 2^31-1."""
    in_ch = 16384
    attrs = {"kernel": 3, "stride": 1, "in_ch": in_ch, "out_ch": 1,
             "relu": False, "in_frac": 7, "w_frac": 7, "b_frac": 14,
             "out_frac": 7, "out_shift": 7, "bias_shift": 0}
    op = EdgeOp("CONV_Q7", "conv_huge", (0,), 1, attrs, {
        "w": np.full((3, 3, in_ch, 1), 127, np.int8),
        "b": np.zeros((1,), np.int8)})
    tensors = (TensorSpec(0, "input", (3, 3, in_ch), 7),
               TensorSpec(1, "out", (1, 1, 1), 7))
    return EdgeProgram(name="huge", rounding="floor", input_frac=7,
                       tensors=tensors, ops=(op,))


def test_mutation_oversized_conv_wraps_int32():
    result = check_program(_oversized_conv_program())
    (d,) = result.by_check("ranges.acc-overflow")
    assert d.op_index == 0 and d.op_name == "conv_huge"
    assert dict(d.detail)["bound"] > 2 ** 31 - 1
    # the identical geometry with |w|=1 fits comfortably -> clean
    ok = _oversized_conv_program()
    op = dataclasses.replace(
        ok.ops[0], weights={"w": np.ones((3, 3, 16384, 1), np.int8),
                            "b": np.zeros((1,), np.int8)})
    assert check_program(
        dataclasses.replace(ok, ops=(op,))).ok


def test_structure_catches_dataflow_breaks():
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    # dangling input reference
    ops = list(program.ops)
    ops[1] = dataclasses.replace(ops[1], inputs=(3,))
    result = check_program(dataclasses.replace(program, ops=tuple(ops)))
    assert result.by_check("ir.undefined-input")
    # double write
    ops = list(program.ops)
    ops[1] = dataclasses.replace(ops[1], output=ops[0].output)
    result = check_program(dataclasses.replace(program, ops=tuple(ops)))
    assert result.by_check("ir.output-clobber")


# ---------------------------------------------------------------------------
# wiring: importer / export refuse bad artifacts
# ---------------------------------------------------------------------------
def test_importer_rejects_tampered_artifact(tmp_path):
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    bad = tamper_attrs(program, 0,
                       out_shift=program.ops[0].attrs["out_shift"] - 1)
    paths = bad.save(tmp_path / "bad")
    with pytest.raises(CheckError, match="out-shift-mismatch"):
        load_qnet(paths["capsbin"])
    with pytest.raises(ValueError):                # importer-caller view
        load_qnet(paths["capsbin"])
    assert load_qnet(paths["capsbin"], check=False) is not None


def _rewrite_header(capsbin, edit):
    """Re-serialize a .capsbin with `edit(header_dict)` applied."""
    raw = pathlib.Path(capsbin).read_bytes()
    hstart = 8 + 4                                 # MAGIC + u32 length
    (hlen,) = struct.unpack_from("<I", raw, 8)
    header = json.loads(raw[hstart:hstart + hlen].decode())
    payload = raw[(hstart + hlen + 15) // 16 * 16:]
    edit(header)
    hbytes = json.dumps(header, sort_keys=True).encode()
    blob = raw[:8] + struct.pack("<I", len(hbytes)) + hbytes
    blob += b"\x00" * (-len(blob) % 16) + payload
    out = pathlib.Path(capsbin).with_suffix(".tampered.capsbin")
    out.write_bytes(blob)
    return out


def test_load_rejects_inconsistent_blob_metadata(tmp_path):
    qnet, _ = built("capsnet_edge_tiny")
    paths = lower(qnet).save(tmp_path / "m")

    def bad_nbytes(h):
        h["ops"][0]["weights"]["w"]["nbytes"] += 1
    with pytest.raises(ValueError, match="declares"):
        EdgeProgram.load(_rewrite_header(paths["capsbin"], bad_nbytes))

    def bad_offset(h):
        h["ops"][0]["weights"]["w"]["offset"] = 1 << 30
    with pytest.raises(ValueError, match="runs past"):
        EdgeProgram.load(_rewrite_header(paths["capsbin"], bad_offset))


def test_export_refuses_to_write_failing_program(tmp_path):
    qnet, _ = built("capsnet_edge_tiny")
    conv_name = next(n for n, p in qnet.plan.layers.items()
                     if isinstance(p, ConvPlan))
    bad_conv = dataclasses.replace(qnet.plan.layers[conv_name],
                                   out_shift=qnet.plan.layers[conv_name]
                                   .out_shift + 1)
    bad_plan = dataclasses.replace(
        qnet.plan, layers={**qnet.plan.layers, conv_name: bad_conv})
    bad_qnet = dataclasses.replace(qnet, plan=bad_plan)
    with pytest.raises(CheckError, match="out-shift-mismatch"):
        export_artifacts(bad_qnet, tmp_path, stem="nope")
    assert not list(tmp_path.iterdir()), "artifacts written despite findings"
    # typed-plan lint sees the same defect, named by layer
    diags = bad_plan.check()
    assert any(d.check == "plan.out-shift-mismatch"
               and d.op_name == conv_name for d in diags)


def test_export_result_reports_checked():
    qnet, x = built("capsnet_edge_tiny")
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        result = export_artifacts(qnet, d, stem="ok")
        assert result["checked"] is True


# ---------------------------------------------------------------------------
# repolint rules
# ---------------------------------------------------------------------------
def test_repolint_repo_src_is_clean():
    assert lint_paths([REPO_ROOT / "src"]) == []


def test_repolint_flags_shim_imports_outside_tests():
    src = ("from repro.quant import ptq\n"
           "import repro.core.capsnet_q7\n"
           "from repro.core.capsnet import CAPSNET_CONFIGS\n")
    findings = lint_source(src, "src/repro/somewhere.py")
    assert [f.rule for f in findings] == ["shim-import"] * 3
    assert [f.line for f in findings] == [1, 2, 3]
    assert lint_source(src, "tests/test_whatever.py") == []
    assert lint_source(src, "src/repro/nn/compat.py") == []


def test_repolint_flags_unregistered_variant_strings():
    src = ('spec = ModelSpec(softmax_impl="turbo")\n'
           'VariantSet(squash="approx")\n'
           'REGISTRY.get("squash", "nope")\n')
    findings = lint_source(src, "src/repro/somewhere.py")
    assert [(f.rule, f.line) for f in findings] == \
        [("unregistered-variant-string", 1),
         ("unregistered-variant-string", 3)]


def test_repolint_reports_syntax_errors():
    (f,) = lint_source("def broken(:\n", "src/repro/x.py")
    assert f.rule == "syntax-error"


def test_repolint_cli(tmp_path, capsys):
    from repro.analysis.repolint import main
    bad = tmp_path / "bad.py"
    bad.write_text("import repro.quant.ptq\n")
    assert main([str(bad)]) == 1
    assert "shim-import" in capsys.readouterr().out
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert main([str(good)]) == 0


# ---------------------------------------------------------------------------
# property test: random valid programs are checker-clean AND bit-exact;
# a random shift tamper is always caught at the right op
# ---------------------------------------------------------------------------
_GEOMETRY_SPACE = dict(
    size=[10, 12], filters=[4, 6], stride=[1, 2], caps=[2, 4],
    classes=[2, 3], routings=[1, 2, 3], rounding=["floor", "nearest"],
    softmax=list(REGISTRY.names("softmax")),
    squash=list(REGISTRY.names("squash")), delta=[1, 2, 3, 4])


def _sampled_geometries(n, seed=0):
    """n deterministic samples of the geometry space (the fallback
    driver when hypothesis is not installed; same space either way)."""
    import random
    rng = random.Random(seed)
    return [{k: rng.choice(v) for k, v in _GEOMETRY_SPACE.items()}
            for _ in range(n)]


def _property_clean_program_bit_exact(g):
    import jax
    import jax.numpy as jnp
    from repro.nn.config import CapsNetConfig
    from repro.nn.pipeline import CapsPipeline

    cfg = CapsNetConfig(
        f"prop_{g['size']}_{g['filters']}_{g['stride']}",
        (g["size"], g["size"], 1), (g["filters"],), (3,), (g["stride"],),
        pcap_caps=g["caps"], pcap_dim=4, pcap_kernel=3, pcap_stride=2,
        num_classes=g["classes"], caps_dim=4, routings=g["routings"])
    pipe = CapsPipeline.from_config(
        cfg, variants=VariantSet(softmax=g["softmax"], squash=g["squash"]))
    params = pipe.init(jax.random.key(1))
    rng = np.random.default_rng(3)
    calib = jnp.asarray(rng.uniform(
        0, 1, (4,) + cfg.input_shape).astype(np.float32))
    qnet = pipe.quantize(params, calib, rounding=g["rounding"])
    program = lower(qnet)

    result = check_program(program)
    assert result.ok, result.format()
    x_q = np.asarray(qnet.quantize_input(
        jnp.asarray(rng.uniform(0, 1, (2,) + cfg.input_shape)
                    .astype(np.float32))))
    np.testing.assert_array_equal(
        EdgeVM(program).run(x_q),
        np.asarray(qnet.forward(jnp.asarray(x_q))))

    # any shift perturbation is caught, at the op that was tampered
    bad = tamper_attrs(program, 0,
                       out_shift=program.ops[0].attrs["out_shift"]
                       + g["delta"])
    tampered = check_program(bad)
    assert not tampered.ok
    assert any(d.op_index == 0 for d in
               tampered.by_check("plan.out-shift-mismatch")
               + tampered.by_check("ranges.shift-range"))


try:                                 # hypothesis drives the sampling when
    import hypothesis                # available; the container may lack it
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(g=st.fixed_dictionaries(
        {k: st.sampled_from(v) for k, v in _GEOMETRY_SPACE.items()}))
    def test_property_clean_programs_run_bit_exact(g):
        _property_clean_program_bit_exact(g)

except ImportError:
    @pytest.mark.parametrize("g", _sampled_geometries(4),
                             ids=lambda g: "-".join(
                                 str(v) for v in g.values()))
    def test_property_clean_programs_run_bit_exact(g):
        _property_clean_program_bit_exact(g)
