"""Tests for the unified observability layer (repro.obs + the MCU cost
model + the bench artifact schema).

Pins, in order:
  * span trees under a fake clock: nesting, timestamps, Chrome
    trace-event export — exact, no wall-clock flakiness;
  * tracing off == zero objects: `obs.span()` returns the one shared
    NULL_SPAN when no tracer is ambient;
  * the metrics registry: labeled series, kind conflicts, JSON-safe
    snapshots, and the Counter-shaped views the pre-obs attributes
    became (PallasBackend.fallbacks, ModelRegistry counts);
  * ServeMetrics empty-window behavior: summary()/report() are explicit
    (None / "no completed requests"), never formatted NaNs, while the
    low-level accessors keep their pinned nan-on-empty contract;
  * traced serving is bit-identical to untraced and emits the nested
    enqueue -> wave -> execute span forest as valid Chrome JSON;
  * EdgeVM with `profile`/`trace`/ambient tracing returns the same bits
    as the bare hot path, for every config x rounding;
  * the static MCU cost model reproduces the paper's four latencies
    (Cortex-M7 119.94/90.60 ms, GAP-8 7.02/38.03 ms) on the smallNORB
    "M" geometry within CALIB_REL_TOL;
  * BENCH_*.json artifacts validate against the repro.bench/v1 schema
    and the validator actually fails on broken invariants.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.edge import (EdgeOp, EdgeProgram, EdgeVM, TensorSpec,
                        costmodel, lower)
from repro.serving import (EDGE_TINY, CapsServeEngine, ModelRegistry,
                           ModelSpec, ServeMetrics)

import test_edge


class FakeClock:
    """Monotone fake clock: every read advances 1s."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


@pytest.fixture(autouse=True)
def _no_ambient_tracer():
    """Every test starts and ends with tracing off (module-global)."""
    obs.set_tracer(None)
    yield
    obs.set_tracer(None)


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
def test_span_nesting_and_fake_clock():
    tr = obs.Tracer(clock=FakeClock())
    with tr.span("outer", model="m") as outer:
        with tr.span("inner.a"):
            pass
        with tr.span("inner.b"):
            pass
    assert [r.name for r in tr.roots] == ["outer"]
    assert [c.name for c in outer.children] == ["inner.a", "inner.b"]
    assert outer.children[0].children == []
    # fake clock reads: outer t0=1, a=[2,3], b=[4,5], outer t1=6
    assert (outer.t0, outer.t1) == (1.0, 6.0)
    assert outer.children[0].dur_s == 1.0
    assert outer.args == {"model": "m"}
    assert tr.span_count() == 3
    assert len(tr.find("inner.a")) == 1
    assert outer.find("inner.b")[0] is outer.children[1]


def test_span_forest_and_reset():
    tr = obs.Tracer(clock=FakeClock())
    with tr.span("a"):
        pass
    with tr.span("b"):
        pass
    assert [r.name for r in tr.roots] == ["a", "b"]
    tr.reset()
    assert tr.roots == [] and tr.span_count() == 0


def test_span_exception_unwind_keeps_stack_sane():
    tr = obs.Tracer(clock=FakeClock())
    with pytest.raises(RuntimeError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise RuntimeError("boom")
    assert tr._stack == []                       # fully unwound
    inner = tr.find("inner")[0]
    assert inner.t1 is not None                  # still closed
    with tr.span("after"):
        pass
    assert [r.name for r in tr.roots] == ["outer", "after"]


def test_chrome_trace_export(tmp_path):
    tr = obs.Tracer(clock=FakeClock())
    with tr.span("serve.wave", bucket=4):
        with tr.span("serve.execute"):
            pass
    doc = tr.chrome_trace()
    ev = {e["name"]: e for e in doc["traceEvents"]}
    assert set(ev) == {"serve.wave", "serve.execute"}
    assert all(e["ph"] == "X" for e in ev.values())
    # fake clock: wave=[1,4], execute=[2,3]; epoch shift -> wave ts=0
    assert ev["serve.wave"]["ts"] == 0.0
    assert ev["serve.wave"]["dur"] == pytest.approx(3e6)
    assert ev["serve.execute"]["ts"] == pytest.approx(1e6)
    assert ev["serve.wave"]["cat"] == "serve"
    assert ev["serve.wave"]["args"] == {"bucket": 4}
    path = tr.write_chrome_trace(tmp_path / "t" / "trace.json")
    assert json.loads(path.read_text()) == json.loads(json.dumps(doc))


def test_ambient_span_is_null_when_off():
    assert obs.get_tracer() is None
    s = obs.span("anything", arg=1)
    assert s is obs.NULL_SPAN                    # shared, no allocation
    with s as inner:
        assert inner is obs.NULL_SPAN
    assert s.find("anything") == []


def test_tracing_scopes_and_restores():
    tr = obs.Tracer(clock=FakeClock())
    with obs.tracing(tr):
        assert obs.get_tracer() is tr
        with obs.span("root"):
            with obs.span("child"):
                pass
        inner = obs.Tracer()
        with obs.tracing(inner):
            assert obs.get_tracer() is inner
        assert obs.get_tracer() is tr
    assert obs.get_tracer() is None
    assert [r.name for r in tr.roots] == ["root"]
    assert tr.roots[0].children[0].name == "child"


def test_explicit_tracer_beats_ambient():
    amb, exp = obs.Tracer(clock=FakeClock()), obs.Tracer(clock=FakeClock())
    with obs.tracing(amb):
        with obs.span("explicit", tracer=exp):
            pass
    assert amb.span_count() == 0 and exp.span_count() == 1


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_labels_and_total():
    reg = obs.MetricsRegistry("t")
    c = reg.counter("hits", help="h")
    c.inc(op="a", variant="x")
    c.inc(2, op="a", variant="y")
    c.inc(op="a", variant="x")
    assert c.value(op="a", variant="x") == 2
    assert c.value(op="a", variant="y") == 2
    assert c.value(op="never") == 0
    assert c.total() == 4
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create: same object back, kind mismatch is loud
    assert reg.counter("hits") is c
    with pytest.raises(ValueError):
        reg.gauge("hits")


def test_gauge_and_histogram():
    reg = obs.MetricsRegistry("t")
    g = reg.gauge("depth")
    g.set(3)
    g.set(7)
    assert g.value() == 7
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    assert h.buckets[-1] == float("inf")         # inf auto-appended
    for v in (0.05, 0.5, 2.0):
        h.observe(v)
    assert h.count() == 3
    assert h.sum() == pytest.approx(2.55)
    s = h.series()[()]
    assert s["bucket_counts"] == [1, 1, 1]
    assert (s["min"], s["max"]) == (0.05, 2.0)


def test_snapshot_is_json_safe():
    reg = obs.MetricsRegistry("t")
    reg.counter("c").inc(model="m@jnp")
    reg.gauge("g").set(1.5)
    h = reg.histogram("h")
    h.observe(0.2)
    snap = reg.snapshot()
    text = json.dumps(snap)                      # must not raise
    assert json.loads(text) == snap
    assert snap["c"]["kind"] == "counter"
    assert snap["c"]["series"] == [
        {"labels": {"model": "m@jnp"}, "value": 1}]
    assert snap["h"]["buckets"][-1] == "inf"
    # untouched histogram min/max never leak inf into JSON
    reg2 = obs.MetricsRegistry()
    reg2.histogram("h2").observe(float("inf"))
    json.dumps(reg2.snapshot())
    reg.reset()
    assert reg.snapshot()["c"]["series"] == []


def test_series_view_is_counter_shaped():
    reg = obs.MetricsRegistry("t")
    c = reg.counter("f")
    view = c.view("op", "variant")
    assert not view                              # falsy when empty
    c.inc(op="squash", variant="approx")
    assert view                                  # live view
    assert view[("squash", "approx")] == 1
    assert ("squash", "approx") in view
    assert ("routing.squash", "approx") not in view
    assert dict(view) == {("squash", "approx"): 1}
    single = c.view("op")
    assert single["squash"] == 1


def test_pallas_backend_fallbacks_are_registry_backed():
    from repro.nn.backend import BACKENDS, PallasBackend
    from repro.obs import METRICS
    be = PallasBackend()                         # private registry
    assert not be.fallbacks
    with pytest.warns(RuntimeWarning):
        be._fallback("squash", "approx")
    assert be.fallbacks[("squash", "approx")] == 1
    assert be.metrics.counter("pallas.fallback_decisions").total() == 1
    # the BACKENDS singleton records into the process registry instead
    assert BACKENDS["pallas"].metrics is METRICS
    assert "pallas.fallback_decisions" in METRICS.names()


def test_model_registry_counts_are_views():
    reg = ModelRegistry(specs={"tiny": ModelSpec(
        "tiny", EDGE_TINY, dataset="uniform", calib_n=4)})
    assert (reg.quantize_count, reg.compile_count, reg.exec_hits) == (0, 0, 0)
    with pytest.raises(AttributeError):          # views are read-only now
        reg.quantize_count = 5
    reg.executable("tiny", 1)
    reg.executable("tiny", 1)
    assert (reg.quantize_count, reg.compile_count, reg.exec_hits) == (1, 1, 1)
    # labeled series carry the model id
    assert reg.metrics.counter("serving.quantize_builds") \
        .value(model="tiny") == 1
    snap = reg.metrics.snapshot()
    assert snap["serving.wave_compiles"]["series"][0]["labels"] == {
        "bucket": "1", "model": "tiny"}


# ---------------------------------------------------------------------------
# ServeMetrics empty-state handling
# ---------------------------------------------------------------------------
def test_servemetrics_empty_is_explicit_not_nan():
    m = ServeMetrics()
    # pinned low-level contract: nan on empty
    assert np.isnan(m.latency_percentile(50))
    assert np.isnan(m.occupancy())
    assert np.isnan(m.images_per_s())
    s = m.summary()
    assert s["empty"] is True
    assert s["images"] == 0
    assert s["p50_ms"] is None and s["occupancy"] is None
    assert s["images_per_s"] is None
    json.dumps(s)                                # NaN would break this
    r = m.report()
    assert "no completed requests" in r
    assert "nan" not in r.lower()


def test_servemetrics_partial_window_report():
    m = ServeMetrics()
    m.record_submit(1.0, 1)                      # submitted, never served
    s = m.summary()
    assert s["empty"] is True and s["max_queue_depth"] == 1
    assert "nan" not in m.report().lower()
    # ... and a full window keeps the old report shape
    m.record_wave(bucket=4, n_real=2, exec_s=0.5, t_done=2.0,
                  latencies_s=[0.5, 1.0])
    s = m.summary()
    assert s["empty"] is False
    assert s["occupancy"] == pytest.approx(0.5)
    assert "2 imgs in 1 waves" in m.report()
    assert "nan" not in m.report().lower()


def test_servemetrics_optional_registry_mirror():
    reg = obs.MetricsRegistry("t")
    m = ServeMetrics(registry=reg)
    m.record_submit(1.0, 3)
    m.record_wave(bucket=4, n_real=2, exec_s=0.5, t_done=2.0,
                  latencies_s=[0.5, 1.0])
    assert reg.counter("serve.requests_total").value(bucket="4") == 2
    assert reg.histogram("serve.latency_seconds").count() == 2
    assert reg.gauge("serve.queue_depth").value() == 3
    assert reg.gauge("serve.wave_occupancy").value() == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# traced serving: bit parity + span forest
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def edge_tiny_registry():
    return ModelRegistry(specs={"tiny": ModelSpec(
        "tiny", EDGE_TINY, dataset="uniform", calib_n=8)})


def _serve(registry, images, tracer=None):
    engine = CapsServeEngine(registry, buckets=(1, 4), tracer=tracer)
    engine.submit_many(images, "tiny")
    return engine.drain()


def test_traced_serving_bit_identical_and_nested(edge_tiny_registry,
                                                 tmp_path):
    rng = np.random.default_rng(7)
    images = rng.uniform(0, 1, (6,) + tuple(EDGE_TINY.input_shape)) \
        .astype(np.float32)
    base = _serve(edge_tiny_registry, images)
    tracer = obs.Tracer()
    traced = _serve(edge_tiny_registry, images, tracer=tracer)
    assert len(base) == len(traced) == 6
    for b, t in zip(base, traced):
        assert np.array_equal(b.v_q, t.v_q)      # bit-identical
        assert (b.pred, b.wave, b.bucket) == (t.pred, t.wave, t.bucket)

    # span forest: enqueue roots + one wave root per wave, with the
    # bucket/compile/execute/complete pipeline nested inside
    assert len(tracer.find("serve.enqueue")) == 6
    waves = [r for r in tracer.roots if r.name == "serve.wave"]
    assert len(waves) == len({c.wave for c in traced}) == 2
    for w in waves:
        kids = [c.name for c in w.children]
        assert kids == ["serve.bucket", "serve.compile", "serve.execute",
                        "serve.complete"]
        assert w.t0 <= w.children[0].t0 and w.children[-1].t1 <= w.t1
    # valid Chrome JSON with the nesting visible as containment
    path = tracer.write_chrome_trace(tmp_path / "trace.json")
    doc = json.loads(path.read_text())
    names = [e["name"] for e in doc["traceEvents"]]
    assert names.count("serve.wave") == 2
    assert names.count("serve.execute") == 2
    for e in doc["traceEvents"]:
        assert e["ph"] == "X" and e["dur"] >= 0


def test_ambient_tracer_reaches_engine_and_ptq(edge_tiny_registry):
    # a FRESH registry so the lazy PTQ build happens inside the traced
    # window (the module fixture's model is already built)
    registry = ModelRegistry(specs={"tiny": ModelSpec(
        "tiny", EDGE_TINY, dataset="uniform", calib_n=8)})
    rng = np.random.default_rng(8)
    images = rng.uniform(0, 1, (2,) + tuple(EDGE_TINY.input_shape)) \
        .astype(np.float32)
    tracer = obs.Tracer()
    with obs.tracing(tracer):
        done = _serve(registry, images)
    assert len(done) == 2
    assert tracer.find("serving.ptq_build")      # registry spans
    assert tracer.find("ptq.calibrate")          # pipeline spans
    assert tracer.find("serving.compile_wave")
    wave = tracer.find("serve.wave")[0]
    assert wave.find("serve.execute")            # nested under the wave


# ---------------------------------------------------------------------------
# EdgeVM profiler: bit parity + rows
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
@pytest.mark.parametrize("name", sorted(test_edge.CONFIGS))
def test_edgevm_profile_bit_parity(name, rounding):
    qnet, x_q = test_edge.built(name, rounding)
    vm = EdgeVM(lower(qnet))
    base = vm.run(x_q)
    prof: list = []
    profiled = vm.run(x_q, profile=prof)
    assert np.array_equal(base, profiled)
    assert [r["name"] for r in prof] == [op.name for op in vm.program.ops]
    assert all(r["wall_s"] >= 0 for r in prof)
    assert {"name", "kind", "wall_s"} <= set(prof[0])
    # ambient tracing alone must not perturb the bits either
    tracer = obs.Tracer()
    with obs.tracing(tracer):
        traced = vm.run(x_q)
    assert np.array_equal(base, traced)
    run = tracer.find("edgevm.run")[0]
    assert len(run.children) == len(vm.program.ops)


# ---------------------------------------------------------------------------
# MCU cost model: calibration against the paper's latency tables
# ---------------------------------------------------------------------------
def _m_geometry_program() -> EdgeProgram:
    """The paper's smallNORB "M" layer shapes (Table 1): pcap
    26x26x32 -k7 s2-> 10x10x64 (16 caps x D4 per position -> I=1600),
    routing J=5, I=1600, O=6, D=4, r=3 — weights zeroed (the cost model
    reads geometry only)."""
    tensors = (
        TensorSpec(0, "input", (26, 26, 32), 7),
        TensorSpec(1, "pcap.out", (1600, 4), 7),
        TensorSpec(2, "caps.out", (5, 6), 7),
    )
    pcap = EdgeOp(
        kind="PRIMARY_CAPS_Q7", name="pcap", inputs=(0,), output=1,
        attrs={"kernel": 7, "stride": 2, "in_ch": 32, "out_ch": 64,
               "dim": 4, "relu": False, "bias_shift": 0, "out_shift": 0,
               "squash_in_frac": 7, "squash_out_frac": 7},
        weights={"w": np.zeros((7, 7, 32, 64), np.int8),
                 "b": np.zeros((64,), np.int32)})
    caps = EdgeOp(
        kind="CAPS_ROUTING_Q7", name="caps", inputs=(1,), output=2,
        attrs={"num_in": 1600, "num_out": 5, "in_dim": 4, "out_dim": 6,
               "routings": 3, "uhat_shift": 0, "logit_frac": 7,
               "caps_out_shifts": (0, 0, 0), "caps_out_fracs": (7, 7, 7),
               "agree_shifts": (0, 0), "squash_out_frac": 7},
        weights={"W": np.zeros((5, 1600, 6, 4), np.int8)})
    return EdgeProgram(name="smallnorb_M", rounding="floor", input_frac=7,
                       tensors=tensors, ops=(pcap, caps))


def test_m_geometry_workload_counts():
    program = _m_geometry_program()
    pcap, caps = program.ops
    assert costmodel.op_counts(program, pcap)["macs"] == 10_035_200
    c = costmodel.op_counts(program, caps)
    assert c["macs"] + c["elems"] == 456_090


@pytest.mark.parametrize("profile", sorted(costmodel.MCU_PROFILES))
def test_costmodel_reproduces_paper_latencies(profile):
    est = costmodel.estimate_program(_m_geometry_program(), profile)
    want = costmodel.PAPER_LATENCY_MS[profile]
    by_name = {r["name"]: r["ms"] for r in est["rows"]}
    assert by_name["pcap"] == pytest.approx(
        want["primary_caps"], rel=costmodel.CALIB_REL_TOL)
    assert by_name["caps"] == pytest.approx(
        want["caps_routing"], rel=costmodel.CALIB_REL_TOL)
    assert est["total_ms"] == pytest.approx(
        want["primary_caps"] + want["caps_routing"],
        rel=costmodel.CALIB_REL_TOL)


def test_costmodel_surfaces():
    qnet, _ = test_edge.built("capsnet_edge_tiny")
    program = lower(qnet)
    ests = costmodel.estimate_all(program)
    assert set(ests) == set(costmodel.MCU_PROFILES)
    for est in ests.values():
        assert est["total_cycles"] == pytest.approx(
            sum(r["cycles"] for r in est["rows"]))
    assert costmodel.total_latency_ms(program, "cortex-m7") \
        == ests["cortex-m7"]["total_ms"]
    with pytest.raises(ValueError):
        costmodel.get_profile("z80")
    text = costmodel.format_estimates(program)
    assert "cortex-m7" in text and "gap8" in text
    # the memory report integration (arena.py)
    from repro.edge import memory_report
    report = memory_report(program, profile="gap8")
    assert report["profile"] == "gap8"
    assert report["est_total_ms"] == pytest.approx(
        ests["gap8"]["total_ms"])
    assert all("est_ms" in r for r in report["rows"])
    from repro.edge import format_report
    assert "est. latency on gap8" in format_report(report)
    # without a profile: no estimate keys (pre-obs shape)
    assert "profile" not in memory_report(program)


def test_table2_rows_carry_latency_axis():
    from repro.captrain.evalq import Table2Row, format_rows
    row = Table2Row(name="n", rounding="floor", acc_f32=0.9, acc_ptq=0.88,
                    acc_qat=0.89, saving_pct=74.0, est_ms_m7=119.94,
                    est_ms_gap8=7.02)
    out = format_rows([row])
    assert "m7_ms" in out and "gap8_ms" in out
    assert "119.94" in out and "7.02" in out


# ---------------------------------------------------------------------------
# bench artifacts: schema + validator gates
# ---------------------------------------------------------------------------
def _bench_doc(**over):
    doc = {"schema": "repro.bench/v1", "section": "serving",
           "stamp": "s", "smoke": True, "config": {}, "figures": {},
           "rows": [{"name": "serve_batched_x", "us_per_call": 1.0,
                     "derived": "d", "figures": {"occupancy": 0.9}}]}
    doc.update(over)
    return doc


def test_bench_recorder_writes_schema(tmp_path):
    from benchmarks import util, validate
    rec = util.BenchRecorder(tmp_path, stamp="abc")
    rec.begin_section("serving", models=["tiny"])
    rec.add_row("serve_batched_tiny", 12.5, "fast", {"occupancy": 1.0})
    rec.add_figures(total=1)
    rec.end_section()
    path = tmp_path / "BENCH_serving.json"
    assert rec.written == [path]
    doc = json.loads(path.read_text())
    assert validate.validate_doc(doc, "t") == []
    assert validate.validate_invariants(doc, "t") == []
    assert doc["stamp"] == "abc"
    assert doc["config"] == {"models": ["tiny"]}
    assert doc["figures"] == {"total": 1}
    assert doc["rows"][0]["figures"]["occupancy"] == 1.0
    paths, findings = validate.validate_dir(tmp_path)
    assert paths == [path] and findings == []


def test_bench_validator_catches_schema_breaks():
    from benchmarks import validate
    assert validate.validate_doc(_bench_doc(schema="nope/v9"), "t")
    bad = _bench_doc()
    del bad["stamp"]
    assert any("stamp" in f for f in validate.validate_doc(bad, "t"))
    bad = _bench_doc(rows=[{"name": "x"}])
    assert validate.validate_doc(bad, "t")


def test_bench_validator_gates_invariants(tmp_path):
    from benchmarks import validate
    # occupancy must be > 0 on batched serving rows
    bad = _bench_doc()
    bad["rows"][0]["figures"]["occupancy"] = 0.0
    assert any("occupancy" in f
               for f in validate.validate_invariants(bad, "t"))
    # default-variant fallbacks must be zero
    ob = _bench_doc(section="observability", rows=[],
                    figures={"default_variant_fallbacks": 3})
    assert any("default_variant_fallbacks" in f
               for f in validate.validate_invariants(ob, "t"))
    # empty dir and unreadable file are findings, and main() exits 1
    (tmp_path / "BENCH_broken.json").write_text("{not json")
    _, findings = validate.validate_dir(tmp_path)
    assert findings
    import contextlib
    import io
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        assert validate.main([str(tmp_path)]) == 1
    assert "FINDING" in buf.getvalue()


# ---------------------------------------------------------------------------
# CLI --profile smoke
# ---------------------------------------------------------------------------
def test_export_caps_profile_cli(tmp_path, capsys):
    from repro.launch import export_caps
    rc = export_caps.main(["--model", "edge_tiny", "--out",
                           str(tmp_path), "--verify-n", "2", "--profile"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "estimated cost on" in out
    assert "cortex-m7" in out and "gap8" in out
    assert "cycles" in out


def test_analysis_cli_profile(tmp_path, capsys):
    qnet, _ = test_edge.built("capsnet_edge_tiny")
    program = lower(qnet)
    paths = program.save(tmp_path / "p")
    from repro.analysis.__main__ import main as analysis_main
    rc = analysis_main([str(paths["capsbin"]), "--profile"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "estimated cost on" in out and "gap8" in out


# ---------------------------------------------------------------------------
# trainer spans
# ---------------------------------------------------------------------------
def test_trainer_emits_spans(tmp_path):
    from repro.captrain import CapsTrainer, TrainConfig
    tcfg = TrainConfig(dataset="edge_tiny", batch=8, microbatches=2,
                       recon_weight=0.0, recalib_every=2, calib_n=8,
                       ckpt_every=2, ckpt_dir=str(tmp_path))
    trainer = CapsTrainer(EDGE_TINY, tcfg)
    state = trainer.init_state()
    tracer = obs.Tracer()
    with obs.tracing(tracer):
        state, plan, hist = trainer.fit(state, 2, qat=True)
    assert len(hist) == 2
    assert len(tracer.find("train.step")) == 2
    assert tracer.find("train.recalibrate")      # entry derivation
    assert tracer.find("train.ckpt")             # step 2 checkpoint
    # the final PTQ entry point carries the ptq.* spans
    with obs.tracing(tracer):
        trainer.quantize(state)
    assert tracer.find("ptq.calibrate")
    assert tracer.find("ptq.plan")
    assert tracer.find("ptq.quantize_weights")
