"""End-to-end behaviour tests for the paper's system: the full pipeline
(train float CapsNet -> PTQ -> int8 inference with the kernel stack) plus
LM substrate end-to-end (loss decreases, serving generates)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capsnet as C
from repro.data.synthetic import TokenTask, make_image_dataset
from repro.optim.adam import AdamW


def test_full_paper_pipeline_mnist():
    """train (float) -> calibrate -> quantize -> int8 inference via BOTH
    the jnp path and the fused Pallas routing kernel; footprints and
    accuracy deltas in the paper's regime."""
    from repro.quant import ptq
    from repro.core.capsnet_q7 import qcapsnet_forward, qclass_lengths
    from repro.kernels import ops as kops
    from repro.quant import int8_ops as q

    cfg = C.MNIST
    params = C.init_capsnet(jax.random.key(0), cfg)
    opt = AdamW(lr=cfg.lr, clip_norm=0.0, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        def loss_fn(p):
            v = C.capsnet_forward(p, x, cfg)
            return C.margin_loss(v, y, cfg.num_classes), v
        (loss, v), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, state, _ = opt.update(g, state, params)
        return params, state, loss

    for i in range(50):
        x, y = make_image_dataset("mnist", 64, seed=i)
        params, state, _ = step(params, state, jnp.asarray(x),
                                jnp.asarray(y))

    calib = jnp.asarray(make_image_dataset("mnist", 96, seed=5555)[0])
    qm = ptq.quantize_capsnet(params, cfg, calib, rounding="nearest")

    x, y = make_image_dataset("mnist", 32, seed=31337)
    xq = ptq.quantize_input(jnp.asarray(x), qm.shifts["input_frac"])

    # (a) jnp int8 reference path
    v_ref = qcapsnet_forward(qm, xq)

    # (b) same network with the FUSED Pallas routing kernel for the caps
    # layer: conv+pcap via jnp oracle ops, routing via kernel
    h = xq
    for i in range(len(cfg.conv_filters)):
        h = q.conv2d_q7(h, qm.weights[f"conv{i}"]["w"],
                        qm.weights[f"conv{i}"]["b"],
                        qm.shifts[f"conv{i}_out_shift"],
                        qm.shifts[f"conv{i}_bias_shift"],
                        stride=cfg.conv_strides[i], rounding=qm.rounding)
        h = q.relu_q7(h)
    from repro.core.capsnet_q7 import pcap_q7
    u = pcap_q7(qm, h)
    acc = jnp.einsum("jiod,bid->bjio",
                     qm.weights["caps"]["W"].astype(jnp.int32),
                     u.astype(jnp.int32))
    u_hat = q.rshift_sat8(acc, qm.shifts["uhat_shift"], qm.rounding)
    v_kernel = kops.routing_q7(
        u_hat, num_iters=cfg.routings,
        caps_out_shifts=tuple(qm.shifts[f"caps_out_shift_{r}"]
                              for r in range(cfg.routings)),
        caps_out_fracs=tuple(qm.shifts[f"caps_out_frac_{r}"]
                             for r in range(cfg.routings)),
        agree_shifts=tuple(qm.shifts[f"agree_shift_{r}"]
                           for r in range(cfg.routings - 1)),
        logit_frac=qm.shifts["logit_frac"], rounding=qm.rounding)
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_kernel))

    # predictions should mostly match the float model
    v_f = C.capsnet_forward(params, jnp.asarray(x), cfg)
    pred_f = np.asarray(jnp.argmax(C.class_lengths(v_f), -1))
    pred_q = np.asarray(jnp.argmax(qclass_lengths(qm, v_ref), -1))
    assert (pred_f == pred_q).mean() >= 0.9


def test_lm_train_loss_decreases():
    """The end-to-end LM driver substrate: loss on the structured token
    task must drop well below the starting point."""
    from tests.conftest import tiny_lm_config
    from repro.models.transformer import build_model

    cfg = tiny_lm_config(vocab_size=64, d_model=64)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=3e-3, clip_norm=1.0)
    state = {"params": params, "opt": opt.init(params)}
    task = TokenTask(cfg.vocab_size, 32, seed=5)

    @jax.jit
    def step(state, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: model.train_loss(p, batch), has_aux=True)(
                state["params"])
        p, o, _ = opt.update(g, state["opt"], state["params"])
        return {"params": p, "opt": o}, loss

    losses = []
    for i in range(80):
        state, loss = step(state, jax.tree.map(jnp.asarray,
                                               task.batch(i, 16)))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])


def test_serve_generates_consistent_tokens():
    """Greedy decode is deterministic & consistent across cache reuse."""
    from tests.conftest import tiny_lm_config
    from repro.models.transformer import build_model, decode_alloc

    cfg = tiny_lm_config()
    model = build_model(cfg)
    params = model.init(jax.random.key(4))
    toks = jnp.asarray(np.random.default_rng(0).integers(1, 200, (2, 8)),
                       jnp.int32)

    def generate(n):
        lg, cache = model.prefill(params, {"inputs": toks},
                                  alloc=decode_alloc(8 + n))
        out = []
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        for i in range(n):
            out.append(np.asarray(tok))
            lg, cache = model.decode_step(params, cache, tok,
                                          jnp.asarray(8 + i, jnp.int32))
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        return np.concatenate(out, 1)

    g1, g2 = generate(6), generate(6)
    np.testing.assert_array_equal(g1, g2)
