"""Tests for the analyze -> regress half of the observability loop
(repro.obs.analyze + repro.obs.baseline + the bench schema gates).

Pins, in order:
  * the analyzer under a fake clock: per-span stats, self vs child
    time, wave critical paths, the queue/compile/execute breakdown and
    the reconstructed per-request timelines — all EXACT, and bit-equal
    whether the source is the live Tracer or its own Chrome export;
  * the repo-wide tiny-sample percentile policy on obs.Histogram:
    n < 3 returns the exact max (never interpolates), empty returns
    None, snapshots carry p50/p95/p99;
  * req_id propagation through a REAL serving run: one request's
    enqueue -> wave -> complete timeline reconstructed from the trace
    alone matches what the engine reported for that request;
  * cost-model drift: 100% join coverage of the schedule for every
    config x rounding, both MCU profiles, shares summing to 1;
  * the perf-baseline gate: the committed benchmarks/baselines/ snapshot
    self-compares clean, a doctored 3x slowdown fails with the metric
    named, direction-awareness (improvements never fail), --slack
    widening timing tolerances only;
  * the bench validator's stamp / known-section rules;
  * CLI smokes: obs.analyze, obs.baseline, serve_caps --trace-summary /
    --metrics-out, export_caps --drift.
"""
import json
import pathlib

import numpy as np
import pytest

from repro import obs
from repro.edge import EdgeVM, lower
from repro.obs import analyze, baseline
from repro.serving import EDGE_TINY, CapsServeEngine, ModelRegistry, ModelSpec

import test_edge
from test_obs import FakeClock

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(autouse=True)
def _no_ambient_tracer():
    obs.set_tracer(None)
    yield
    obs.set_tracer(None)


def _fake_serve_trace() -> obs.Tracer:
    """A hand-built serve-shaped forest under the fake clock (every
    read advances 1s), so every analyzer number is exact:

      enqueue#0 [1,2]  enqueue#1 [3,4]
      wave [5,16]: bucket [6,7]  compile [8,9]
                   execute [10,13] > edgevm.run [11,12]
                   complete [14,15]
    """
    tr = obs.Tracer(clock=FakeClock())
    with tr.span("serve.enqueue", model="m", req_id=0):
        pass
    with tr.span("serve.enqueue", model="m", req_id=1):
        pass
    with tr.span("serve.wave", wave=0, model="m") as w:
        with tr.span("serve.bucket"):
            pass
        with tr.span("serve.compile"):
            pass
        with tr.span("serve.execute"):
            with tr.span("edgevm.run"):
                pass
        with tr.span("serve.complete", req_ids="0,1"):
            pass
        w.note(bucket=4, n_real=2, req_ids="0,1")
    return tr


# ---------------------------------------------------------------------------
# analyzer: exact numbers under the fake clock
# ---------------------------------------------------------------------------
def test_span_stats_exact_under_fake_clock():
    report = analyze.analyze(_fake_serve_trace())
    assert report["span_count"] == 8
    s = report["spans"]
    # epoch-normalized: the first enqueue starts at 0.0
    assert s["serve.enqueue"] == {
        "count": 2, "total_s": 2.0, "mean_s": 1.0, "p50_s": 1.0,
        "p95_s": 1.0, "max_s": 1.0, "self_s": 2.0}
    # wave [4,15]: dur 11, children 1+1+3+1 -> self 5
    assert s["serve.wave"]["total_s"] == 11.0
    assert s["serve.wave"]["self_s"] == 5.0
    # execute [9,12] contains edgevm.run [10,11] -> self 2
    assert s["serve.execute"]["total_s"] == 3.0
    assert s["serve.execute"]["self_s"] == 2.0
    assert s["edgevm.run"]["self_s"] == 1.0


def test_wave_critical_path_and_summary():
    report = analyze.analyze(_fake_serve_trace())
    (w,) = report["waves"]
    assert (w["wave"], w["model"], w["bucket"], w["n_real"]) \
        == (0, "m", 4, 2)
    assert w["req_ids"] == [0, 1]
    assert w["dur_s"] == 11.0
    # execute (3s) dominates bucket/compile/complete (1s each)
    assert [p["name"] for p in w["critical_path"]] \
        == ["serve.wave", "serve.execute", "edgevm.run"]
    assert [p["dur_s"] for p in w["critical_path"]] == [11.0, 3.0, 1.0]


def test_request_timelines_exact():
    report = analyze.analyze(_fake_serve_trace())
    r0, r1 = report["requests"]
    # rid 0: enqueued [0,1], wave opens at 4, last complete exits at 14
    assert (r0["req_id"], r0["wave"], r0["bucket"]) == (0, 0, 4)
    assert (r0["t_enq"], r0["t_done"]) == (0.0, 14.0)
    assert (r0["e2e_s"], r0["queue_s"]) == (14.0, 3.0)
    # rid 1: enqueued [2,3] -> shorter queue, same completion
    assert (r1["t_enq"], r1["e2e_s"], r1["queue_s"]) == (2.0, 12.0, 1.0)


def test_wave_breakdown_exact():
    report = analyze.analyze(_fake_serve_trace())
    (b,) = report["breakdown"]
    assert (b["model"], b["bucket"], b["waves"], b["images"]) \
        == ("m", 4, 1, 2)
    assert b["wave_s"] == 11.0
    assert (b["bucket_s"], b["compile_s"], b["execute_s"],
            b["complete_s"]) == (1.0, 1.0, 3.0, 1.0)
    assert b["queue_s"] == 4.0                   # 3.0 + 1.0


def test_chrome_round_trip_is_bit_identical(tmp_path):
    tr = _fake_serve_trace()
    from_tracer = analyze.analyze(tr)
    from_dict = analyze.analyze(tr.chrome_trace())
    assert from_tracer == from_dict              # same report, bit for bit
    path = tr.write_chrome_trace(tmp_path / "trace.json")
    assert analyze.analyze(path) == from_tracer
    assert analyze.analyze(str(path)) == from_tracer
    # and the whole report is JSON-safe
    json.loads(json.dumps(from_tracer))


def test_load_trace_rejects_garbage():
    with pytest.raises(TypeError):
        analyze.load_trace(42)


def test_format_analysis_renders_every_block():
    report = analyze.analyze(_fake_serve_trace())
    text = analyze.format_analysis(report)
    assert "8 spans" in text
    assert "serve.wave > serve.execute > edgevm.run" in text
    assert "breakdown per (model, bucket)" in text
    assert "requests: 2 reconstructed" in text


# ---------------------------------------------------------------------------
# tiny-sample percentile policy (obs.Histogram + the analyzer's _pctl)
# ---------------------------------------------------------------------------
def test_histogram_percentile_tiny_samples():
    reg = obs.MetricsRegistry("t")
    h = reg.histogram("lat", buckets=(1.0, 10.0))
    assert h.percentile(50) is None              # empty: no number at all
    h.observe(3.0)
    # 1 and 2 observations: the exact max, never an interpolation
    assert h.percentile(50) == 3.0
    assert h.percentile(99) == 3.0
    h.observe(0.5)
    assert h.percentile(50) == 3.0
    assert h.percentile(95) == 3.0
    s = h.summary()
    assert (s["count"], s["p50"], s["p95"]) == (2, 3.0, 3.0)


def test_histogram_percentile_nearest_rank_and_snapshot():
    reg = obs.MetricsRegistry("t")
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 3.0, 5.0):
        h.observe(v)
    # nearest-rank over cumulative buckets: p50 -> rank 2 -> bucket <=2.0
    assert h.percentile(50) == 2.0
    # p95 -> rank 4 -> last bucket, clamped to the observed max
    assert h.percentile(95) == 5.0
    snap = reg.snapshot()
    (series,) = snap["lat"]["series"]
    assert {"p50", "p95", "p99"} <= set(series["value"])
    assert series["value"]["p95"] == 5.0
    json.dumps(snap)                             # inf never leaks


def test_analyzer_pctl_matches_policy():
    assert analyze._pctl([], 50) is None
    assert analyze._pctl([7.0], 95) == 7.0
    assert analyze._pctl([1.0, 9.0], 50) == 9.0  # n<3 -> exact max
    vals = sorted(float(i) for i in range(1, 11))
    assert analyze._pctl(vals, 50) == 5.0        # nearest rank, 1-based
    assert analyze._pctl(vals, 95) == 10.0
    assert analyze._pctl(vals, 99) == 10.0


# ---------------------------------------------------------------------------
# req_id propagation through a real serving run
# ---------------------------------------------------------------------------
def test_real_serve_trace_reconstructs_requests():
    registry = ModelRegistry(specs={"tiny": ModelSpec(
        "tiny", EDGE_TINY, dataset="uniform", calib_n=8)})
    rng = np.random.default_rng(3)
    images = rng.uniform(0, 1, (6,) + tuple(EDGE_TINY.input_shape)) \
        .astype(np.float32)
    tracer = obs.Tracer()
    engine = CapsServeEngine(registry, buckets=(1, 4), tracer=tracer)
    rids = [engine.submit(img, "tiny") for img in images]
    done = {c.rid: c for c in engine.drain()}

    report = analyze.analyze(tracer)
    rows = {r["req_id"]: r for r in report["requests"]}
    assert set(rows) == set(rids) == set(done)
    # pin one full reconstructed timeline against the engine's own view
    r0, c0 = rows[rids[0]], done[rids[0]]
    assert (r0["wave"], r0["bucket"]) == (c0.wave, c0.bucket)
    assert r0["queue_s"] >= 0.0
    assert r0["e2e_s"] >= r0["queue_s"]
    assert r0["t_enq"] <= r0["t_done"]
    # every wave span carries its membership, covering all requests once
    member = [rid for w in report["waves"] for rid in w["req_ids"]]
    assert sorted(member) == sorted(rids)
    for w in report["waves"]:
        assert w["critical_path"][0]["name"] == "serve.wave"
        assert w["n_real"] == len(w["req_ids"])


# ---------------------------------------------------------------------------
# cost-model drift: 100% join coverage for every config x rounding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
@pytest.mark.parametrize("name", sorted(test_edge.CONFIGS))
def test_costmodel_drift_full_coverage(name, rounding):
    qnet, x_q = test_edge.built(name, rounding)
    program = lower(qnet)
    rows: list = []
    EdgeVM(program).run(x_q, profile=rows)
    batch = x_q.shape[0] if x_q.ndim == 4 else 1
    drift = analyze.costmodel_drift(program, rows, batch=batch)
    assert drift["coverage"] == 1.0
    assert drift["n_joined"] == drift["n_ops"] == len(program.ops)
    assert drift["unmatched"] == []
    assert set(drift["profiles"]) == {"cortex-m7", "gap8"}
    for p in drift["profiles"].values():
        assert len(p["rows"]) == len(program.ops)
        assert sum(r["est_share"] for r in p["rows"]) \
            == pytest.approx(1.0)
        assert sum(r["meas_share"] for r in p["rows"]) \
            == pytest.approx(1.0)
        assert p["total_est_ms"] > 0
    text = analyze.format_drift(drift)
    assert "100%" in text and "cortex-m7" in text


def test_costmodel_drift_reports_unjoined_ops():
    qnet, x_q = test_edge.built("capsnet_edge_tiny")
    program = lower(qnet)
    rows: list = []
    EdgeVM(program).run(x_q, profile=rows)
    drift = analyze.costmodel_drift(program, rows[:-1])
    assert drift["coverage"] < 1.0
    assert drift["unmatched"][0]["name"] == program.ops[-1].name
    assert "UNMATCHED" in analyze.format_drift(drift)


# ---------------------------------------------------------------------------
# perf-baseline gate
# ---------------------------------------------------------------------------
def test_committed_baselines_self_compare_clean():
    base_dir = REPO / "benchmarks" / "baselines"
    assert sorted(p.name for p in base_dir.glob("BENCH_*.json")) == [
        "BENCH_edge_vm.json", "BENCH_numerics.json",
        "BENCH_observability.json", "BENCH_search.json",
        "BENCH_serving.json", "BENCH_variants.json"]
    findings, notes = baseline.compare_dirs(base_dir, base_dir)
    assert findings == [] and notes == []


def test_injected_3x_slowdown_fails_with_named_metric(tmp_path):
    base_dir = REPO / "benchmarks" / "baselines"
    run_dir = tmp_path / "run"
    run_dir.mkdir()
    for p in base_dir.glob("BENCH_*.json"):
        (run_dir / p.name).write_text(p.read_text())
    doc = json.loads((run_dir / "BENCH_serving.json").read_text())
    for row in doc["rows"]:
        row["us_per_call"] *= 3.0                # 3x slower everywhere
        figs = row["figures"]
        for k in ("images_per_s", "speedup"):
            if k in figs:
                figs[k] /= 3.0
        if "p95_ms" in figs:
            figs["p95_ms"] *= 3.0
    (run_dir / "BENCH_serving.json").write_text(json.dumps(doc))
    findings, _ = baseline.compare_dirs(run_dir, base_dir)
    assert findings
    assert any("us_per_call" in f for f in findings)
    assert any("images_per_s" in f for f in findings)
    assert all(f.startswith("BENCH_serving") for f in findings)
    # the CLI turns the findings into exit 1 and REGRESSION lines
    rc = baseline.main(["compare", str(run_dir),
                        "--baselines", str(base_dir)])
    assert rc == 1


def test_gate_is_direction_aware():
    base = {"schema": baseline.BENCH_SCHEMA, "section": "serving",
            "stamp": "s", "smoke": True, "config": {}, "figures": {},
            "rows": [{"name": "r", "us_per_call": 100.0, "derived": "",
                      "figures": {"images_per_s": 1000.0, "p95_ms": 2.0,
                                  "occupancy": 0.5}}]}
    better = json.loads(json.dumps(base))
    better["rows"][0]["us_per_call"] = 10.0      # 10x faster
    better["rows"][0]["figures"]["images_per_s"] = 9000.0
    better["rows"][0]["figures"]["p95_ms"] = 0.5
    assert baseline.compare_docs(base, better) == []
    # ... but an exact metric moving AT ALL is a finding, even "up"
    better["rows"][0]["figures"]["occupancy"] = 0.9
    (f,) = baseline.compare_docs(base, better)
    assert "occupancy" in f and "deterministic" in f
    # slack widens timing tolerances only
    slow = json.loads(json.dumps(base))
    slow["rows"][0]["us_per_call"] = 300.0       # 3x: fails at slack 1
    assert any("us_per_call" in f
               for f in baseline.compare_docs(base, slow))
    assert baseline.compare_docs(base, slow, slack=2.0) == []
    slow["rows"][0]["figures"]["occupancy"] = 0.9
    assert any("occupancy" in f                  # exact ignores slack
               for f in baseline.compare_docs(base, slow, slack=100.0))


def test_gate_catches_disappearing_rows_and_sections(tmp_path):
    base_dir, run_dir = tmp_path / "base", tmp_path / "run"
    base_dir.mkdir()
    run_dir.mkdir()
    doc = {"schema": baseline.BENCH_SCHEMA, "section": "serving",
           "stamp": "s", "smoke": True, "config": {}, "figures": {},
           "rows": [{"name": "r", "us_per_call": 1.0, "derived": "",
                     "figures": {}}]}
    (base_dir / "BENCH_serving.json").write_text(json.dumps(doc))
    gone = json.loads(json.dumps(doc))
    gone["rows"] = []
    (run_dir / "BENCH_serving.json").write_text(json.dumps(gone))
    extra = dict(doc, section="edge_vm")
    (run_dir / "BENCH_edge_vm.json").write_text(json.dumps(extra))
    findings, notes = baseline.compare_dirs(run_dir, base_dir)
    assert any("disappeared" in f for f in findings)
    # unbaselined sections are notes, not failures
    assert any("edge_vm" in n for n in notes)
    # a baselined section missing entirely IS a failure
    (run_dir / "BENCH_serving.json").unlink()
    findings, _ = baseline.compare_dirs(run_dir, base_dir)
    assert any("missing from the run" in f for f in findings)


def test_record_refuses_malformed_docs(tmp_path):
    out_dir, base_dir = tmp_path / "out", tmp_path / "base"
    out_dir.mkdir()
    bad = {"schema": baseline.BENCH_SCHEMA, "section": "serving",
           "stamp": "", "smoke": True, "config": {}, "figures": {},
           "rows": []}
    (out_dir / "BENCH_serving.json").write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="stamp"):
        baseline.record(out_dir, base_dir)
    with pytest.raises(ValueError, match="nothing to record"):
        baseline.record(out_dir, base_dir, sections={"edge_vm"})
    good = dict(bad, stamp="s")
    (out_dir / "BENCH_serving.json").write_text(json.dumps(good))
    written = baseline.record(out_dir, base_dir)
    assert [p.name for p in written] == ["BENCH_serving.json"]
    findings, _ = baseline.compare_dirs(out_dir, base_dir)
    assert findings == []


# ---------------------------------------------------------------------------
# bench validator: stamp + known-section rules
# ---------------------------------------------------------------------------
def test_validator_refuses_unknown_section_and_empty_stamp():
    from benchmarks import util, validate
    assert util.SCHEMA == validate.SCHEMA        # single source of truth
    doc = {"schema": validate.SCHEMA, "section": "serving", "stamp": "x",
           "smoke": True, "config": {}, "figures": {}, "rows": []}
    assert validate.validate_doc(doc, "t") == []
    assert any("unknown section" in f for f in validate.validate_doc(
        dict(doc, section="made_up"), "t"))
    assert any("stamp" in f for f in validate.validate_doc(
        dict(doc, stamp="  "), "t"))
    assert "observability" in validate.KNOWN_SECTIONS


# ---------------------------------------------------------------------------
# CLI smokes
# ---------------------------------------------------------------------------
def test_analyze_cli(tmp_path, capsys):
    tr = _fake_serve_trace()
    path = tr.write_chrome_trace(tmp_path / "trace.json")
    metrics = tmp_path / "metrics.json"
    reg = obs.MetricsRegistry("r")
    reg.counter("serve.requests_total").inc(2)
    metrics.write_text(json.dumps(
        {"schema": "repro.metrics/v1", "process": {},
         "run": reg.snapshot(), "serve_summary": None}))
    assert analyze.main([str(path), "--metrics", str(metrics)]) == 0
    out = capsys.readouterr().out
    assert "serve.wave > serve.execute" in out
    assert "serve.requests_total (counter): 2" in out
    assert analyze.main([str(path), "--json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["span_count"] == 8


def test_baseline_cli_compare_ok(capsys):
    rc = baseline.main(["compare", str(REPO / "benchmarks" / "baselines"),
                        "--baselines",
                        str(REPO / "benchmarks" / "baselines")])
    out = capsys.readouterr().out
    assert rc == 0
    assert "0 findings ok" in out


def test_serve_caps_trace_summary_and_metrics_out(tmp_path, capsys):
    from repro.launch import serve_caps
    metrics_path = tmp_path / "m.json"
    rc = serve_caps.main(["--model", "edge_tiny@jnp", "--requests", "4",
                          "--buckets", "1,4", "--trace-summary",
                          "--metrics-out", str(metrics_path)])
    out = capsys.readouterr().out
    assert rc is None or rc == 0
    assert "trace summary:" in out
    assert "waves (critical path):" in out
    assert "requests: 4 reconstructed" in out
    doc = json.loads(metrics_path.read_text())
    assert doc["schema"] == "repro.metrics/v1"
    assert doc["serve_summary"]["images"] == 4
    assert "serve.requests_total" in doc["run"]
    # the analyzer accepts the dump as its --metrics input
    text = analyze._format_metrics(doc)
    assert "serve.requests_total" in text


def test_export_caps_drift_cli(tmp_path, capsys):
    from repro.launch import export_caps
    rc = export_caps.main(["--model", "edge_tiny", "--out",
                           str(tmp_path), "--verify-n", "0", "--drift",
                           "--drift-n", "2"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "cost-model drift" in out
    assert "join coverage 3/3 ops = 100%" in out
    assert "gap8" in out and "cortex-m7" in out
