"""Tests for the MCU export compiler (repro.edge).

Core guarantees:
  * the NumPy q7 VM executes `lower(qnet)` bit-identically to
    `QuantCapsNet.forward` for all three paper configs + edge_tiny and
    both rounding modes (and for per-channel conv plans);
  * `.capsbin` serialize -> load round-trips the program and its
    execution exactly;
  * the arena planner never overlaps live tensors and always beats the
    naive sum-of-activations allocation;
  * the C emitter is deterministic (golden files);
  * the exported memory report reproduces the paper's Table 2 footprint
    story (>= 70 % total reduction vs fp32).
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capsnet as C
from repro.edge import (EdgeOp, EdgeProgram, EdgeVM, TensorSpec,
                        assign_offsets, emit_c, lifetimes, lower,
                        memory_report, plan_arena)
from repro.nn.pipeline import CapsPipeline
from repro.quant import ptq
from repro.serving import EDGE_TINY, ModelRegistry

CONFIGS = dict(C.CAPSNET_CONFIGS, capsnet_edge_tiny=EDGE_TINY)
GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"

_cache = {}


def built(name, rounding="floor", per_channel=False):
    """Quantized net + probe inputs, cached across tests (PTQ is the
    expensive part; every edge test reuses the same builds)."""
    key = (name, rounding, per_channel)
    if key not in _cache:
        cfg = CONFIGS[name]
        pipe = CapsPipeline.from_config(cfg, per_channel=per_channel)
        params = pipe.init(jax.random.key(0))
        rng = np.random.default_rng(7)
        calib = jnp.asarray(
            rng.uniform(0, 1, (16,) + cfg.input_shape).astype(np.float32))
        x = jnp.asarray(
            rng.uniform(0, 1, (2,) + cfg.input_shape).astype(np.float32))
        qnet = pipe.quantize(params, calib, rounding=rounding)
        _cache[key] = (qnet, np.asarray(qnet.quantize_input(x)))
    return _cache[key]


# ---------------------------------------------------------------------------
# VM bit-parity (the subsystem's core contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_vm_bit_identical_to_host(name, rounding):
    qnet, x_q = built(name, rounding)
    program = lower(qnet)
    assert program.rounding == rounding
    v_vm = EdgeVM(program).run(x_q)
    v_host = np.asarray(qnet.forward(jnp.asarray(x_q)))
    assert v_vm.dtype == np.int8
    np.testing.assert_array_equal(v_vm, v_host)


def test_vm_per_channel_bit_identical():
    """Per-channel conv plans lower to shift tables the VM honours."""
    qnet, x_q = built("capsnet_edge_tiny", "nearest", per_channel=True)
    program = lower(qnet)
    conv = program.ops[0]
    assert conv.attrs["out_shift_per_channel"], "per-channel table missing"
    assert len(conv.attrs["out_shift_per_channel"]) == conv.attrs["out_ch"]
    np.testing.assert_array_equal(
        EdgeVM(program).run(x_q), np.asarray(qnet.forward(jnp.asarray(x_q))))


def test_vm_single_sample_and_bad_input():
    qnet, x_q = built("capsnet_edge_tiny")
    vm = EdgeVM(lower(qnet))
    batched = vm.run(x_q)
    single = vm.run(x_q[0])
    assert single.shape == batched.shape[1:]
    np.testing.assert_array_equal(single, batched[0])
    with pytest.raises(TypeError):
        vm.run(x_q.astype(np.float32))
    with pytest.raises(ValueError):
        vm.run(x_q[:, :4])


# ---------------------------------------------------------------------------
# serialization round-trip
# ---------------------------------------------------------------------------
def test_capsbin_round_trip(tmp_path):
    qnet, x_q = built("capsnet_edge_tiny")
    program = lower(qnet)
    paths = program.save(tmp_path / "m")
    reloaded = EdgeProgram.load(paths["capsbin"])
    assert program.same_as(reloaded) and reloaded.same_as(program)
    np.testing.assert_array_equal(EdgeVM(program).run(x_q),
                                  EdgeVM(reloaded).run(x_q))
    # the side-car manifest is the same header the binary embeds
    manifest = json.loads(paths["manifest"].read_text())
    assert manifest == program.header() == reloaded.header()


def test_capsbin_rejects_garbage(tmp_path):
    p = tmp_path / "x.capsbin"
    p.write_bytes(b"not a capsbin at all")
    with pytest.raises(ValueError, match="not a capsbin"):
        EdgeProgram.load(p)


# ---------------------------------------------------------------------------
# arena planner properties
# ---------------------------------------------------------------------------
def _check_no_overlap(blocks, offsets):
    for i, (ka, sa, (s0, e0)) in enumerate(blocks):
        for kb, sb, (s1, e1) in blocks[i + 1:]:
            if e0 < s1 or e1 < s0:
                continue            # disjoint lifetimes may share bytes
            a, b = offsets[ka], offsets[kb]
            assert a + sa <= b or b + sb <= a, \
                f"live blocks {ka} and {kb} overlap"


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_arena_plan_properties(name):
    qnet, _ = built(name)
    program = lower(qnet)
    plan = plan_arena(program)
    life = lifetimes(program)
    # tid 0 is the caller's input buffer: never arena-allocated
    assert 0 not in plan.offsets
    blocks = [(tid, program.tensor(tid).nbytes, life[tid])
              for tid in life if tid != 0]
    _check_no_overlap(blocks, plan.offsets)
    assert plan.arena_bytes <= plan.naive_bytes
    # liveness must actually buy something on a >=3-op schedule
    assert plan.arena_bytes < plan.naive_bytes
    assert plan.arena_bytes >= max(size for _, size, _ in blocks)


def test_arena_allocator_random_blocks():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(
        st.tuples(st.integers(1, 500),
                  st.tuples(st.integers(0, 9), st.integers(0, 9))),
        min_size=1, max_size=24))
    @hyp.settings(deadline=None, max_examples=60)
    def run(raw):
        blocks = [(i, size, (min(a, b), max(a, b)))
                  for i, (size, (a, b)) in enumerate(raw)]
        offsets = assign_offsets(blocks)
        _check_no_overlap(blocks, offsets)
        peak = max(offsets[k] + s for k, s, _ in blocks)
        assert peak <= sum(s for _, s, _ in blocks)

    run()


# ---------------------------------------------------------------------------
# memory report (paper Table 2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(C.CAPSNET_CONFIGS))
def test_memory_report_footprint(name):
    qnet, _ = built(name)
    report = memory_report(lower(qnet))
    assert report["saving_pct"] >= 70.0          # Table 2 ballpark
    assert report["arena_bytes"] < report["naive_act_bytes"]
    # flash agrees with the typed container's own accounting to within
    # the (few-dozen-scalar) difference in table bookkeeping
    assert abs(report["flash_bytes"] - qnet.memory_bytes()) < 512


# ---------------------------------------------------------------------------
# C emitter (golden files)
# ---------------------------------------------------------------------------
def golden_program() -> EdgeProgram:
    """Deterministic hand-built program (no RNG, no jax) so the golden
    files pin the emitter, not the initializer."""
    def arr(shape, dtype=np.int8, lo=-90):
        n = int(np.prod(shape))
        return (np.arange(n, dtype=np.int32) * 37 % 181 + lo) \
            .astype(dtype).reshape(shape)

    tensors = (
        TensorSpec(0, "input", (8, 8, 1), 7),
        TensorSpec(1, "conv0.out", (6, 6, 4), 5),
        TensorSpec(2, "pcap.caps", (8, 2), 7),
        TensorSpec(3, "caps.v", (2, 2), 7),
    )
    conv = EdgeOp("CONV_Q7", "conv0", (0,), 1, {
        "kernel": 3, "stride": 1, "in_ch": 1, "out_ch": 4, "relu": True,
        "in_frac": 7, "w_frac": 7, "b_frac": 8, "out_frac": 5,
        "out_shift": 9, "bias_shift": 6,
        "w_frac_per_channel": (7, 8, 7, 7),
        "out_shift_per_channel": (9, 10, 9, 9),
        "bias_shift_per_channel": (6, 7, 6, 6),
    }, {"w": arr((3, 3, 1, 4)), "b": arr((4,))})
    pcap = EdgeOp("PRIMARY_CAPS_Q7", "pcap", (1,), 2, {
        "kernel": 3, "stride": 2, "in_ch": 4, "out_ch": 4, "relu": False,
        "in_frac": 5, "w_frac": 7, "b_frac": 8, "out_frac": 6,
        "out_shift": 6, "bias_shift": 4, "caps": 2, "dim": 2,
        "squash_in_frac": 6, "squash_out_frac": 7,
    }, {"w": arr((3, 3, 4, 4)), "b": arr((4,))})
    caps = EdgeOp("CAPS_ROUTING_Q7", "caps", (2,), 3, {
        "num_out": 2, "num_in": 8, "out_dim": 2, "in_dim": 2,
        "routings": 2, "in_frac": 7, "W_frac": 7, "uhat_frac": 7,
        "uhat_shift": 7, "logit_frac": 7,
        "caps_out_shifts": (5, 5), "caps_out_fracs": (9, 9),
        "agree_shifts": (7,), "softmax_impl": "q7",
        "squash_out_frac": 7,
    }, {"W": arr((2, 8, 2, 2))})
    return EdgeProgram(name="golden_caps", rounding="floor",
                       input_frac=7, tensors=tensors,
                       ops=(conv, pcap, caps))


def golden_program_approx() -> EdgeProgram:
    """The golden program with the ISLPED'22 approximate softmax/squash
    variant references — pins the variant-specific C emission (kernel
    symbols + extra prototypes) the same way golden_caps pins the
    default one."""
    import dataclasses

    base = golden_program()
    ops = []
    for op in base.ops:
        attrs = dict(op.attrs)
        if op.kind == "PRIMARY_CAPS_Q7":
            attrs["squash_impl"] = "approx"
        elif op.kind == "CAPS_ROUTING_Q7":
            attrs["softmax_impl"] = "approx"
            attrs["squash_impl"] = "approx"
        ops.append(dataclasses.replace(op, attrs=attrs))
    return dataclasses.replace(base, name="golden_caps_approx",
                               ops=tuple(ops))


@pytest.mark.parametrize("make", [golden_program, golden_program_approx])
def test_emit_c_matches_golden(make):
    program = make()
    src = emit_c(program)
    for ext in ("c", "h"):
        golden = (GOLDEN_DIR / f"{program.name}.{ext}").read_text()
        assert src[ext] + "\n" == golden, \
            (f"emitted .{ext} drifted from tests/golden/{program.name}."
             f"{ext}; if the change is intentional, regenerate with "
             "tests/golden/regen.py")


def test_emit_c_approx_symbols():
    """Non-default variants change the emitted kernel symbols and add
    their prototypes; the default emission carries neither."""
    approx = emit_c(golden_program_approx())
    assert "capsnet_squash_q7_approx(" in approx["c"]
    assert ("capsnet_dynamic_routing_q7_softmax_approx_squash_approx("
            in approx["c"])
    base = emit_c(golden_program())
    assert "approx" not in base["c"] and "approx" not in base["h"]
    assert "ISLPED" in approx["h"]


def test_golden_program_runs_in_vm():
    program = golden_program()
    x = (np.arange(64, dtype=np.int32) % 201 - 100).astype(np.int8)
    v = EdgeVM(program).run(x.reshape(8, 8, 1))
    assert v.shape == (2, 2) and v.dtype == np.int8


# ---------------------------------------------------------------------------
# export path + per-channel satellite
# ---------------------------------------------------------------------------
def test_registry_export(tmp_path):
    result = ModelRegistry().export("edge_tiny@jnp", tmp_path)
    for p in result["paths"].values():
        assert p.exists() and p.stat().st_size > 0
    assert result["verified"] == 4
    assert {p.suffix for p in result["paths"].values()} == \
        {".capsbin", ".json", ".c", ".h"}


def test_tampered_capsbin_is_detected(tmp_path):
    """Weight-blob corruption cannot survive `same_as` — the equality
    export verification relies on really covers the payload bits."""
    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    paths = program.save(tmp_path / "m")
    blob = bytearray(paths["capsbin"].read_bytes())
    blob[-3] ^= 0x55                 # flip bits inside the last weight
    paths["capsbin"].write_bytes(bytes(blob))
    assert not program.same_as(EdgeProgram.load(paths["capsbin"]))


def test_per_channel_plan_fields_and_error_message():
    qnet, _ = built("capsnet_edge_tiny", per_channel=True)
    plan = qnet.plan["conv0"]
    assert plan.per_channel
    assert len(plan.w_frac_per_channel) == 8
    assert plan.out_shift_per_channel == tuple(
        plan.in_frac + f - plan.out_frac for f in plan.w_frac_per_channel)
    # the legacy string-keyed container cannot carry tuple tables; the
    # error now points at the typed path instead of claiming no layer
    # supports per-channel
    cfg = EDGE_TINY
    params = CapsPipeline.from_config(cfg).init(jax.random.key(0))
    calib = jnp.ones((2,) + cfg.input_shape)
    with pytest.raises(ValueError, match="quantize_pipeline"):
        ptq.quantize_capsnet(params, cfg, calib, per_channel=True)


def test_per_channel_plan_edit_reaches_quantize():
    """Regression: quantize() must use the PLAN's channel formats, not a
    fresh derivation — an edited w_frac_per_channel changes the weights
    consistently with the shifts fwd_q7 applies."""
    import dataclasses

    qnet, _ = built("capsnet_edge_tiny", per_channel=True)
    layer = qnet.pipeline.layer("conv0")
    params = CapsPipeline.from_config(
        EDGE_TINY, per_channel=True).init(jax.random.key(0))["conv0"]
    plan = qnet.plan["conv0"]
    edited = dataclasses.replace(
        plan,
        w_frac_per_channel=tuple(f - 1 for f in plan.w_frac_per_channel))
    w_base = np.asarray(layer.quantize(params, plan)["w"], np.int32)
    w_edit = np.asarray(layer.quantize(params, edited)["w"], np.int32)
    assert not np.array_equal(w_base, w_edit)
    # one fewer fractional bit == halved codes (up to rounding)
    np.testing.assert_allclose(w_edit, w_base / 2, atol=0.5)


def test_per_channel_weights_reconstruct_no_worse():
    """Per-channel formats can only tighten the weight grid (channel max
    <= tensor max), so reconstruction error must not regress."""
    qnet_pt, _ = built("capsnet_edge_tiny")
    qnet_pc, _ = built("capsnet_edge_tiny", per_channel=True)
    w = np.asarray(
        CapsPipeline.from_config(EDGE_TINY).init(jax.random.key(0))
        ["conv0"]["w"])
    pt = qnet_pt.plan["conv0"]
    pc = qnet_pc.plan["conv0"]
    err_pt = np.mean((w - np.asarray(qnet_pt.qweights["conv0"]["w"],
                                     np.float32) * 2.0 ** -pt.w_frac) ** 2)
    ns = np.asarray(pc.w_frac_per_channel, np.float32)
    err_pc = np.mean((w - np.asarray(qnet_pc.qweights["conv0"]["w"],
                                     np.float32) * 2.0 ** -ns) ** 2)
    assert err_pc <= err_pt + 1e-12


# ---------------------------------------------------------------------------
# .capsbin importer (serve exactly the artifact that shipped)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("per_channel", [False, True])
def test_importer_roundtrip_bit_exact(per_channel):
    """to_qnet inverts lower(): the imported model forwards bit-
    identically and re-lowers to the very same program."""
    from repro.edge import to_qnet

    qnet, x_q = built("capsnet_edge_tiny", "nearest", per_channel)
    program = lower(qnet)
    q2 = to_qnet(program)
    np.testing.assert_array_equal(
        np.asarray(q2.forward(jnp.asarray(x_q))),
        np.asarray(qnet.forward(jnp.asarray(x_q))))
    assert lower(q2, name=program.name).same_as(program)


def test_importer_multiconv_geometry():
    """The geometry rebuild handles deeper conv stacks (CIFAR's four
    convs), not just the single-conv edge_tiny schedule."""
    from repro.edge import to_qnet

    qnet, x_q = built("capsnet_cifar10")
    q2 = to_qnet(lower(qnet))
    cfg = q2.pipeline.cfg
    assert cfg.conv_filters == (32, 32, 64, 64)
    assert cfg.num_input_caps == qnet.pipeline.cfg.num_input_caps
    np.testing.assert_array_equal(
        np.asarray(q2.forward(jnp.asarray(x_q))),
        np.asarray(qnet.forward(jnp.asarray(x_q))))


def test_importer_from_disk_through_registry(tmp_path):
    """ModelRegistry.install_artifact serves the on-disk .capsbin bits:
    the served wave equals the EdgeVM executing the same file."""
    from repro.serving import compile_wave

    qnet, x_q = built("capsnet_edge_tiny")
    program = lower(qnet)
    paths = program.save(tmp_path / "shipped")

    reg = ModelRegistry(specs={})
    q2 = reg.install_artifact(paths["capsbin"], model_id="shipped")
    assert reg.has("shipped")
    assert reg.input_shape("shipped") == tuple(EDGE_TINY.input_shape)
    # default id = the program's own name
    reg.install_artifact(paths["capsbin"])
    assert reg.has("capsnet_edge_tiny")

    v_vm = EdgeVM(EdgeProgram.load(paths["capsbin"])).run(x_q)
    np.testing.assert_array_equal(
        np.asarray(q2.forward(jnp.asarray(x_q))), v_vm)

    rng = np.random.default_rng(11)
    images = rng.uniform(0, 1, (2,) + tuple(EDGE_TINY.input_shape)) \
        .astype(np.float32)
    exe = reg.executable("shipped", 2)
    np.testing.assert_array_equal(
        np.asarray(exe(images)[0]),
        np.asarray(q2.forward(q2.quantize_input(jnp.asarray(images)))))


def test_importer_rejects_malformed_schedules():
    from repro.edge import program_config, to_qnet
    import dataclasses

    qnet, _ = built("capsnet_edge_tiny")
    program = lower(qnet)
    doubled = dataclasses.replace(program,
                                  ops=program.ops + (program.ops[-1],))
    with pytest.raises(ValueError, match="CAPS_ROUTING_Q7"):
        program_config(doubled)
    with pytest.raises(ValueError):
        to_qnet(doubled)
