"""Paper-core behaviour: CapsNet learns, PTQ reproduces Table 2's
footprint saving and small accuracy delta, int8 pipeline is sane."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capsnet as C
from repro.data.synthetic import make_image_dataset
from repro.optim.adam import AdamW
from repro.quant import ptq


def train_small(cfg, steps=60, batch=64, seed=0):
    params = C.init_capsnet(jax.random.key(seed), cfg)
    opt = AdamW(lr=cfg.lr, clip_norm=0.0, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, x, y):
        def loss_fn(p):
            v = C.capsnet_forward(p, x, cfg)
            return C.margin_loss(v, y, cfg.num_classes), v
        (loss, v), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, state, _ = opt.update(g, state, params)
        return params, state, loss, C.accuracy(v, y)

    kind = cfg.name.split("_")[-1]
    accs = []
    for i in range(steps):
        x, y = make_image_dataset(kind, batch, seed=i)
        params, state, loss, acc = step(params, state, jnp.asarray(x),
                                        jnp.asarray(y))
        accs.append(float(acc))
    return params, accs


@pytest.fixture(scope="module")
def trained_mnist():
    return train_small(C.MNIST, steps=70)


def test_capsnet_geometry_matches_paper():
    """Table 2/7 cross-check: layer shapes & fp32 footprints."""
    assert C.MNIST.num_input_caps == 1024          # 10x1024x6x4 "L"
    assert C.SMALLNORB.num_input_caps == 1600      # 5x1600x6x4 "M"
    assert C.CIFAR10.num_input_caps == 64          # 10x64x5x4  "S"
    p = C.init_capsnet(jax.random.key(0), C.SMALLNORB)
    kb = C.param_bytes_fp32(p) / 1024
    assert abs(kb - 1182.34) < 30                  # paper: 1182.34 KB
    p = C.init_capsnet(jax.random.key(0), C.CIFAR10)
    kb = C.param_bytes_fp32(p) / 1024
    assert abs(kb - 461.19) < 15                   # paper: 461.19 KB


def test_capsnet_learns(trained_mnist):
    _, accs = trained_mnist
    assert np.mean(accs[-10:]) > 0.85, np.mean(accs[-10:])
    assert np.mean(accs[-10:]) > np.mean(accs[:5]) + 0.3


def test_ptq_footprint_saving_75pct(trained_mnist):
    params, _ = trained_mnist
    calib = jnp.asarray(make_image_dataset("mnist", 128, seed=5555)[0])
    qm = ptq.quantize_capsnet(params, C.MNIST, calib)
    rep = ptq.footprint_report(params, qm)
    assert 74.5 <= rep["saving_pct"] <= 75.0       # paper: 74.99 %


def test_ptq_small_accuracy_loss(trained_mnist):
    params, _ = trained_mnist
    calib = jnp.asarray(make_image_dataset("mnist", 128, seed=5555)[0])
    tx, ty = make_image_dataset("mnist", 256, seed=9999)
    tx, ty = jnp.asarray(tx), jnp.asarray(ty)
    acc_f = ptq.eval_float(params, C.MNIST, tx, ty)
    qm = ptq.quantize_capsnet(params, C.MNIST, calib, rounding="nearest")
    acc_q = ptq.eval_q7(qm, tx, ty)
    assert acc_f - acc_q < 0.03, (acc_f, acc_q)    # paper: 0.07-0.18 %


def test_ptq_shift_consistency(trained_mnist):
    """Alg. 6 invariants: out/bias shifts equal frac-bit differences."""
    params, _ = trained_mnist
    calib = jnp.asarray(make_image_dataset("mnist", 64, seed=1)[0])
    qm = ptq.quantize_capsnet(params, C.MNIST, calib)
    s = qm.shifts
    assert s["conv0_out_shift"] == s["input_frac"] + s["conv0_w_frac"] \
        - s["conv0_out_frac"]
    assert s["uhat_shift"] == 7 + s["caps_W_frac"] - s["uhat_frac"]
    for r in range(C.MNIST.routings):
        assert s[f"caps_out_shift_{r}"] == s["uhat_frac"] + 7 \
            - s[f"caps_out_frac_{r}"]


def test_q7_forward_uses_only_int8_tensors(trained_mnist):
    params, _ = trained_mnist
    calib = jnp.asarray(make_image_dataset("mnist", 64, seed=1)[0])
    qm = ptq.quantize_capsnet(params, C.MNIST, calib)
    for leaf in jax.tree_util.tree_leaves(qm.weights):
        assert leaf.dtype == jnp.int8
    from repro.core.capsnet_q7 import qcapsnet_forward
    x, _ = make_image_dataset("mnist", 4, seed=2)
    xq = ptq.quantize_input(jnp.asarray(x), qm.shifts["input_frac"])
    v = qcapsnet_forward(qm, xq)
    assert v.dtype == jnp.int8
    assert v.shape == (4, 10, 6)
