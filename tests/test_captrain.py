"""Training-subsystem tests (repro.captrain).

Pinned guarantees:
  * the fake-quant faces really live on the int8 grid: a conv layer's
    `fwd_fq` is BIT-identical to the dequantized `fwd_q7` (the int32
    accumulator is exactly representable in fp32 at these sizes), and
    `fake_quant`'s gradient is the straight-through identity;
  * QAT trains against the exact plans PTQ derives: `derive_plan` on a
    QAT-trained state equals the plan `pipeline.quantize` produces, and
    the quantized model round-trips through `lower()` / `EdgeVM` /
    `export_artifacts`' built-in re-verify bit-exactly;
  * checkpoint resume is deterministic: same step counter => same loss,
    bit for bit, including a resume mid-way through a QAT
    recalibration interval (the plan side-car);
  * the tree-reduced data-parallel step is bit-identical to the
    unsharded step on a 1-device mesh (fast tier) and on a real
    8-device mesh (slow tier, forced-host-device subprocess);
  * acceptance: a QAT fine-tuned edge_tiny exports with re-verify
    passing, and its float-vs-int8 accuracy delta on the synthetic
    edge-MNIST analogue is <= the plain-PTQ delta for the same seed.
"""
import dataclasses
import json
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.captrain import (CapsTrainer, TrainConfig, eval_q7,
                            pairwise_reduce, table2_rows)
from repro.data.synthetic import make_image_dataset
from repro.launch.mesh import make_host_mesh
from repro.nn.plans import plan_from_json, plan_to_json
from repro.quant import qformat as qf
from repro.serving.registry import EDGE_TINY

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

TINY = TrainConfig(dataset="edge_tiny", batch=32, microbatches=8,
                   calib_n=32, lr=3e-3, recalib_every=20)


@pytest.fixture(scope="module")
def trained():
    """One short float+QAT run shared by the structural tests."""
    trainer = CapsTrainer(EDGE_TINY, TINY)
    state = trainer.init_state()
    state, _, hist_f = trainer.fit(state, 30)
    qstate, plan, hist_q = trainer.fit(state, 10, qat=True)
    return trainer, state, qstate, plan, hist_f, hist_q


# ---------------------------------------------------------------------------
# fake-quant primitives
# ---------------------------------------------------------------------------
def test_fake_quant_forward_is_the_ptq_grid():
    """Forward values land exactly where quantize->dequantize would."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1.5, (64,)).astype(np.float32))
    for n in (2, 5, 7):
        np.testing.assert_array_equal(
            np.asarray(qf.fake_quant(x, n)),
            np.asarray(qf.dequantize(qf.quantize(x, n), n)))
        # floor mode truncates instead
        got = np.asarray(qf.fake_quant(x, n, rounding="floor"))
        want = np.clip(np.floor(np.asarray(x) * 2.0 ** n), -128, 127) \
            * 2.0 ** -n
        np.testing.assert_array_equal(got, want.astype(np.float32))


def test_fake_quant_gradient_is_identity():
    x = jnp.asarray([-3.0, -0.51, 0.0, 0.26, 0.75, 9.9], jnp.float32)
    for rounding in ("nearest", "floor"):
        g = jax.grad(lambda t: jnp.sum(qf.fake_quant(t, 7, rounding)))(x)
        np.testing.assert_array_equal(np.asarray(g), np.ones_like(x))
    ns = (3, 7)
    g = jax.grad(lambda t: jnp.sum(
        qf.fake_quant_with_fracs(t.reshape(3, 2), ns, axis=1)))(
        jnp.arange(6, dtype=jnp.float32) / 7)
    np.testing.assert_array_equal(np.asarray(g), np.ones(6, np.float32))


def test_fake_quant_per_channel_matches_quantizer():
    rng = np.random.default_rng(1)
    w = rng.normal(0, 0.3, (3, 3, 2, 4)).astype(np.float32)
    q, ns = qf.quantize_per_channel(w, axis=-1)
    fq = np.asarray(qf.fake_quant_with_fracs(jnp.asarray(w), ns, axis=-1))
    want = np.asarray(q, np.float32) * \
        (2.0 ** -np.asarray(ns, np.float32)).reshape(1, 1, 1, -1)
    np.testing.assert_array_equal(fq, want)


def test_conv_fwd_fq_bit_matches_dequantized_fwd_q7(trained):
    """At edge_tiny sizes the int32 conv accumulator fits fp32 exactly,
    so the fake-quant face must reproduce the int8 conv bit for bit
    under floor rounding (the same `>> shift` truncation)."""
    trainer, state, *_ = trained
    params = state["params"]["caps"]
    plan = trainer.derive_plan(state)
    layer = trainer.pipeline.layer("conv0")
    lp = plan["conv0"]

    x = trainer.calib_images()[:4]
    x_fq = np.asarray(qf.fake_quant(x, plan.input_frac))
    x_q = np.asarray(qf.quantize(x, plan.input_frac))

    y_fq = np.asarray(layer.fwd_fq(params["conv0"], lp,
                                   jnp.asarray(x_fq), rounding="floor"))
    qw = layer.quantize(params["conv0"], lp)
    y_q7 = np.asarray(layer.fwd_q7(qw, lp, jnp.asarray(x_q),
                                   rounding="floor"), np.float32)
    np.testing.assert_array_equal(y_fq, y_q7 * 2.0 ** -lp.out_frac)


def test_routing_fwd_fq_trains_against_plan_softmax(trained):
    """The fake-quant couplings follow RoutingPlan.softmax_impl: the
    "q7" variant reproduces int8_ops.softmax_q7's powers-of-two
    probabilities (within 1 code of the integer division), and flipping
    the plan field changes the QAT forward like it changes fwd_q7."""
    from repro.nn.layers import CapsuleRouting
    from repro.quant import int8_ops as q

    rng = np.random.default_rng(5)
    f = 5
    b_q = rng.integers(-128, 128, (2, 7, 9)).astype(np.int8)
    b = jnp.asarray(b_q, jnp.float32) * 2.0 ** -f   # on the Q(f) grid

    c_fq = np.asarray(CapsuleRouting._softmax_fq(b, "q7"))  # over axis 1
    c_int = np.asarray(q.softmax_q7(jnp.asarray(b_q).swapaxes(1, 2),
                                    in_frac=f)).swapaxes(1, 2)
    assert np.abs(c_fq * 128.0 - c_int).max() <= 1.0

    trainer, state, *_ = trained
    plan = trainer.derive_plan(state)
    params = state["params"]["caps"]
    layer = trainer.pipeline.layer("caps")
    u, _ = trainer.pipeline.layer("pcap").fwd_f32(
        params["pcap"],
        trainer.pipeline.layer("conv0").fwd_f32(
            params["conv0"], trainer.calib_images()[:2])[0])
    rp = plan["caps"]
    v_q7 = layer.fwd_fq(params["caps"], rp, u)
    v_pr = layer.fwd_fq(params["caps"],
                        dataclasses.replace(rp, softmax_impl="precise"), u)
    assert not np.array_equal(np.asarray(v_q7), np.asarray(v_pr))


# ---------------------------------------------------------------------------
# deterministic reduction + plan codec
# ---------------------------------------------------------------------------
def test_pairwise_reduce_sums_and_validates():
    a = jnp.arange(8.0)
    assert float(pairwise_reduce(a)) == 28.0
    m = jnp.arange(12.0).reshape(4, 3)
    np.testing.assert_array_equal(np.asarray(pairwise_reduce(m)),
                                  np.asarray(m.sum(0)))
    with pytest.raises(ValueError, match="power of two"):
        pairwise_reduce(jnp.arange(6.0))


def test_plan_json_roundtrip(trained):
    trainer, state, *_ = trained
    plan = trainer.derive_plan(state)
    blob = json.dumps(plan_to_json(plan), sort_keys=True)
    assert plan_from_json(json.loads(blob)) == plan


# ---------------------------------------------------------------------------
# trainer: smoke, QAT<->PTQ parity, export round-trip
# ---------------------------------------------------------------------------
def test_trainer_loss_decreases(trained):
    _, _, _, _, hist_f, hist_q = trained
    assert hist_f[-1]["loss"] < hist_f[0]["loss"]
    assert hist_f[-1]["step"] == 30
    assert hist_q[-1]["step"] == 40          # QAT continues the counter
    assert all(np.isfinite(h["loss"]) for h in hist_f + hist_q)


def test_qat_plan_equals_ptq_plan(trained):
    """The plan QAT trains against IS the plan PTQ derives for the same
    weights — one machinery, pinned."""
    trainer, _, qstate, _, _, _ = trained
    qnet = trainer.quantize(qstate)
    assert trainer.derive_plan(qstate) == qnet.plan


def test_qat_model_lowers_and_reverifies(tmp_path, trained):
    """A QAT-trained model goes through the UNCHANGED export path:
    export_artifacts' built-in reload + EdgeVM re-verify passes (it
    raises on any bit mismatch)."""
    from repro.edge import export_artifacts

    trainer, _, qstate, _, _, _ = trained
    for rounding in ("floor", "nearest"):
        qnet = trainer.quantize(qstate, rounding=rounding)
        result = export_artifacts(
            qnet, tmp_path, stem=f"qat_{rounding}",
            verify_images=np.asarray(trainer.calib_images()[:4]))
        assert result["verified"] == 4


def test_step_validates_batch_geometry(trained):
    trainer, state, *_ = trained
    x, y = trainer.task.batch(0, 12)          # 12 % 8 != 0
    with pytest.raises(ValueError, match="not divisible"):
        trainer.train_step(state, x, y)
    with pytest.raises(ValueError, match="power of two"):
        CapsTrainer(EDGE_TINY,
                    dataclasses.replace(TINY, microbatches=6)) \
            .train_step(state, *trainer.task.batch(0, 30))


# ---------------------------------------------------------------------------
# checkpoint / resume determinism
# ---------------------------------------------------------------------------
def test_ckpt_resume_same_step_same_loss(tmp_path):
    """Resume mid-QAT-interval: the restored run must replay the exact
    loss stream of the uninterrupted one (plan side-car + step-indexed
    batches + full optimizer state)."""
    tc = dataclasses.replace(TINY, recalib_every=4, calib_n=16,
                             ckpt_dir=str(tmp_path), ckpt_every=2)

    a = CapsTrainer(EDGE_TINY, tc)
    sa = a.init_state()
    sa, plan_a, hist_a = a.fit(sa, 6, qat=True)   # ckpts at 2, 4, 6

    # rewind to step 2 — inside the interval of the plan derived at step
    # 0, so the resumed run MUST take the side-car plan (re-deriving from
    # the step-2 weights would give different grids and different losses)
    (tmp_path / "step_00000004.npz").unlink()
    (tmp_path / "step_00000006.npz").unlink()
    (tmp_path / "LATEST").write_text("2")

    b = CapsTrainer(EDGE_TINY, tc)
    sb, plan_b = b.resume_or_init()
    assert b.step_index(sb) == 2
    assert plan_b is not None and plan_b != plan_a  # pre-recalib side-car
    sb, _, hist_b = b.fit(sb, 4, qat=True, plan=plan_b)

    assert [h["step"] for h in hist_b] == [3, 4, 5, 6]
    for ha, hb in zip(hist_a[2:], hist_b):
        assert ha["loss"] == hb["loss"], (ha, hb)   # bit-exact
        assert ha["accuracy"] == hb["accuracy"]
    for (pa, la), (pb, lb) in zip(
            jax.tree_util.tree_flatten_with_path(sa)[0],
            jax.tree_util.tree_flatten_with_path(sb)[0]):
        assert np.array_equal(np.asarray(la), np.asarray(lb)), pa


def test_resume_or_init_fresh_when_no_ckpt(tmp_path):
    tc = dataclasses.replace(TINY, ckpt_dir=str(tmp_path / "empty"))
    trainer = CapsTrainer(EDGE_TINY, tc)
    state, plan = trainer.resume_or_init()
    assert trainer.step_index(state) == 0 and plan is None


# ---------------------------------------------------------------------------
# sharded data-parallel steps
# ---------------------------------------------------------------------------
def _run_steps(mesh, n_float=3, n_qat=2):
    trainer = CapsTrainer(EDGE_TINY, TINY, mesh=mesh)
    state = trainer.init_state()
    state, _, hist = trainer.fit(state, n_float)
    state, _, hist2 = trainer.fit(state, n_qat, qat=True)
    return state, [h["loss"] for h in hist + hist2]


def test_sharded_step_bit_parity_on_1device_mesh():
    """Acceptance (fast half): the same trainer under a 1-device mesh
    reproduces the meshless run bit for bit, float and QAT steps."""
    mesh = make_host_mesh(("pod", "data", "model"))
    s0, l0 = _run_steps(None)
    s1, l1 = _run_steps(mesh)
    assert l0 == l1
    for (p, a), (_, b) in zip(jax.tree_util.tree_flatten_with_path(s0)[0],
                              jax.tree_util.tree_flatten_with_path(s1)[0]):
        assert np.array_equal(np.asarray(a), np.asarray(b)), p


@pytest.mark.slow
def test_sharded_step_bit_parity_on_8device_mesh():
    """Acceptance (slow half): on a real 8-device mesh the BATCH axis
    splits the microbatches across devices and the loss stream + final
    state still match the unsharded run bit for bit (the tree-reduced
    gradient contract, see captrain/steps.py)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.captrain import CapsTrainer, TrainConfig
        from repro.serving.registry import EDGE_TINY

        tc = TrainConfig(dataset="edge_tiny", batch=32, microbatches=8,
                         calib_n=16, lr=3e-3, recalib_every=20)

        def run(mesh):
            t = CapsTrainer(EDGE_TINY, tc, mesh=mesh)
            s = t.init_state()
            s, _, h1 = t.fit(s, 3)
            s, _, h2 = t.fit(s, 2, qat=True)
            return s, [h["loss"] for h in h1 + h2]

        s0, l0 = run(None)
        mesh = Mesh(np.asarray(jax.devices()).reshape(1, 8, 1),
                    ("pod", "data", "model"))
        s1, l1 = run(mesh)
        assert l0 == l1, (l0, l1)
        for a, b in zip(jax.tree_util.tree_leaves(s0),
                        jax.tree_util.tree_leaves(s1)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """) % SRC
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])


# ---------------------------------------------------------------------------
# acceptance: the Table-2 delta, QAT <= PTQ
# ---------------------------------------------------------------------------
def test_qat_delta_not_worse_than_ptq_delta():
    """Train edge_tiny on the synthetic edge-MNIST analogue, PTQ the
    float weights, QAT-fine-tune the same weights; under floor rounding
    the QAT model's float-vs-int8 delta must not exceed plain PTQ's
    (fixed seed — everything here is deterministic on CPU)."""
    rows = table2_rows(EDGE_TINY, TINY, float_steps=120, qat_steps=40,
                      eval_n=256, roundings=("floor",))
    (row,) = rows
    assert row.acc_f32 > 0.8, row                 # the task was learned
    assert row.delta_qat <= row.delta_ptq, row    # ISSUE acceptance
    assert row.saving_pct >= 70.0, row            # Table-2 memory story


def test_eval_q7_scores_like_class_lengths(trained):
    trainer, state, *_ = trained
    qnet = trainer.quantize(state)
    images, labels = make_image_dataset("edge_tiny", 32, seed=123)
    acc = eval_q7(qnet, images, labels, batch=10)  # partial batches
    xq = qnet.quantize_input(jnp.asarray(images))
    lengths = np.asarray(qnet.class_lengths(qnet.forward(xq)))
    want = float((lengths.argmax(-1) == labels).mean())
    assert acc == pytest.approx(want)
