"""Property-based tests (hypothesis) for the quantization framework's
invariants and the int8 numeric semantics.

hypothesis is an OPTIONAL test dependency (declared in pyproject.toml's
`test` extra); this module skips cleanly when it is not installed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional test dependency (pip install hypothesis)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.quant import int8_ops as q
from repro.quant import qformat as qf

finite_floats = st.floats(min_value=-1e4, max_value=1e4,
                          allow_nan=False, allow_subnormal=False)


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=1e-6, max_value=1e4, allow_nan=False))
def test_frac_bits_maximal(max_abs):
    """Alg. 7 invariant: n is the LARGEST exponent whose quantized max
    still fits in [-127, 127]."""
    n = qf.frac_bits(max_abs)
    assert round(max_abs * 2.0 ** n) <= 127
    if n < qf.MAX_FRAC_BITS:
        assert round(max_abs * 2.0 ** (n + 1)) > 127


@settings(max_examples=100, deadline=None)
@given(st.lists(finite_floats, min_size=1, max_size=64))
def test_quantize_roundtrip_bound(vals):
    """|dequant(quant(x)) - x| <= 0.5 * 2^-n for in-range x (round-to-
    nearest with power-of-two step)."""
    x = np.array(vals, np.float32)
    n = qf.frac_bits(float(np.abs(x).max()))
    deq = np.asarray(qf.dequantize(qf.quantize(x, n), n))
    assert np.all(np.abs(deq - x) <= 0.5 * 2.0 ** -n + 1e-7)


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_isqrt_is_floor_sqrt(n):
    got = int(q.isqrt_newton(jnp.asarray([n], jnp.int32))[0])
    want = int(np.floor(np.sqrt(np.float64(n))))
    # guard fp edge at perfect squares
    while (want + 1) * (want + 1) <= n:
        want += 1
    while want * want > n:
        want -= 1
    assert got == want, (n, got, want)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=2, max_value=16),
       st.integers(min_value=0, max_value=1000))
def test_softmax_q7_normalized(ncls, seed):
    """Integer softmax outputs are a Q0.7 distribution: non-negative and
    summing to ~1.0 (128), never exceeding 127 per entry."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (4, ncls)), jnp.int8)
    c = q.softmax_q7(x, in_frac=5)
    c = np.asarray(c, np.int32)
    assert (c >= 0).all() and (c <= 127).all()
    s = c.sum(-1)
    assert ((s >= 128 - ncls) & (s <= 128 + ncls)).all(), s


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=1000),
       st.integers(min_value=2, max_value=8),
       st.integers(min_value=3, max_value=9))
def test_squash_q7_norm_bounded(seed, D, in_frac):
    """squash output length <= 1.0 (i.e. ||v||_q <= 128 + rounding slack),
    and v is parallel to s (signs preserved)."""
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.integers(-128, 128, (16, D)), jnp.int8)
    v = np.asarray(q.squash_q7(s, in_frac=in_frac), np.int32)
    norm = np.sqrt((v.astype(np.int64) ** 2).sum(-1))
    assert (norm <= 130).all(), norm.max()
    sn = np.asarray(s, np.int32)
    assert ((v == 0) | (np.sign(v) == np.sign(sn))).all()


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_matmul_q7_dequant_close_to_float(seed):
    """dequant(matmul_q7(q(a), q(b))) approximates the float product within
    the accumulated rounding bound."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(-1, 1, (8, 16)).astype(np.float32)
    b = rng.uniform(-1, 1, (16, 8)).astype(np.float32)
    na, nb = qf.frac_bits(np.abs(a).max()), qf.frac_bits(np.abs(b).max())
    ref_out = a @ b
    n_out = qf.frac_bits(np.abs(ref_out).max() + 1e-9)
    shift = qf.out_shift(na, nb, n_out)
    got = q.matmul_q7(qf.quantize(a, na), qf.quantize(b, nb), shift,
                      rounding="nearest")
    deq = np.asarray(got, np.float32) * 2.0 ** -n_out
    # error: K per-element quantization errors + one output rounding
    K = a.shape[1]
    bound = K * (2.0 ** -na + 2.0 ** -nb) * 0.75 + 2.0 ** -n_out
    assert np.abs(deq - ref_out).max() <= bound


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_float_routing_coupling_sums_to_one(seed):
    """Float dynamic routing: softmax over output capsules -> for each
    input capsule the couplings sum to 1; squash keeps ||v|| < 1."""
    from repro.core.routing import dynamic_routing, squash
    rng = np.random.default_rng(seed)
    u_hat = jnp.asarray(rng.normal(0, 0.3, (2, 5, 16, 4)), jnp.float32)
    v, _ = dynamic_routing(u_hat, num_iters=3)
    norms = np.linalg.norm(np.asarray(v), axis=-1)
    assert (norms < 1.0).all()
    s = jnp.asarray(rng.normal(0, 2.0, (7, 4)))
    vs = np.linalg.norm(np.asarray(squash(s)), axis=-1)
    assert (vs < 1.0).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=1000))
def test_grad_compress_error_bound(seed):
    """One EF round: |g - decompress(compress(g))| <= step/2, and the
    error buffer equals the residual exactly."""
    from repro.optim import grad_compress as gc
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)
    qv, e = gc.compress(g)
    deq = gc.decompress(qv, e)
    step = float(jnp.exp2(-e))
    assert float(jnp.max(jnp.abs(deq - g))) <= 0.5 * step + 1e-7


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=100))
def test_per_channel_quant_tighter_than_per_tensor(seed):
    """Beyond-paper per-channel quantization never has larger per-channel
    reconstruction error than per-tensor (property of maximal formats)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 1, (16, 8)).astype(np.float32)
    w[:, 0] *= 20.0                      # one loud channel
    n_t = qf.frac_bits(np.abs(w).max())
    per_t = np.asarray(qf.dequantize(qf.quantize(w, n_t), n_t))
    qc, ns = qf.quantize_per_channel(w, axis=1)
    per_c = np.asarray(qc, np.float32) * (2.0 ** -np.asarray(ns))[None, :]
    err_t = np.abs(per_t - w).max(0)
    err_c = np.abs(per_c - w).max(0)
    assert (err_c <= err_t + 1e-7).all()
