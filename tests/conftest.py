import os
import sys

# tests see ONE device (the dry-run process forces 512 itself; forcing it
# here would poison every smoke test / benchmark — see the dryrun docstring)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def tiny_lm_config(**kw):
    from repro.configs.base import ModelConfig
    base = dict(name="tiny", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                vocab_size=256)
    base.update(kw)
    return ModelConfig(**base)
