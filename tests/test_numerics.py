"""Tests for the numeric-health probe layer (repro.obs.numerics).

Pins, in order:
  * probes-off is the untouched hot path (no ambient probe, module
    hooks no-op) and probes-ON execution is bit-identical to
    probes-off for every shipped config x both roundings — on the
    EdgeVM, the jnp `fwd_q7` pipeline, and the fake-quant face;
  * observed range ⊆ static interval bound on every op of every
    shipped config x rounding (`check_containment` empty, bound
    tightness <= 1, every VM requant site has a static bound to
    check against) — the runtime cross-validation of the PR 6
    verifier;
  * mutation localization: shrinking a shift in an EdgeProgram makes
    the saturation telemetry point at the SAME op the static checker
    flags (conv out_shift and routing uhat_shift);
  * fake-quant STE-clip counting is exact, and `CapsTrainer` records
    a per-recalibration `qat.clip_rate` series into its registry;
  * `NumericsReport` docs round-trip bit-identically through
    repro.numerics/v1 JSON, the analyze CLI accepts them and
    `--gate-clips` gates, the bench validator's numerics invariant
    fires, and the baseline policy gates the new metrics.
"""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

import test_edge
from repro.analysis import check_program
from repro.analysis.ranges import requant_bounds
from repro.edge import EdgeVM, lower
from repro.obs import MetricsRegistry
from repro.obs import numerics as nh
from repro.quant import qformat as qf


@pytest.fixture(autouse=True)
def _no_ambient_probe():
    """Probing is always scoped; a leaked ambient probe would silently
    slow (and observe) every later test."""
    assert nh.get_probe() is None
    yield
    assert nh.get_probe() is None


# ---------------------------------------------------------------------------
# probes-off: the hot path is untouched
# ---------------------------------------------------------------------------
def test_probes_off_hooks_are_noops():
    # module-level hooks return before touching their arguments
    nh.observe_requant(np.array([1, 2]), 3, "floor")
    nh.observe_fq(np.array([999.0]))
    with nh.scope("anything"):
        pass
    assert nh.get_probe() is None


def test_probing_restores_previous_probe_on_exception():
    p = nh.NumericsProbe()
    with pytest.raises(RuntimeError):
        with nh.probing(p):
            assert nh.get_probe() is p
            raise RuntimeError("boom")
    assert nh.get_probe() is None


# ---------------------------------------------------------------------------
# bit-parity + containment: every shipped config x both roundings
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rounding", ["floor", "nearest"])
@pytest.mark.parametrize("name", sorted(test_edge.CONFIGS))
def test_vm_probed_bit_identical_and_contained(name, rounding):
    qnet, x_q = test_edge.built(name, rounding)
    program = lower(qnet)
    vm = EdgeVM(program)
    ref = vm.run(x_q)

    probe = nh.NumericsProbe()
    with nh.probing(probe):
        out = vm.run(x_q)
    np.testing.assert_array_equal(ref, out)

    report = nh.NumericsReport(program=program.name,
                               rounding=program.rounding,
                               batch=int(x_q.shape[0]), rows=probe.rows())
    # no int32 clips ever on a verifier-clean program
    assert report.total_int32_clip() == 0
    # observed range ⊆ static interval bound, op/tensor-precise
    assert nh.check_containment(program, report) == []
    sites, out_ivs = requant_bounds(program)
    for row in report.rows:
        if row["family"] == "requant":
            # every VM requant site has a static bound to check against
            assert (row["op_index"], row["site"]) in sites
            tight = row.get("bound_tightness")
            if tight is not None:
                assert 0.0 < tight <= 1.0
        elif row["family"] == "output":
            assert row["op_index"] in out_ivs


@pytest.mark.parametrize("rounding", ["floor", "nearest"])
def test_fwd_q7_jnp_probed_bit_identical(rounding):
    qnet, x_q = test_edge.built("capsnet_edge_tiny", rounding)
    ref = np.asarray(qnet.forward(jnp.asarray(x_q)))
    probe = nh.NumericsProbe()
    with nh.probing(probe):
        out = np.asarray(qnet.forward(jnp.asarray(x_q)))
    np.testing.assert_array_equal(ref, out)
    rows = probe.rows()
    assert {r["op"] for r in rows} == {l.name for l in qnet.pipeline.layers}
    assert sum(r.get("int32_clip", 0) for r in rows) == 0


def test_forward_fq_probed_values_identical():
    qnet, _ = test_edge.built("capsnet_edge_tiny", "floor")
    pipe = qnet.pipeline
    params = pipe.init(__import__("jax").random.key(0))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(0, 1, (2,) + pipe.cfg.input_shape)
                    .astype(np.float32))
    ref = np.asarray(pipe.forward_fq(params, x, qnet.plan))
    probe = nh.NumericsProbe()
    with nh.probing(probe):
        out = np.asarray(pipe.forward_fq(params, x, qnet.plan))
    np.testing.assert_array_equal(ref, out)
    rates = probe.fq_clip_rates()
    assert "input" in rates
    assert all(0.0 <= v <= 1.0 for v in rates.values())


# ---------------------------------------------------------------------------
# mutation localization: telemetry agrees with the static checker
# ---------------------------------------------------------------------------
def _mutate_attr(program, op_index, **edits):
    op = program.ops[op_index]
    op = dataclasses.replace(op, attrs={**op.attrs, **edits})
    ops = list(program.ops)
    ops[op_index] = op
    return dataclasses.replace(program, ops=tuple(ops))


def _worst_saturation_row(report):
    rows = [r for r in report.rows if r["family"] == "requant"]
    return max(rows, key=lambda r: r["saturation_rate"])


@pytest.mark.parametrize("mutate,site", [
    (lambda p: _mutate_attr(p, 0, out_shift=p.ops[0].attrs["out_shift"] - 4),
     "out"),
    (lambda p: _mutate_attr(p, 2, uhat_shift=p.ops[2].attrs["uhat_shift"] - 4),
     "uhat"),
], ids=["conv-out-shift", "routing-uhat-shift"])
def test_mutation_saturation_localizes_checker_finding(mutate, site):
    qnet, x_q = test_edge.built("capsnet_edge_tiny", "floor")
    bad = mutate(lower(qnet))

    result = check_program(bad)
    assert not result.ok
    plan_diags = [d for d in result.diagnostics
                  if d.check.startswith("plan.") and d.op_index is not None]
    assert plan_diags, [str(d) for d in result.diagnostics]
    flagged_ops = {d.op_index for d in plan_diags}

    # the mutated shift only changes the requantization, never the
    # accumulator, so the VM's acc_bound assert stays quiet and the
    # saturation telemetry is what localizes the defect
    _, report = nh.run_program_numerics(bad, x_q)
    worst = _worst_saturation_row(report)
    assert worst["saturation_rate"] > 0.0
    assert worst["op_index"] in flagged_ops
    # the mutated site itself saturates (downstream sites on the same
    # op may saturate even harder — e.g. s[r] after a blown uhat)
    (mutated,) = [r for r in report.rows if r["family"] == "requant"
                  and r["op_index"] in flagged_ops and r["site"] == site]
    assert mutated["saturation_rate"] > 0.0


# ---------------------------------------------------------------------------
# SNR probe mode + report serialization
# ---------------------------------------------------------------------------
def _edge_tiny_report(n=4):
    qnet, _ = test_edge.built("capsnet_edge_tiny", "floor")
    params = qnet.pipeline.init(__import__("jax").random.key(0))
    rng = np.random.default_rng(11)
    images = rng.uniform(0, 1, (n,) + qnet.pipeline.cfg.input_shape) \
        .astype(np.float32)
    return nh.run_numerics(qnet, images, params=params)


def test_snr_rows_one_per_layer():
    report = _edge_tiny_report()
    qnet, _ = test_edge.built("capsnet_edge_tiny", "floor")
    assert [r["layer"] for r in report.snr] == \
        [l.name for l in qnet.pipeline.layers]
    for r in report.snr:
        assert r["noise_power"] >= 0.0
        assert r["snr_db"] is None or np.isfinite(r["snr_db"])
    # the conv front is well-quantized: clearly positive SNR
    assert report.snr[0]["snr_db"] > 10.0


def test_report_doc_roundtrip_identical():
    report = _edge_tiny_report()
    doc = json.loads(json.dumps(report.to_doc(), sort_keys=True))
    back = nh.NumericsReport.from_doc(doc)
    assert back.rows == report.rows
    assert back.snr == report.snr
    assert back.summary() == report.summary()
    assert back.format() == report.format()
    with pytest.raises(ValueError):
        nh.NumericsReport.from_doc({"schema": "repro.trace/v1"})


def test_report_summary_names_worst_offenders():
    report = _edge_tiny_report()
    s = report.summary()
    assert s["int32_clip_total"] == 0
    assert s["worst_tightness"]["tightness"] == \
        pytest.approx(report.max_bound_tightness())
    assert s["min_snr"]["snr_db"] == pytest.approx(report.min_snr_db())


# ---------------------------------------------------------------------------
# fake-quant clip counting + the trainer's QAT series
# ---------------------------------------------------------------------------
def test_fake_quant_clip_count_exact():
    probe = nh.NumericsProbe()
    with nh.probing(probe):
        qf.fake_quant(jnp.asarray([0.1, 5.0, -5.0]), 7)
    (rec,) = [r for r in probe.rows() if r["family"] == "fq"]
    assert rec["n"] == 3
    assert rec["clipped"] == 2
    assert rec["clip_rate"] == pytest.approx(2 / 3)


def test_trainer_records_clip_rate_series():
    from repro.captrain.trainer import CapsTrainer, TrainConfig
    from repro.serving import EDGE_TINY

    reg = MetricsRegistry("testrun")
    tcfg = TrainConfig(dataset="edge_tiny", batch=8, microbatches=2,
                       recon_weight=0.0, recalib_every=2, calib_n=8)
    trainer = CapsTrainer(EDGE_TINY, tcfg, metrics=reg)
    state = trainer.init_state()
    state, plan, _ = trainer.fit(state, 3, qat=True)
    assert plan is not None

    snap = reg.snapshot()
    assert "qat.clip_rate" in snap
    series = snap["qat.clip_rate"]["series"]
    steps = {s["labels"]["step"] for s in series}
    layers = {s["labels"]["layer"] for s in series}
    assert steps == {"0", "2"}          # entry + the recalib boundary
    assert {"conv0", "pcap", "caps"} <= layers
    assert all(0.0 <= s["value"] <= 1.0 for s in series)


def test_run_numerics_streams_metrics():
    qnet, _ = test_edge.built("capsnet_edge_tiny", "floor")
    reg = MetricsRegistry("testrun")
    rng = np.random.default_rng(5)
    images = rng.uniform(0, 1, (2,) + qnet.pipeline.cfg.input_shape) \
        .astype(np.float32)
    nh.run_numerics(qnet, images, metrics=reg)
    snap = reg.snapshot()
    assert "numerics.range_utilization" in snap
    ops = {s["labels"]["op"]
           for s in snap["numerics.range_utilization"]["series"]}
    assert {"conv0", "pcap", "caps"} <= ops


# ---------------------------------------------------------------------------
# surfaces: analyze CLI, bench validator, baseline policy
# ---------------------------------------------------------------------------
def test_analyze_cli_accepts_numerics_doc(tmp_path, capsys):
    from repro.obs import analyze

    report = _edge_tiny_report()
    path = tmp_path / "numerics.json"
    path.write_text(json.dumps(report.to_doc(), sort_keys=True))
    assert analyze.main([str(path), "--gate-clips"]) == 0
    assert "numerics report" in capsys.readouterr().out

    doc = report.to_doc()
    doc["rows"] = [dict(r, int32_clip=5) if r["family"] == "requant"
                   else r for r in doc["rows"]]
    bad = tmp_path / "clipped.json"
    bad.write_text(json.dumps(doc, sort_keys=True))
    assert analyze.main([str(bad)]) == 0            # report-only: fine
    assert analyze.main([str(bad), "--gate-clips"]) == 1


def test_validator_gates_numerics_clips():
    from benchmarks import validate

    assert "numerics" in validate.KNOWN_SECTIONS
    doc = {"section": "numerics", "figures": {"int32_clip_total": 0}}
    assert validate.validate_invariants(doc, "x") == []
    doc["figures"]["int32_clip_total"] = 3
    findings = validate.validate_invariants(doc, "x")
    assert findings and "int32_clip_total" in findings[0]


def test_baseline_policy_gates_numerics_metrics():
    from repro.obs.baseline import METRIC_POLICY

    assert METRIC_POLICY["saturation_rate"].direction == "lower"
    assert METRIC_POLICY["snr_db"].direction == "higher"
    assert METRIC_POLICY["int32_clip"].direction == "exact"
    # negative-valued metrics gate in the right direction: the worst
    # acceptable SNR is BELOW a negative baseline, not above it
    assert METRIC_POLICY["snr_db"].bound(-6.0, 1.0) < -6.0
    assert METRIC_POLICY["snr_db"].bound(6.0, 1.0) < 6.0
