"""Checkpoint/restart + fault-tolerance machinery."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ckpt
from repro.dist.fault import choose_mesh, run_with_restarts


def make_tree(seed=0):
    k = jax.random.key(seed)
    return {
        "a": jax.random.normal(k, (4, 8), jnp.float32),
        "nested": {"b": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
                   "c": (jnp.ones((3,), jnp.bfloat16),
                         jnp.zeros((), jnp.int32))},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = make_tree()
    ckpt.save(tmp_path, 7, tree)
    got = ckpt.restore(tmp_path, 7, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_and_gc(tmp_path):
    tree = make_tree()
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, tree)
    assert ckpt.latest_step(tmp_path) == 4
    ckpt.gc_keep_n(tmp_path, keep=2)
    snaps = sorted(os.listdir(tmp_path))
    assert "step_00000003.npz" in snaps and "step_00000001.npz" not in snaps


def test_latest_marker_lost_falls_back_to_scan(tmp_path):
    tree = make_tree()
    ckpt.save(tmp_path, 5, tree)
    (tmp_path / "LATEST").unlink()
    assert ckpt.latest_step(tmp_path) == 5


def test_partial_write_is_ignored(tmp_path):
    tree = make_tree()
    ckpt.save(tmp_path, 3, tree)
    # simulate a crash mid-write of step 4
    (tmp_path / "step_00000004.npz.tmp").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 3
    step, got = ckpt.restore_latest(tmp_path, jax.eval_shape(lambda: tree))
    assert step == 3 and got is not None


def test_training_resume_is_exact(tmp_path):
    """Crash-restart continuity: 10 straight steps == 5 steps + crash +
    resume + 5 steps, bit-for-bit (deterministic index-based data)."""
    from repro.optim.adam import AdamW
    from repro.data.synthetic import TokenTask

    opt = AdamW(lr=1e-2, clip_norm=1.0)
    task = TokenTask(64, 16, seed=1)
    w0 = jnp.ones((16, 64), jnp.float32) * 0.01

    def loss_fn(w, batch):
        x = jax.nn.one_hot(batch["inputs"], 64) @ w.T  # [B,S,16]
        logits = x @ w                                  # [B,S,64]
        return jnp.mean(
            (logits - jax.nn.one_hot(batch["targets"], 64)) ** 2)

    @jax.jit
    def step(state, batch):
        g = jax.grad(loss_fn)(state["params"], batch)
        p, o, _ = opt.update(g, state["opt"], state["params"])
        return {"params": p, "opt": o, "step": state["step"] + 1}

    def run(state, a, b):
        for i in range(a, b):
            state = step(state, jax.tree.map(jnp.asarray, task.batch(i, 4)))
        return state

    ref_state = run({"params": w0, "opt": opt.init(w0),
                     "step": jnp.zeros((), jnp.int32)}, 0, 10)

    st = run({"params": w0, "opt": opt.init(w0),
              "step": jnp.zeros((), jnp.int32)}, 0, 5)
    ckpt.save(tmp_path, 5, st)
    del st                                   # "crash"
    step_n, st2 = ckpt.restore_latest(
        tmp_path, jax.eval_shape(lambda: {"params": w0,
                                          "opt": opt.init(w0),
                                          "step": jnp.zeros((), jnp.int32)}))
    st2 = run(st2, step_n, 10)
    np.testing.assert_array_equal(np.asarray(ref_state["params"]),
                                  np.asarray(st2["params"]))


def test_run_with_restarts_retries_then_succeeds():
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("simulated node failure")
        return 42

    assert run_with_restarts(flaky, max_restarts=3, backoff_s=0.01) == 42
    assert calls == [0, 1, 2]


def test_choose_mesh_elastic():
    assert choose_mesh(512) == (2, 16, 16)
    assert choose_mesh(256) == (1, 16, 16)
    assert choose_mesh(480) == (2, 15, 16)   # lost 2 hosts of 8 chips
    with pytest.raises(ValueError):
        choose_mesh(100, model=16)
