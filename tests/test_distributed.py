"""Distribution-layer tests.

The sharding/dry-run path needs >1 XLA device, which must be forced BEFORE
jax initializes — so the heavy test shells out to a fresh interpreter with
XLA_FLAGS set (same pattern as launch/dryrun.py).
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import pytest

from jax.sharding import PartitionSpec as P

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def test_fspec_filters_missing_axes():
    from repro.dist.api import fspec

    class FakeMesh:
        axis_names = ("data", "model")
    m = FakeMesh()
    assert fspec(m, ("pod", "data"), None, "model") == \
        P("data", None, "model")
    assert fspec(m, "pod", "model") == P(None, "model")


def test_param_rules_cover_every_leaf():
    """Every parameter leaf of every assigned arch resolves to a spec whose
    ndim matches (no silent P() fallbacks for shardable >=2D weights)."""
    from repro.configs.base import ARCH_IDS, get_config
    from repro.launch.train import reduced
    from repro.models.transformer import build_model
    from repro.dist import sharding as shd
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch), d_model=64)
        model = build_model(cfg)
        tree = jax.eval_shape(lambda m=model: m.init(jax.random.key(0)))
        specs = shd.param_specs(tree)
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        sflat = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat) == len(sflat)
        for (path, leaf), spec in zip(flat, sflat):
            if len(spec) > 0:
                assert len(spec) == leaf.ndim, (path, leaf.shape, spec)


def test_hlo_cost_model_trip_counts():
    """The HLO analyzer must multiply nested while bodies by trip counts —
    XLA's own cost_analysis does not (the reason this module exists)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp
        from repro.dist.hlo_analysis import analyze_hlo

        def layer(x, w):
            return jnp.tanh(x @ w)

        def nested(x, ws):
            def outer(c, w):
                def inner(ci, _):
                    return layer(ci, w), None
                c, _ = jax.lax.scan(inner, c, None, length=5)
                return c, None
            y, _ = jax.lax.scan(outer, x, ws)
            return y

        x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
        c = jax.jit(nested).lower(x, ws).compile()
        cost = analyze_hlo(c.as_text())
        expected = 50 * 2 * 128 * 256 * 256
        assert abs(cost.flops - expected) / expected < 1e-6, cost.flops
        assert cost.n_whiles >= 2
        print("OK")
    """) % SRC
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_tiny_multipod_dryrun_compiles():
    """A reduced arch must lower+compile on a (2,2,2) pod mesh with the
    production sharding rules, and the collective parser must find real
    collective traffic (all-gather/all-reduce from FSDP+TP)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.configs.base import get_config, ShapeSpec
        from repro.launch.train import reduced
        from repro.launch import steps
        from repro.launch.roofline import analyze_cell

        cfg = reduced(get_config("qwen3_14b"), d_model=128)
        shape = ShapeSpec("tiny_train", "train", 64, 8)
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 2, 2),
                    ("pod", "data", "model"))
        with mesh:
            fn, args, in_sh, out_sh = steps.make_cell(cfg, shape, mesh)
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*args).compile()
            rec = analyze_cell(compiled, cfg, shape, mesh, "tiny")
        assert rec["collective_bytes_per_dev"] > 0, rec["collectives"]
        assert rec["flops_per_dev"] > 0
        assert rec["memory"]["temp_size_in_bytes"] > 0
        print("OK", rec["collectives"]["count_by_kind"])
    """) % SRC
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=600)
    assert "OK" in out.stdout, (out.stdout[-1000:], out.stderr[-3000:])


def test_dryrun_artifacts_complete_if_present():
    """If the full sweep has been run, every (arch x shape x mesh) cell
    must be ok or a documented skip — a failed cell is a bug (assignment:
    'Failures here are bugs in your system')."""
    art = pathlib.Path("artifacts/dryrun")
    if not art.exists() or len(list(art.glob("*.json"))) < 80:
        pytest.skip("full sweep not run in this environment")
    bad = []
    for f in art.glob("*__single.json"):
        rec = json.loads(f.read_text())
        if rec["status"] not in ("ok", "skipped"):
            bad.append(f.name)
    for f in art.glob("*__multi.json"):
        rec = json.loads(f.read_text())
        if rec["status"] not in ("ok", "skipped"):
            bad.append(f.name)
    assert not bad, bad
